"""Stateful property-test harness for the continuous-batching scheduler.

A hypothesis :class:`RuleBasedStateMachine` drives random interleavings
of the scheduler op vocabulary — submit (mixed prompt/output lengths and
priorities), step, fault injection — against the *real*
:class:`~repro.serving.ServingEngine` (real event DAG, real size-class
``BufferPool`` paging) over the deterministic
:class:`~repro.serving.executor.StubExecutor`, whose closed-form
``expected_tokens`` is the single-slot oracle: the token stream a
request must produce when served alone, one at a time.

Invariants checked after every step and at teardown (docs/serving.md):

* every submitted request reaches a terminal state **exactly once** —
  completed or failed, never dropped, never completed twice (preemption
  requeues, it does not retire);
* per-request outputs are **independent of arrival interleaving**: a
  running request's stream is always a prefix of the oracle stream, a
  completed request's stream equals it bitwise;
* failures are always *typed* (:class:`~repro.core.errors.ReproError`)
  and only ever the injected fault's error;
* the KV pool **never leaks pages**: live-page accounting matches the
  resident slots at every step and returns to zero across a full drain,
  with every allocated page freed.

The op/oracle logic lives in :class:`SchedDriver`, which needs no
hypothesis — a seeded random-walk test (plus a single-slot
cross-engine comparison) drives it on every install, and the hypothesis
state machine (run under the ``ci``/``dev`` profiles registered in
tests/conftest.py, the PR-4 pattern) adds minimized counterexamples.
"""

import random

import numpy as np

from repro.core.errors import DeviceLostError, ReproError
from repro.serving import Request, RequestState, ServingEngine, StubExecutor

try:
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:               # plain tests below still run
    HAVE_HYPOTHESIS = False

SLOTS = 2
MAX_SEQ = 64
PAGE_TOKENS = 4
BUDGET_PAGES = 10                 # 40 tokens: two residents can collide
MAX_PROMPT = 8
MAX_NEW = 20                      # 8 + 20 + 1 < 40: any request fits alone


class SchedDriver:
    """The machine body: a real engine + the closed-form oracle.

    Every op method performs the real operation and asserts the
    op-local contract; :meth:`check_invariants` asserts the global
    ones.  Drivable by hypothesis rules or a plain seeded random walk.
    """

    def __init__(self, budget_pages=BUDGET_PAGES):
        self.ex = StubExecutor(batch_slots=SLOTS, max_seq=MAX_SEQ,
                               bytes_per_token=64)
        budget = None if budget_pages is None \
            else budget_pages * PAGE_TOKENS * 64
        self.eng = ServingEngine(None, None, None, batch_slots=SLOTS,
                                 max_seq=MAX_SEQ, executor=self.ex,
                                 page_tokens=PAGE_TOKENS,
                                 kv_budget_bytes=budget)
        self.requests = []        # every request ever submitted
        self.retired = set()      # ids observed terminal (exactly once)
        self.injected = {}        # id -> injected error

    # -- ops -------------------------------------------------------------------
    def submit(self, plen, max_new, priority, seed):
        rng = np.random.default_rng(seed)
        r = Request(prompt=rng.integers(0, 500, plen).astype(np.int32),
                    max_new_tokens=max_new, priority=priority)
        self.eng.submit(r)
        assert r.id >= 0 and r.state == RequestState.WAITING
        self.requests.append(r)
        return r

    def step(self):
        finished = self.eng.step()
        for r in finished:
            assert r.id not in self.retired, \
                f"request {r.id} retired twice"
            self.retired.add(r.id)
            self._check_terminal(r)
        return finished

    def inject_fault(self, idx, stage):
        live = [r for r in self.requests if r.id not in self.retired]
        if not live:
            return
        r = live[idx % len(live)]
        err = DeviceLostError(f"chaos:{r.id}:{stage}")
        self.eng.inject_fault(r, stage=stage, error=err)
        self.injected[r.id] = err

    def drain(self):
        out = self.eng.drain()
        for r in out:
            assert r.id not in self.retired
            self.retired.add(r.id)
            self._check_terminal(r)

    # -- the oracle ------------------------------------------------------------
    def _oracle(self, r):
        return StubExecutor.expected_tokens(r.prompt, r.max_new_tokens,
                                            eos_token=r.eos_token)

    def _check_terminal(self, r):
        if r.done:
            assert r.state == RequestState.FINISHED
            # bitwise-identical to serving the request alone: output
            # independent of slots, co-tenants, preemption, arrivals
            assert r.out_tokens == self._oracle(r), \
                f"request {r.id} stream diverged from the oracle"
        else:
            assert r.state == RequestState.FAILED
            assert isinstance(r.error, ReproError), r.error
            assert r.id in self.injected, \
                f"request {r.id} failed without an injected fault"
            assert r.error is self.injected[r.id]

    def check_invariants(self):
        kv = self.eng.kv_stats
        sched = self.eng.scheduler_stats
        # page accounting matches the resident slots at every step
        live_pages = sum(len(s.pages) for s in self.eng._slots
                         if s is not None)
        assert kv["pages_live"] == live_pages
        assert kv["kv_used_bytes"] == live_pages * kv["page_bytes"]
        assert sched["pages_allocated"] - sched["pages_freed"] == \
            live_pages
        # no request is lost: everything submitted is waiting, resident,
        # or retired — and never more than one of those
        waiting_ids = {r.id for r in self.eng._waiting}
        running_ids = {s.request.id for s in self.eng._slots
                       if s is not None}
        assert not (waiting_ids & running_ids)
        assert not (waiting_ids | running_ids) & self.retired
        for r in self.requests:
            assert (r.id in waiting_ids) or (r.id in running_ids) or \
                (r.id in self.retired), f"request {r.id} dropped"
            if r.id in running_ids:
                # a running stream is always an oracle prefix
                oracle = self._oracle(r)
                assert r.out_tokens == oracle[:len(r.out_tokens)]

    def check_drained(self):
        assert {r.id for r in self.requests} == self.retired, \
            "drain left requests behind"
        kv = self.eng.kv_stats
        assert kv["pages_live"] == 0 and kv["kv_used_bytes"] == 0, \
            "KV pool leaked pages across a full drain"
        sched = self.eng.scheduler_stats
        assert sched["pages_allocated"] == sched["pages_freed"]


# --------------------------------------------------------------------------
# hypothesis-free: seeded random walk (runs on every install)
# --------------------------------------------------------------------------

def test_scheduler_random_walk_seeded():
    for seed in range(6):
        rnd = random.Random(seed)
        d = SchedDriver()
        for _ in range(120):
            op = rnd.random()
            if op < 0.35 and len(d.requests) < 25:
                d.submit(plen=rnd.randint(2, MAX_PROMPT),
                         max_new=rnd.randint(1, MAX_NEW),
                         priority=rnd.randint(0, 2),
                         seed=rnd.randint(0, 10**6))
            elif op < 0.42:
                d.inject_fault(rnd.randint(0, 30),
                               rnd.choice(["prefill", "decode"]))
            else:
                d.step()
            d.check_invariants()
        d.drain()
        d.check_invariants()
        d.check_drained()


def test_multi_slot_outputs_match_single_slot_engine():
    """The literal single-slot oracle: the same request set served by a
    batch_slots=1 engine, one at a time, produces identical streams."""
    rng = np.random.default_rng(11)
    specs = [(int(rng.integers(2, MAX_PROMPT + 1)),
              int(rng.integers(1, MAX_NEW + 1))) for _ in range(8)]
    prompts = [rng.integers(0, 500, p).astype(np.int32)
               for p, _ in specs]

    def serve(slots):
        eng = ServingEngine(None, None, None, batch_slots=slots,
                            max_seq=MAX_SEQ, page_tokens=PAGE_TOKENS,
                            executor=StubExecutor(batch_slots=slots,
                                                  max_seq=MAX_SEQ))
        reqs = [Request(prompt=p.copy(), max_new_tokens=m)
                for p, (_, m) in zip(prompts, specs)]
        pending = list(reqs)
        k = 0
        while pending or eng.scheduler_stats["waiting"] or \
                eng.scheduler_stats["running"]:
            # stagger arrivals differently per width
            if pending and k % (slots + 1) != 0:
                eng.submit(pending.pop(0))
            k += 1
            eng.step()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    assert serve(3) == serve(1)


def test_preemption_pressure_walk_never_drops():
    """Tight budget + long requests: heavy preemption churn must retire
    every request with oracle-exact streams and zero page leaks."""
    rnd = random.Random(99)
    d = SchedDriver(budget_pages=8)     # 32 tokens for 2 slots
    for _ in range(10):
        d.submit(plen=rnd.randint(4, MAX_PROMPT),
                 max_new=rnd.randint(10, 18),
                 priority=rnd.randint(0, 1),
                 seed=rnd.randint(0, 10**6))
    d.drain()
    d.check_drained()
    assert d.eng.scheduler_stats["preemptions"] >= 1
    assert all(r.done for r in d.requests)


# --------------------------------------------------------------------------
# hypothesis state machine (minimized counterexamples where available)
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    class SchedulerMachine(RuleBasedStateMachine):
        @initialize()
        def setup(self):
            self.d = SchedDriver()

        @rule(plen=st.integers(2, MAX_PROMPT),
              max_new=st.integers(1, MAX_NEW),
              priority=st.integers(0, 2),
              seed=st.integers(0, 10**6))
        def submit(self, plen, max_new, priority, seed):
            if len(self.d.requests) < 40:
                self.d.submit(plen, max_new, priority, seed)

        @rule()
        def step(self):
            self.d.step()

        @rule(n=st.integers(2, 5))
        def step_many(self, n):
            for _ in range(n):
                self.d.step()

        @rule(idx=st.integers(0, 50),
              stage=st.sampled_from(["prefill", "decode"]))
        def chaos(self, idx, stage):
            self.d.inject_fault(idx, stage)

        @invariant()
        def invariants(self):
            if hasattr(self, "d"):
                self.d.check_invariants()

        def teardown(self):
            if hasattr(self, "d"):
                self.d.drain()
                self.d.check_invariants()
                self.d.check_drained()

    TestSchedulerMachine = SchedulerMachine.TestCase
