"""Roofline extraction: HLO collective/convert parsers, flop models,
ideal-byte accounting, and the rederive path."""

import pytest

from repro import configs
from repro.launch import roofline as RL
from repro.models.config import TRAIN_4K, PREFILL_32K, DECODE_32K


HLO = """
HloModule test
%fused (p: bf16[8,128]) -> f32[8,128] {
  %p = bf16[8,128]{1,0} parameter(0)
  %convert.1 = f32[8,128]{1,0} convert(%p)
}
ENTRY %main {
  %x = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
  %ag = bf16[16,512]{1,0} all-gather(%y), dimensions={0}
  %aa = f32[64,32]{1,0} all-to-all(%z)
  %cp = f32[128]{0} collective-permute(%w)
  %rs = f32[256]{0} reduce-scatter(%v)
  %notacoll = f32[999]{0} add(%x, %x)
}
"""


def test_collective_bytes_parser():
    out = RL.collective_bytes(HLO)
    assert out["bytes"]["all-reduce"] == 1024 * 4
    assert out["bytes"]["all-gather"] == 16 * 512 * 2
    assert out["bytes"]["all-to-all"] == 64 * 32 * 4
    assert out["bytes"]["collective-permute"] == 128 * 4
    assert out["bytes"]["reduce-scatter"] == 256 * 4
    assert out["count"]["all-reduce"] == 1
    assert out["total_bytes"] == sum(out["bytes"].values())


def test_cpu_upconvert_parser():
    n = RL.cpu_upconvert_bytes(HLO)
    # one bf16->f32 convert of 8*128 elems, x4 bytes, x2 (write+read)
    assert n == 8 * 128 * 4 * 2


def test_model_flops_scaling():
    cfg = configs.get_config("internlm2-20b")
    na = RL.active_params(cfg)
    assert na > 19e9
    train = RL.model_flops(cfg, TRAIN_4K, na, "train")
    prefill = RL.model_flops(cfg, PREFILL_32K, na, "prefill")
    decode = RL.model_flops(cfg, DECODE_32K, na, "decode")
    # train is 3x the fwd flops of the same token count + attention terms
    assert train > 3 * 6.0 * na * 1e5
    assert decode < prefill < train * 10
    # remat adds about a third for block-remat configs
    ex = RL.executed_flops(cfg, TRAIN_4K, na)
    assert 1.25 < ex / train < 1.45


def test_moe_active_params_smaller_than_total():
    cfg = configs.get_config("phi3.5-moe-42b-a6.6b")
    from repro.models import model_defs
    from repro.models.params import count_params
    total = count_params(model_defs(cfg))
    active = RL.active_params(cfg)
    assert active < 0.3 * total          # 2 of 16 experts active
    assert total > 40e9 and active < 8e9


def test_ideal_bytes_decode_includes_cache():
    cfg = configs.get_config("granite-34b")
    dec = RL.ideal_bytes(cfg, DECODE_32K, 256)
    pre = RL.ideal_bytes(cfg, PREFILL_32K, 256)
    assert dec > cfg.n_layers * 2 * DECODE_32K.seq_len \
        * cfg.n_kv * cfg.hd * 2 * DECODE_32K.global_batch / 256


def test_report_roundtrip_and_dominance():
    cfg = configs.get_config("smollm-135m")
    rep = RL.build_report(arch="smollm-135m", shape=TRAIN_4K,
                          mesh_name="t", chips=256,
                          cost={"flops": 1e12, "bytes accessed": 1e9},
                          mem_bytes=1e9, hlo_text=HLO, cfg=cfg)
    d = rep.to_dict()
    assert d["dominant"] in ("compute", "memory", "collective")
    assert 0 <= d["roofline_fraction"] <= 1.5
    assert d["hlo_gbytes_adj"] <= d["hlo_gbytes"] + 1e-9

    from repro.launch.rederive import rederive
    d2 = rederive(dict(d))
    assert d2["dominant"] == d["dominant"]
    assert d2["roofline_fraction"] == pytest.approx(
        d["roofline_fraction"], rel=1e-6)
