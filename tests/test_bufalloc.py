"""Bufalloc property tests (paper §3): chunked first-fit allocator with
greedy mode — invariants under random alloc/free interleavings."""

import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.runtime.bufalloc import Bufalloc, OutOfMemory


def test_basic_alloc_free():
    a = Bufalloc(1024, alignment=64)
    c1 = a.alloc(100)
    c2 = a.alloc(200)
    assert c1.start % 64 == 0 and c2.start % 64 == 0
    assert c2.start >= c1.start + 100
    a.free(c1)
    a.free(c2)
    assert a.allocated_bytes() == 0
    assert a.largest_free() == 1024


def test_first_fit_reuses_freed_hole():
    a = Bufalloc(1024, alignment=1)
    c1 = a.alloc(128)
    c2 = a.alloc(128)
    a.free(c1)
    c3 = a.alloc(64)            # first fit -> the hole at offset 0
    assert c3.start == 0
    a.free(c2)
    a.free(c3)


def test_out_of_memory():
    a = Bufalloc(256, alignment=1)
    a.alloc(200)
    with pytest.raises(OutOfMemory):
        a.alloc(100)


def test_group_alloc_contiguous_in_greedy_mode():
    """Paper: greedy mode serves successive kernel-argument allocations
    from the region tail so buffer groups land contiguously."""
    a = Bufalloc(4096, alignment=1, greedy=True)
    hole_maker = a.alloc(64)
    filler = a.alloc(64)
    a.free(hole_maker)          # leave a hole at the front
    group = a.alloc_group([128, 128, 128])
    starts = sorted(c.start for c in group)
    assert starts[1] == starts[0] + 128 and starts[2] == starts[1] + 128
    a.free_group(group)
    a.free(filler)


def test_coalescing():
    a = Bufalloc(1024, alignment=1)
    cs = [a.alloc(100) for _ in range(5)]
    for c in cs:
        a.free(c)
    assert a.largest_free() == 1024      # all holes merged
    assert a.fragmentation() == 0.0


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 300)),
                min_size=1, max_size=60),
       st.booleans())
def test_allocator_invariants(ops, greedy):
    """Random alloc/free sequences: chunks never overlap, stay in-region,
    accounting adds up, and the internal chunk list stays consistent."""
    a = Bufalloc(8192, alignment=16, greedy=greedy)
    live = []
    for do_alloc, size in ops:
        if do_alloc or not live:
            try:
                c = a.alloc(size)
            except OutOfMemory:
                continue
            assert c.start % 16 == 0
            assert c.start + size <= 8192
            live.append((c, size))
        else:
            c, _ = live.pop(np.random.default_rng(size).integers(len(live)))
            a.free(c)
        # no two live chunks overlap
        spans = sorted((c.start, c.start + s) for c, s in live)
        for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
            assert e1 <= s2, "overlapping chunks"
        a.check_invariants()
    for c, _ in live:
        a.free(c)
    assert a.allocated_bytes() == 0
