"""Training stack: optimizer math, microbatch equivalence, checkpoint
restart, failure injection, and actual loss descent on the copy task."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data import synth_batch, data_iterator
from repro.distributed.sharding import BASELINE_RULES
from repro.training import (
    OptimizerConfig, TrainConfig, Trainer, adamw_update, init_opt_state, lr_schedule, make_train_step, init_state, abstract_state, checkpoint)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.int32(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1e-3,
                                                                   rel=1e-5)
    end = float(lr_schedule(cfg, jnp.int32(100)))
    assert end == pytest.approx(1e-4, rel=1e-4)


def test_adamw_moves_toward_gradient():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=10,
                          weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    opt = init_opt_state(params)
    new_p, new_opt, m = adamw_update(cfg, params, grads, opt, jnp.int32(0))
    assert float(new_p["w"][0, 0]) < 1.0
    assert float(m["grad_norm"]) == pytest.approx(4.0)


def test_nonfinite_grads_skipped():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((2,))}
    grads = {"w": jnp.asarray([jnp.nan, 1.0])}
    opt = init_opt_state(params)
    new_p, _, m = adamw_update(cfg, params, grads, opt, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0)
    assert float(m["nonfinite"]) == 1.0


def test_microbatch_equivalence():
    """nmb=2 grad accumulation must match nmb=1 up to accumulation dtype."""
    cfg = configs.get_smoke("smollm-135m")
    batch = synth_batch(cfg, 8, 32, step=0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    opt = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1 = init_state(cfg, jax.random.PRNGKey(0))
    s2 = jax.tree.map(lambda x: x.copy(), s1)
    step1 = make_train_step(cfg, BASELINE_RULES,
                            TrainConfig(num_microbatches=1, opt=opt))
    step2 = make_train_step(cfg, BASELINE_RULES,
                            TrainConfig(num_microbatches=2, opt=opt))
    s1n, m1 = jax.jit(step1)(s1, batch)
    s2n, m2 = jax.jit(step2)(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1n["params"], s2n["params"])
    # bf16 forward rounding differs per microbatch split; Adam at step 0
    # turns any sign flip into a full +/-lr step, so the bound is ~2*lr
    assert max(jax.tree.leaves(d)) < 2.5 * 1e-3


def test_loss_decreases_on_copy_task(tmp_path):
    cfg = configs.get_smoke("smollm-135m")
    tcfg = TrainConfig(num_microbatches=1, ckpt_dir=None, log_every=1,
                       opt=OptimizerConfig(lr=3e-3, warmup_steps=5,
                                           total_steps=60))
    tr = Trainer(cfg, BASELINE_RULES, tcfg)
    tr.init(0)
    hist = tr.run(data_iterator(cfg, 8, 32), 40)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first - 0.2, (first, last)


def test_checkpoint_roundtrip(tmp_path):
    cfg = configs.get_smoke("whisper-small")
    state = init_state(cfg, jax.random.PRNGKey(0))
    state["step"] = jnp.int32(7)
    path = str(tmp_path / "ck")
    checkpoint.save(path, state)
    restored = checkpoint.restore_latest(path, abstract_state(cfg))
    assert int(restored["step"]) == 7
    a = jax.tree.leaves(state["params"])
    b = jax.tree.leaves(restored["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_gc_keeps_latest(tmp_path):
    cfg = configs.get_smoke("smollm-135m")
    state = init_state(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        state["step"] = jnp.int32(s)
        checkpoint.save(path, state, keep=2)
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_failure_injection_and_restart(tmp_path):
    """Simulated node failure mid-run; a fresh Trainer restores from the
    last checkpoint and continues — the fault-tolerance path."""
    cfg = configs.get_smoke("smollm-135m")
    path = str(tmp_path / "ck")
    tcfg = TrainConfig(ckpt_dir=path, ckpt_every=3, log_every=100,
                       opt=OptimizerConfig(lr=1e-3, warmup_steps=0,
                                           total_steps=50))

    class Boom(RuntimeError):
        pass

    def failure(step):
        if step == 7:
            raise Boom("node lost")

    tr = Trainer(cfg, BASELINE_RULES, tcfg)
    tr.init(0)
    with pytest.raises(Boom):
        tr.run(data_iterator(cfg, 4, 16), 20, failure_hook=failure)

    tr2 = Trainer(cfg, BASELINE_RULES, tcfg)
    resumed_at = tr2.init(0)
    assert resumed_at == 6                      # last ckpt before the crash
    hist = tr2.run(data_iterator(cfg, 4, 16, start_step=resumed_at), 4)
    assert np.isfinite(hist[-1]["loss"])


def test_elastic_restore_under_new_mesh_shape():
    """Checkpoints are mesh-independent numpy trees: a restore into a
    freshly-built state (different device layout) must bit-match."""
    cfg = configs.get_smoke("smollm-135m")
    state = init_state(cfg, jax.random.PRNGKey(5))
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, state)
        restored = checkpoint.restore_latest(d, abstract_state(cfg))
    x = jax.tree.leaves(state["opt"]["m"])[0]
    y = jax.tree.leaves(restored["opt"]["m"])[0]
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
