"""Stateful property-test harness for the N-device adaptive scheduler.

The adaptive co-execution path (docs/runtime.md §Scheduler) composes a
per-device :class:`~repro.runtime.scheduler.ThroughputModel` (EWMA of
groups/sec off the event profiling counters) with an HGuided
:class:`~repro.runtime.scheduler.AdaptiveSplitter` (geometrically
shrinking chunks proportional to modeled speed, straggler stealing when
the frontier drains).  This harness locks down its invariants:

* **exactly-once assignment** — the fresh (non-stolen) spans the
  splitter dispenses partition ``[0, n_groups)`` contiguously, with no
  gap and no overlap, for every device count / speed vector / trace;
* **coverage** — the launch finishes exactly when completed spans first
  cover the range, and a span is duplicated only by an explicit steal;
* **weights stay normalized and finite** — under arbitrary observation
  traces, including zero/negative/NaN durations and mid-run speed
  changes;
* **a stalled device never strands work** — tail chunks get stolen, so
  completion time is bounded by the healthy devices, not the stall;
* **merge is bitwise-identical to single-device** — for real launches
  over lopsided simulated platforms, every interleaving.

The scheduling logic is simulated in *virtual time* by
:class:`SplitDriver` (no real devices, threads, or sleeps), which needs
no hypothesis — seeded random-walk tests drive it on every install, and
a hypothesis ``RuleBasedStateMachine`` (under the ``ci``/``dev``
profiles from tests/conftest.py) adds minimized counterexamples.  Real
:class:`~repro.runtime.scheduler.CoExecutor` launches over
:class:`~repro.runtime.platform.ThrottledDevice` platforms then pin the
end-to-end behaviour: bitwise identity, one plan build across N
heterogeneous devices, stats consistency with the event timeline, and
warm-table convergence within two launches (acceptance criteria).
"""

import math
import random

import numpy as np
import pytest

from repro.core import KernelBuilder
from repro.core.autotune import TuningTable
from repro.runtime import (AdaptiveSplitter, Context, DeviceInfo,
                           InvalidArgError, ThrottledDevice,
                           ThroughputModel, chunk_counters, device_class)

try:
    from hypothesis import given, strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:               # plain tests below still run
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# SplitDriver: virtual-time simulation of one adaptive launch
# ---------------------------------------------------------------------------

class SplitDriver:
    """Simulates the co-executor's adaptive dispatch loop in virtual
    time: symbolic devices with true speeds (groups/sec), one in-flight
    chunk per device, completion-ordered event callbacks, optional
    one-shot stalls and mid-run speed changes.  Mirrors
    ``CoExecutor._co_run``'s adaptive mode exactly — dispatch one chunk
    per device, then on each (virtual) completion observe throughput and
    dispatch the next chunk for that device until completed spans cover
    the range — so its invariants are the scheduler's invariants."""

    def __init__(self, speeds, n_groups, min_chunk=1, divisor=2.0,
                 alpha=0.5, seed_weights=None):
        self.devices = [f"dev{i}" for i in range(len(speeds))]
        self.speed = dict(zip(self.devices, [float(s) for s in speeds]))
        self.model = ThroughputModel(alpha=alpha)
        if seed_weights is not None:
            for d, w in zip(self.devices, seed_weights):
                self.model.seed(d, w)
        self.split = AdaptiveSplitter(n_groups, self.devices, self.model,
                                      min_chunk=min_chunk, divisor=divisor)
        self.n_groups = int(n_groups)
        self.stalls = {d: 0.0 for d in self.devices}
        self.fresh_spans = []        # (device, span) in dispense order
        self.steal_spans = []        # (device, span)
        self.completions = []        # (device, span, t_end)
        self.finished_at = None
        self.weight_checks = 0

    def add_stall(self, device, seconds):
        self.stalls[device] += float(seconds)

    def set_speed(self, device, speed):
        self.speed[device] = float(speed)

    def _check_weights(self):
        w = self.model.weights(self.devices)
        assert len(w) == len(self.devices)
        assert all(math.isfinite(x) and x > 0 for x in w), \
            f"weights not finite/positive: {w}"
        assert abs(sum(w) - 1.0) < 1e-9, f"weights not normalized: {w}"
        self.weight_checks += 1

    def _dispatch(self, device, now, active):
        steals_before = self.split.steals[device]
        span = self.split.next_chunk(device)
        if span is None:
            return
        if self.split.steals[device] > steals_before:
            self.steal_spans.append((device, span))
        else:
            self.fresh_spans.append((device, span))
        stall = self.stalls[device]
        self.stalls[device] = 0.0
        dur = stall + (span[1] - span[0]) / self.speed[device]
        active[device] = (span, now, now + dur)

    def run(self, max_events=100000):
        active = {}
        for d in self.devices:
            self._dispatch(d, 0.0, active)
        events = 0
        while active:
            events += 1
            assert events < max_events, "scheduler failed to terminate"
            d = min(active, key=lambda k: active[k][2])
            span, t0, t1 = active.pop(d)
            # the real path feeds the event's RUNNING->end window, which
            # includes any stall charged inside the chunk
            self.model.observe(d, span[1] - span[0], t1 - t0)
            self._check_weights()
            finished = self.split.complete(d, span)
            self.completions.append((d, span, t1))
            if finished:
                self.finished_at = t1
            if self.finished_at is None:
                self._dispatch(d, t1, active)
        self.check_invariants()
        return self

    def check_invariants(self):
        # fresh spans partition [0, n_groups): contiguous, no overlap
        spans = sorted(s for _, s in self.fresh_spans)
        if self.n_groups == 0:
            assert spans == []
            assert self.split.finished
            return
        assert spans[0][0] == 0
        assert spans[-1][1] == self.n_groups
        for (_, e0), (s1, _) in zip(spans, spans[1:]):
            assert e0 == s1, f"gap or overlap in fresh spans: {spans}"
        assert all(b > a for a, b in spans), "empty span dispensed"
        # the launch finished, and exactly when coverage completed
        assert self.finished_at is not None
        assert self.split.finished
        # duplicates only via explicit steals, at most one per span
        fresh = [s for _, s in self.fresh_spans]
        for d, s in self.steal_spans:
            assert s in fresh, "stole a span that was never dispensed"
            owner = [dd for dd, ss in self.fresh_spans if ss == s]
            assert owner and owner[0] != d, "device stole its own span"
        assert len(set(self.steal_spans)) == len(self.steal_spans)
        # splitter accounting matches the trace
        for d in self.devices:
            mine = [s for dd, s in self.fresh_spans + self.steal_spans
                    if dd == d]
            assert self.split.chunks[d] == len(mine)
            assert self.split.dispensed[d] == \
                sum(b - a for a, b in mine)
            assert self.split.steals[d] == \
                len([1 for dd, _ in self.steal_spans if dd == d])
        self._check_weights()


def _rand_driver(rng, **overrides):
    n_dev = overrides.pop("n_dev", rng.randint(1, 6))
    speeds = overrides.pop(
        "speeds", [10 ** rng.uniform(-1.5, 1.5) for _ in range(n_dev)])
    kw = dict(n_groups=rng.randint(0, 200),
              min_chunk=rng.randint(1, 8),
              divisor=rng.uniform(1.0, 4.0),
              alpha=rng.uniform(0.1, 1.0))
    kw.update(overrides)
    return SplitDriver(speeds, **kw)


# ---------------------------------------------------------------------------
# seeded random walks (run on every install, no hypothesis needed)
# ---------------------------------------------------------------------------

def test_split_driver_random_walks():
    """Random device counts, speed vectors, chunk knobs, stalls, and
    mid-run speed changes: every trace upholds the invariants."""
    rng = random.Random(0xC0E3EC)
    for _ in range(150):
        drv = _rand_driver(rng)
        # random one-shot stalls and mid-run speed changes
        for d in drv.devices:
            if rng.random() < 0.3:
                drv.add_stall(d, rng.uniform(0.0, 50.0))
        if rng.random() < 0.5 and drv.devices:
            drv.set_speed(rng.choice(drv.devices),
                          10 ** rng.uniform(-1.5, 1.5))
        drv.run()


def test_stalled_device_never_strands_work():
    """One device stalls for ~forever; the others finish the whole range
    (tail chunks stolen) in time bounded by their own speed, not by the
    stall."""
    for seed in range(5):
        rng = random.Random(seed)
        stall = 1e6
        drv = _rand_driver(rng, n_dev=3, speeds=[100.0, 100.0, 50.0],
                           n_groups=rng.randint(30, 120))
        drv.add_stall(drv.devices[2], stall)
        drv.run()
        # two healthy devices at 100 groups/s: generous bound, still
        # orders of magnitude under the stall
        assert drv.finished_at < drv.n_groups / 100.0 + 1.0
        assert drv.finished_at < stall / 100
        stolen = [s for d, s in drv.steal_spans]
        assert stolen, "stalled device's in-flight span was never stolen"


def test_weights_converge_to_speed_ratio():
    """Stationary speeds: after one launch the modeled split tracks the
    true speed ratio (the HGuided premise)."""
    drv = SplitDriver([100.0, 100.0, 20.0], n_groups=400, min_chunk=2)
    drv.run()
    w = drv.model.weights(drv.devices)
    ideal = [100 / 220, 100 / 220, 20 / 220]
    for got, want in zip(w, ideal):
        assert abs(got - want) < 0.12, (w, ideal)


def test_throughput_model_degenerate_observations():
    """Zero/negative/NaN/inf durations and group counts never corrupt
    the model: rejected samples change nothing, weights stay a finite
    distribution."""
    m = ThroughputModel(alpha=0.5)
    devs = ["a", "b"]
    assert m.observe("a", 10, 0.1)
    baseline = m.weights(devs)
    for groups, seconds in [(0, 1.0), (-5, 1.0), (10, 0.0), (10, -1.0),
                            (float("nan"), 1.0), (10, float("nan")),
                            (10, float("inf")), (None, 1.0), (10, "x")]:
        assert not m.observe("a", groups, seconds)
        assert not m.observe("b", groups, seconds)
    assert m.weights(devs) == baseline
    assert m.rate("b") is None
    # invalid seeds are rejected too
    for bad in (0.0, -1.0, float("nan"), float("inf"), None, "x"):
        assert not m.seed("b", bad)
    w = m.weights(devs)
    assert abs(sum(w) - 1.0) < 1e-9 and all(x > 0 for x in w)
    with pytest.raises(InvalidArgError):
        ThroughputModel(alpha=0.0)
    with pytest.raises(InvalidArgError):
        ThroughputModel(alpha=1.5)


def test_throughput_model_seed_replaced_by_first_measurement():
    """A warm-start seed (a relative share, arbitrary scale) must be
    *replaced* by the first real groups/sec measurement, not blended
    across scales."""
    m = ThroughputModel(alpha=0.5)
    assert m.seed("a", 0.9)
    assert m.seed("b", 0.1)
    assert m.weights(["a", "b"])[0] == pytest.approx(0.9)
    m.observe("a", 100, 1.0)           # 100 g/s, replaces the 0.9 seed
    assert m.rate("a") == pytest.approx(100.0)
    m.observe("a", 200, 1.0)           # now EWMA: 0.5*200 + 0.5*100
    assert m.rate("a") == pytest.approx(150.0)
    # a seed never overwrites a measured rate
    assert not m.seed("a", 5.0)
    assert m.rate("a") == pytest.approx(150.0)


def test_adaptive_splitter_basics():
    m = ThroughputModel()
    s = AdaptiveSplitter(10, ["a", "b"], m, min_chunk=1, divisor=2.0)
    # equal cold weights: chunk = ceil(remaining * 0.5 / 2)
    assert s.next_chunk("a") == (0, 3)       # ceil(10 * .5 / 2)
    assert s.next_chunk("b") == (3, 5)       # ceil(7 * .5 / 2)
    # drain the rest of the frontier via a: geometric shrink to min_chunk
    spans = [(0, 3), (3, 5)]
    while spans[-1][1] < 10:                 # stop at coverage: no steal
        spans.append(s.next_chunk("a"))
    # fresh spans partition [0, 10) contiguously
    assert spans[-1][1] == 10
    assert all(e0 == s1 for (_, e0), (s1, _) in zip(spans, spans[1:]))
    # completion fires True exactly once, on first full coverage
    fired = [sp for sp in spans if s.complete("a", sp)]
    assert fired == [spans[-1]] and s.finished
    # accounting: every dispensed group attributed, no steals yet
    assert s.dispensed["a"] + s.dispensed["b"] == 10
    assert s.steals == {"a": 0, "b": 0}
    # empty range is born finished
    assert AdaptiveSplitter(0, ["a"], m).finished
    with pytest.raises(InvalidArgError):
        AdaptiveSplitter(4, [], m)
    with pytest.raises(InvalidArgError):
        AdaptiveSplitter(4, ["a"], m, min_chunk=0)
    with pytest.raises(InvalidArgError):
        AdaptiveSplitter(4, ["a"], m, divisor=0.5)


def test_adaptive_splitter_steals_only_when_frontier_empty():
    m = ThroughputModel()
    s = AdaptiveSplitter(8, ["a", "b"], m, min_chunk=1, divisor=2.0)
    first = s.next_chunk("a")
    assert s.steals["a"] == 0 and s.steals["b"] == 0
    # drain the frontier with b
    while True:
        sp = s.next_chunk("b")
        if sp is None or s.steals["b"] > 0:
            break
    # b's last grab was a steal of a's in-flight span (frontier empty)
    assert s.steals["b"] == 1 and sp == first
    # no second duplicate of the same span
    assert s.next_chunk("b") is None
    # completing everything flips finished exactly once
    fired = 0
    for d, span in [("a", first)] + \
            [("b", x) for x in list(s.pending_spans())]:
        if s.complete(d, span):
            fired += 1
    assert s.finished and fired == 1


# ---------------------------------------------------------------------------
# real launches: lopsided simulated platforms (ThrottledDevice)
# ---------------------------------------------------------------------------

def build_scale():
    b = KernelBuilder("scale")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    y[g] = x[g] * 2.0 + g
    return b.finish()


def make_sim_device(i, seconds_per_group, cls):
    return ThrottledDevice(DeviceInfo(
        name=f"sim-{cls}-{i}", driver="vector",
        global_mem_size=1 << 30, local_mem_size=1 << 20,
        max_work_group_size=1024, compute_units=1),
        seconds_per_group=seconds_per_group, coexec_class=cls)


# simulated per-group costs must dominate the ~1ms per-chunk scheduling
# overhead, or the observed speed ratio compresses under host load and
# convergence assertions get noisy (same constants as bench_coexec)
FAST_S = 0.001
SLOW_S = 0.008


def lopsided_platform(fast_s=FAST_S, slow_s=SLOW_S):
    return [make_sim_device(0, fast_s, "fast"),
            make_sim_device(1, fast_s, "fast"),
            make_sim_device(2, slow_s, "slow")]


N = 96 * 16
LSZ = 16


def _kernel(ctx):
    prog = ctx.create_program(build_scale).build()
    k = prog.create_kernel("scale")
    k.set_args(x=np.arange(N, dtype=np.float32),
               y=np.zeros(N, np.float32))
    return k


def test_adaptive_bitwise_identical_every_interleaving():
    """Adaptive N-device launches — cold, converged, stalled (with
    steals), re-weighted — are all bitwise-identical to a single-device
    launch of the same kernel."""
    devs = lopsided_platform()
    ctx = Context(devices=devs)
    k = _kernel(ctx)
    co = ctx.create_co_executor(devs, tuning_table=TuningTable())

    ref_dev = make_sim_device(9, 0.0, "ref")
    ref_ctx = Context(devices=[ref_dev])
    ref = ref_ctx.create_co_executor(
        [ref_dev], tuning_table=TuningTable()).launch(
            _kernel(ref_ctx), (N,), (LSZ,), mode="static")

    rng = random.Random(7)
    for i in range(6):
        if rng.random() < 0.5:
            devs[2].stall(rng.uniform(0.01, 0.08))
        out = co.launch(k, (N,), (LSZ,), mode="adaptive")
        assert out["y"].tobytes() == ref["y"].tobytes(), \
            f"launch {i} diverged bitwise from single-device"
        st = co.last_stats
        assert st.mode == "adaptive" and st.n_groups == N // LSZ
        w = st.weights
        assert abs(sum(w.values()) - 1.0) < 1e-9
        assert all(math.isfinite(x) and x > 0 for x in w.values())
    co.finish()


def test_one_plan_build_across_n_heterogeneous_devices():
    """N heterogeneous devices specialize one kernel through the
    context's shared plan tier: region formation runs once, not once
    per device."""
    devs = lopsided_platform()
    ctx = Context(devices=devs)
    k = _kernel(ctx)
    co = ctx.create_co_executor(devs, tuning_table=TuningTable())
    co.launch(k, (N,), (LSZ,), mode="adaptive")
    assert ctx.cache.stats.plan_builds == 1, \
        "shared plan tier must build the work-group plan exactly once"
    co.finish()


def test_coexec_stats_consistent_with_event_timeline():
    """Satellite: CoExecStats cross-checked against the event profile of
    a seeded 3-device adaptive run — per-device chunk counts equal the
    per-device kernel events, steal counts equal duplicated spans, and
    migration overlap is bounded by the transfer/kernel windows."""
    devs = lopsided_platform()
    ctx = Context(devices=devs)
    k = _kernel(ctx)
    co = ctx.create_co_executor(devs, tuning_table=TuningTable())
    devs[2].stall(0.05)                    # force at least one steal
    co.launch(k, (N,), (LSZ,), mode="adaptive")
    st = co.last_stats
    co.finish()                            # drain stragglers first

    rows = chunk_counters(st.events, kind="kernel")
    assert all(r["ok"] for r in rows)
    # event names carry device + span: co-adaptive:<device>:<lo>-<hi>
    by_dev, spans = {}, {}
    for r in rows:
        _, dev_name, span = str(r["name"]).split(":")
        lo, hi = map(int, span.split("-"))
        by_dev[dev_name] = by_dev.get(dev_name, 0) + 1
        spans.setdefault((lo, hi), []).append(dev_name)
    # chunk counts: every executed chunk event is counted, per device
    assert by_dev == st.chunks_per_device
    # groups: per device, the sum of its executed span lengths
    for name, count in st.groups_per_device.items():
        got = sum(hi - lo for (lo, hi), ds in spans.items()
                  for d in ds if d == name)
        assert got == count, (name, got, count)
    # spans executed by >1 device are exactly the steals
    dup = sum(len(ds) - 1 for ds in spans.values())
    assert dup == sum(st.steals_per_device.values())
    assert dup >= 1, "the stalled device's span should have been stolen"
    # every group covered: union of executed spans is [0, n_groups)
    merged = []
    for lo, hi in sorted(spans):
        if merged and lo <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
        else:
            merged.append((lo, hi))
    assert merged == [(0, st.n_groups)]
    # migration overlap: non-negative, bounded by total transfer time
    overlap = st.migration_overlap_s()
    total_transfer = sum(r["duration_s"] for r in
                         chunk_counters(st.transfer_events))
    assert 0.0 <= overlap <= total_transfer + 1e-9
    assert st.migrations == 6, "2 buffers x 3 devices, copied once each"


def test_warm_tuning_table_converges_within_two_launches():
    """Acceptance: a fresh executor warm-started from a persisted
    TuningTable reaches the converged lopsided split within 2 launches
    — its very first split already avoids overloading the slow device."""
    table = TuningTable()
    devs = lopsided_platform()
    ctx = Context(devices=devs)
    k = _kernel(ctx)
    co = ctx.create_co_executor(devs, tuning_table=table)
    # one untimed static launch warms each device's jit trace: the
    # one-shot trace cost otherwise lands inside the first chunk's event
    # window and poisons the first throughput observation (which
    # *replaces* the seed) — compile cost is not execution speed
    co.launch(k, (N,), (LSZ,), mode="static")
    for _ in range(4):                      # converge + persist
        co.launch(k, (N,), (LSZ,), mode="adaptive")
    co.finish()
    key = TuningTable.make_coexec_key(
        k.ir_hash, [device_class(d) for d in devs])
    ent = table.get_coexec(key)
    assert ent is not None and ent["launches"] == 4
    slow_share = ent["weights"]["slow"]
    assert slow_share < 0.25, f"persisted slow share too high: {ent}"

    # fresh executor, same table: warm from launch one
    devs2 = lopsided_platform()
    ctx2 = Context(devices=devs2)
    k2 = _kernel(ctx2)
    co2 = ctx2.create_co_executor(devs2, tuning_table=table)
    co2.launch(k2, (N,), (LSZ,), mode="static")    # jit-trace warm-up
    for launch in range(2):
        co2.launch(k2, (N,), (LSZ,), mode="adaptive")
        st = co2.last_stats
    co2.finish()
    slow_name = devs2[2].info.name
    # converged: slow's modeled share is lopsided (true speed ratio is
    # ~0.06), nowhere near the cold-start equal third
    assert st.weights[slow_name] < 0.2, \
        f"warm run failed to converge within 2 launches: {st.weights}"
    # and the slow device executed far less than an equal share
    slow_groups = st.groups_per_device.get(slow_name, 0)
    assert slow_groups < st.n_groups / 2


# ---------------------------------------------------------------------------
# hypothesis layer: minimized traces + stateful machine
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(st.data())
    def test_split_driver_hypothesis_traces(data):
        n_dev = data.draw(st.integers(1, 5), label="n_dev")
        speeds = data.draw(st.lists(
            st.floats(0.05, 500.0, allow_nan=False, allow_infinity=False),
            min_size=n_dev, max_size=n_dev), label="speeds")
        n_groups = data.draw(st.integers(0, 150), label="n_groups")
        drv = SplitDriver(
            speeds, n_groups,
            min_chunk=data.draw(st.integers(1, 6), label="min_chunk"),
            divisor=data.draw(st.floats(1.0, 4.0), label="divisor"),
            alpha=data.draw(st.floats(0.05, 1.0), label="alpha"))
        for d in drv.devices:
            if data.draw(st.booleans(), label=f"stall?{d}"):
                drv.add_stall(d, data.draw(
                    st.floats(0.0, 100.0), label=f"stall{d}"))
        drv.run()

    class CoexecMachine(RuleBasedStateMachine):
        """Drives the splitter + model with an adversarial interleaving
        of dispenses, completions (any order), steals, and arbitrary —
        including degenerate — observations, checking the dispense
        partition, steal discipline, and weight normalization after
        every step."""

        @initialize(n_groups=st.integers(0, 120),
                    n_dev=st.integers(1, 4),
                    min_chunk=st.integers(1, 5))
        def setup(self, n_groups, n_dev, min_chunk):
            self.devices = [f"d{i}" for i in range(n_dev)]
            self.model = ThroughputModel(alpha=0.5)
            self.split = AdaptiveSplitter(
                n_groups, self.devices, self.model, min_chunk=min_chunk)
            self.n_groups = n_groups
            self.fresh = []
            self.stolen = []
            self.inflight = []

        def _dev(self, i):
            return self.devices[i % len(self.devices)]

        @rule(i=st.integers(0, 3))
        def dispense(self, i):
            d = self._dev(i)
            before = self.split.steals[d]
            span = self.split.next_chunk(d)
            if span is None:
                return
            if self.split.steals[d] > before:
                assert (d, span) not in self.stolen
                self.stolen.append((d, span))
                # steals only happen with the frontier drained
                assert sum(b - a for _, (a, b) in self.fresh) \
                    == self.n_groups
            else:
                self.fresh.append((d, span))
            self.inflight.append((d, span))

        @rule(i=st.integers(0, 3), j=st.integers(0, 200))
        def complete_one(self, i, j):
            if not self.inflight:
                return
            d, span = self.inflight.pop(j % len(self.inflight))
            was_finished = self.split.finished
            fired = self.split.complete(d, span)
            if fired:
                assert not was_finished, "finished fired twice"

        @rule(i=st.integers(0, 3),
              groups=st.one_of(st.integers(-5, 50),
                               st.floats(allow_nan=True)),
              seconds=st.one_of(st.floats(allow_nan=True),
                                st.floats(0.0001, 10.0)))
        def observe(self, i, groups, seconds):
            self.model.observe(self._dev(i), groups, seconds)

        @invariant()
        def weights_normalized_finite(self):
            if not hasattr(self, "model"):
                return
            w = self.model.weights(self.devices)
            assert all(math.isfinite(x) and x > 0 for x in w)
            assert abs(sum(w) - 1.0) < 1e-9

        @invariant()
        def fresh_spans_prefix_partition(self):
            if not hasattr(self, "split"):
                return
            spans = sorted(s for _, s in self.fresh)
            covered = 0
            for a, b in spans:
                assert a == covered, f"gap/overlap: {spans}"
                assert b > a
                covered = b
            assert covered <= self.n_groups

        @invariant()
        def finished_only_after_full_dispensation(self):
            if not hasattr(self, "split"):
                return
            if self.split.finished and self.n_groups:
                dispensed = {s for _, s in self.fresh}
                assert sum(b - a for a, b in dispensed) >= self.n_groups

    TestCoexecMachine = CoexecMachine.TestCase
