"""DAG-level kernel fusion tests (docs/runtime.md §Kernel fusion,
docs/compiler.md §Fusion).

Covers the fusion acceptance contract: a golden canonical-IR snapshot of
the stitched rmsnorm→residual→quantize chain, legality negatives (each
must leave the DAG unfused), bitwise identity of fused vs unfused
execution on all three targets and under 1-vs-2-device co-execution,
intermediate-buffer elision (lazy pooled intermediates never
materialize), fused-tier caching (``plan_builds`` stable after the first
launch), event identity/profiling mirroring, the ``REPRO_FUSE=0``
kill-switch, and ``dag_stats()`` accounting.

Regenerate the golden after intentional stitcher changes:

  REPRO_UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_fusion.py
"""

import os

import numpy as np
import pytest

from repro.core import canonical_ir, ir_hash
from repro.core.cache import CompilationCache
from repro.core.examples import (build_quantize, build_residual_add,
                                 build_rmsnorm_ew)
from repro.core.fusion import (ChainEdge, FusionError, build_fused_spec,
                               fusible_kernel, stitch_functions)
from repro.core.passes import kernel_fusibility
from repro.runtime.context import Context

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

N = 256
LSZ = (64,)

CHAIN_EDGES = [ChainEdge(0, 1, "y", "y", True),
               ChainEdge(1, 2, "z", "z", True)]
CHAIN_ALIASES = [[(0, "y"), (1, "y")], [(1, "z"), (2, "z")]]
CHAIN_BUILDERS = [build_rmsnorm_ew, build_residual_add, build_quantize]


def _host_inputs(n=N, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n).astype(np.float32),
            rng.standard_normal(n).astype(np.float32),
            rng.standard_normal(n).astype(np.float32))


def _run_chain(ctx, fusion, n=N, target=None, queue_kwargs=None,
               return_queue=False):
    """Enqueue the rmsnorm→residual→quantize chain on a fresh queue and
    return (q_result, y, z, queue-or-None)."""
    xh, wh, rh = _host_inputs(n)
    dev = ctx.devices[0]
    prog = ctx.create_program(*CHAIN_BUILDERS)
    bufs = {nm: ctx.create_buffer(n) for nm in "xwryzq"}
    queue = ctx.create_queue(dev, fusion=fusion, **(queue_kwargs or {}))
    queue.enqueue_write_buffer(bufs["x"], xh)
    queue.enqueue_write_buffer(bufs["w"], wh)
    queue.enqueue_write_buffer(bufs["r"], rh)
    k1 = prog.create_kernel("rmsnorm_ew")
    k1.set_args(x=bufs["x"], w=bufs["w"], y=bufs["y"], inv_rms=0.5)
    k2 = prog.create_kernel("residual_add")
    k2.set_args(y=bufs["y"], r=bufs["r"], z=bufs["z"])
    k3 = prog.create_kernel("quantize")
    k3.set_args(z=bufs["z"], q=bufs["q"], scale=16.0)
    events = [queue.enqueue_nd_range(k, (n,), LSZ, target=target)
              for k in (k1, k2, k3)]
    queue.finish()
    out = np.array(bufs["q"].data)
    if return_queue:
        return out, bufs, events, queue
    return out, bufs, events, None


# --------------------------------------------------------------------------
# fusibility facts (core/passes.py)
# --------------------------------------------------------------------------

def test_chain_kernels_are_elementwise():
    for build in CHAIN_BUILDERS:
        facts = kernel_fusibility(build())
        assert facts.elementwise, facts.reasons
        assert fusible_kernel(build())
        for fp in facts.footprints:
            assert fp.gid_only


def test_non_elementwise_kernels_are_rejected():
    from repro.core.examples import build_condbar, build_dct, build_reduce2
    for build, why in ((build_reduce2, "barrier+loop+local"),
                       (build_condbar, "user barrier"),
                       (build_dct, "loop")):
        facts = kernel_fusibility(build())
        assert not facts.elementwise, why
        assert facts.reasons, why


def test_footprints_count_loads_and_stores():
    facts = kernel_fusibility(build_rmsnorm_ew())
    y = facts.footprint("y")
    assert y.stores == 1 and y.loads == 0
    x = facts.footprint("x")
    assert x.loads == 1 and x.stores == 0
    assert facts.footprint("nope") is None


# --------------------------------------------------------------------------
# IR stitching (core/fusion.py) + golden snapshot
# --------------------------------------------------------------------------

def test_golden_stitched_chain_ir():
    fused, _, _ = stitch_functions([b() for b in CHAIN_BUILDERS],
                                   CHAIN_EDGES, CHAIN_ALIASES)
    got = canonical_ir(fused) + "\n"
    path = os.path.join(GOLDEN_DIR, "fused_chain.txt")
    if os.environ.get("REPRO_UPDATE_GOLDEN"):
        with open(path, "w") as f:
            f.write(got)
        pytest.skip(f"golden updated: {path}")
    assert os.path.exists(path), \
        f"golden file missing; run with REPRO_UPDATE_GOLDEN=1 ({path})"
    with open(path) as f:
        want = f.read()
    assert got == want, (
        "stitched-chain canonical IR drifted; if the stitcher change is "
        "intentional, regenerate with REPRO_UPDATE_GOLDEN=1")


def test_stitch_is_deterministic():
    f1, _, _ = stitch_functions([b() for b in CHAIN_BUILDERS],
                                CHAIN_EDGES, CHAIN_ALIASES)
    f2, _, _ = stitch_functions([b() for b in CHAIN_BUILDERS],
                                CHAIN_EDGES, CHAIN_ALIASES)
    assert ir_hash(f1) == ir_hash(f2)


def test_stitch_elides_intermediate_params_and_stores():
    fused, bmap, smap = stitch_functions([b() for b in CHAIN_BUILDERS],
                                         CHAIN_EDGES, CHAIN_ALIASES)
    names = [a.name for a in fused.buffer_args]
    # elided intermediates are gone from the signature
    assert names == ["k0_x", "k0_w", "k1_r", "k2_q"]
    # exactly one store remains (the final output)
    stores = [i for blk in fused.blocks.values() for i in blk.instrs
              if i.op == "store"]
    assert len(stores) == 1 and stores[0].attrs["buffer"] == "k2_q"
    assert (0, "y") not in bmap and (1, "z") not in bmap
    assert smap == {(0, "inv_rms"): "k0_inv_rms", (2, "scale"): "k2_scale"}


def test_stitch_keeps_store_for_non_elided_edge():
    edges = [ChainEdge(0, 1, "y", "y", False)]
    fused, bmap, _ = stitch_functions(
        [build_rmsnorm_ew(), build_residual_add()], edges,
        [[(0, "y"), (1, "y")]])
    assert (0, "y") in bmap        # still a fused parameter
    stores = [i.attrs["buffer"] for blk in fused.blocks.values()
              for i in blk.instrs if i.op == "store"]
    assert sorted(stores) == ["k0_y", "k1_z"]


def test_stitch_rejects_non_elementwise_segment():
    from repro.core.examples import build_reduce2
    with pytest.raises(FusionError):
        stitch_functions([build_rmsnorm_ew(), build_reduce2()],
                         [ChainEdge(0, 1, "y", "inp", False)],
                         [[(0, "y"), (1, "inp")]])


def test_fused_spec_caches_by_topology():
    cache = CompilationCache()
    args = (CHAIN_BUILDERS, ["a", "b", "c"], CHAIN_EDGES, CHAIN_ALIASES)
    s1 = build_fused_spec(*args, cache=cache)
    s2 = build_fused_spec(*args, cache=cache)
    assert s1 is s2
    assert cache.stats.fused_builds == 1
    assert cache.stats.fused_hits == 1
    assert cache.fused_cache_size() == 1
    # a different topology (no elision) is a distinct entry
    edges2 = [ChainEdge(e.producer, e.consumer, e.prod_arg, e.cons_arg,
                        False) for e in CHAIN_EDGES]
    s3 = build_fused_spec(CHAIN_BUILDERS, ["a", "b", "c"], edges2,
                          CHAIN_ALIASES, cache=cache)
    assert s3 is not s1
    assert cache.fused_cache_size() == 2


# --------------------------------------------------------------------------
# queue rewrite: identity, elision, caching, events
# --------------------------------------------------------------------------

def test_fused_bitwise_identical_all_targets():
    ctx = Context()
    for target in (None, "loop", "vector", "pallas"):
        q_off, _, _, _ = _run_chain(ctx, "off", target=target)
        q_on, _, _, _ = _run_chain(ctx, "flush", target=target)
        assert np.array_equal(q_off, q_on), f"target={target}"


def test_fusion_elides_pooled_intermediates():
    ctx = Context()
    q, bufs, _, queue = _run_chain(ctx, "flush", return_queue=True)
    assert not bufs["y"].materialized
    assert not bufs["z"].materialized
    stats = queue.dag_stats()
    assert stats["fused_chains"] == 1
    assert stats["commands_eliminated"] == 2
    # one avoided store + one avoided load per elided intermediate
    assert stats["bytes_elided"] == 2 * 2 * N * 4
    assert queue.stats["launches"] == 1


def test_unfused_queue_reports_zero_stats():
    ctx = Context()
    _, bufs, _, queue = _run_chain(ctx, "off", return_queue=True)
    assert queue.dag_stats() == {"mode": "off", "fused_chains": 0,
                                 "commands_eliminated": 0,
                                 "bytes_elided": 0}
    assert bufs["y"].materialized      # chain ran unfused, wrote through
    assert queue.stats["launches"] == 3


def test_repro_fuse_kill_switch(monkeypatch):
    ctx = Context()
    monkeypatch.setenv("REPRO_FUSE", "0")
    q_killed, bufs, _, queue = _run_chain(ctx, "flush", return_queue=True)
    assert queue.dag_stats()["fused_chains"] == 0
    assert queue.stats["launches"] == 3
    assert bufs["y"].materialized
    monkeypatch.delenv("REPRO_FUSE")
    q_fused, _, _, _ = _run_chain(ctx, "flush")
    assert np.array_equal(q_killed, q_fused)


def test_original_events_complete_and_share_profiling():
    ctx = Context()
    _, _, events, queue = _run_chain(ctx, "flush", return_queue=True)
    assert all(e.succeeded for e in events)
    # mirrored from one fused command: identical profiling counters
    assert len({e.start_ns for e in events}) == 1
    assert len({e.end_ns for e in events}) == 1
    assert queue.dag_stats()["fused_chains"] == 1


def test_fused_event_provenance_names_constituents():
    ctx = Context()
    dev = ctx.devices[0]
    prog = ctx.create_program(*CHAIN_BUILDERS)
    bufs = {nm: ctx.create_buffer(N) for nm in "xwryzq"}
    queue = ctx.create_queue(dev, fusion="flush")
    k1 = prog.create_kernel("rmsnorm_ew")
    k1.set_args(x=bufs["x"], w=bufs["w"], y=bufs["y"], inv_rms=0.5)
    k2 = prog.create_kernel("residual_add")
    k2.set_args(y=bufs["y"], r=bufs["r"], z=bufs["z"])
    e1 = queue.enqueue_nd_range(k1, (N,), LSZ)
    e2 = queue.enqueue_nd_range(k2, (N,), LSZ)
    queue.flush()
    fused = [e for e in queue.events() if e.fused_from]
    assert len(fused) == 1
    assert fused[0].fused_from == [e1, e2]
    assert "rmsnorm_ew" in fused[0].name
    assert "residual_add" in fused[0].name
    queue.finish()


def test_repeat_launch_hits_fused_tier_and_plan_cache():
    ctx = Context()
    dev = ctx.devices[0]
    _run_chain(ctx, "flush")
    cstats = dev.compile_cache.stats
    assert cstats.fused_builds >= 1
    builds0 = cstats.fused_builds
    plans0 = cstats.plan_builds
    q1, _, _, _ = _run_chain(ctx, "flush")
    q2, _, _, _ = _run_chain(ctx, "flush")
    assert np.array_equal(q1, q2)
    assert cstats.fused_builds == builds0      # stitched exactly once
    assert cstats.plan_builds == plans0        # planned exactly once
    assert cstats.fused_hits >= 2


def test_eager_mode_warms_fused_tier_at_enqueue():
    ctx = Context()
    dev = ctx.devices[0]
    prog = ctx.create_program(*CHAIN_BUILDERS)
    bufs = {nm: ctx.create_buffer(N) for nm in "xwryzq"}
    queue = ctx.create_queue(dev, fusion="eager")
    k1 = prog.create_kernel("rmsnorm_ew")
    k1.set_args(x=bufs["x"], w=bufs["w"], y=bufs["y"], inv_rms=0.5)
    k2 = prog.create_kernel("residual_add")
    k2.set_args(y=bufs["y"], r=bufs["r"], z=bufs["z"])
    k3 = prog.create_kernel("quantize")
    k3.set_args(z=bufs["z"], q=bufs["q"], scale=16.0)
    stats = dev.compile_cache.stats
    before = stats.fused_hits + stats.fused_misses
    for k in (k1, k2, k3):
        queue.enqueue_nd_range(k, (N,), LSZ)
    # the fused tier was consulted during the enqueue window, before any
    # flush (a warm process sees hits; a cold one sees misses + builds)
    assert stats.fused_hits + stats.fused_misses > before
    queue.finish()
    assert queue.dag_stats()["fused_chains"] == 1


def test_invalid_fusion_mode_rejected():
    from repro.core.errors import InvalidArgError
    ctx = Context()
    with pytest.raises(InvalidArgError, match="fusion mode"):
        ctx.create_queue(ctx.devices[0], fusion="sometimes")


# --------------------------------------------------------------------------
# legality negatives: each scenario must leave the DAG unfused
# --------------------------------------------------------------------------

def _two_kernel_setup(ctx, n=N):
    prog = ctx.create_program(build_rmsnorm_ew, build_residual_add)
    bufs = {nm: ctx.create_buffer(n) for nm in "xwryz"}
    k1 = prog.create_kernel("rmsnorm_ew")
    k1.set_args(x=bufs["x"], w=bufs["w"], y=bufs["y"], inv_rms=0.5)
    k2 = prog.create_kernel("residual_add")
    k2.set_args(y=bufs["y"], r=bufs["r"], z=bufs["z"])
    return prog, bufs, k1, k2


def test_no_fusion_across_queue_barrier():
    ctx = Context()
    _, bufs, k1, k2 = _two_kernel_setup(ctx)
    queue = ctx.create_queue(ctx.devices[0], fusion="flush")
    queue.enqueue_nd_range(k1, (N,), LSZ)
    queue.enqueue_barrier()
    queue.enqueue_nd_range(k2, (N,), LSZ)
    queue.finish()
    assert queue.dag_stats()["fused_chains"] == 0
    assert queue.stats["launches"] == 2


def test_no_fusion_with_mismatched_ndrange():
    ctx = Context()
    _, bufs, k1, k2 = _two_kernel_setup(ctx)
    queue = ctx.create_queue(ctx.devices[0], fusion="flush")
    queue.enqueue_nd_range(k1, (N,), LSZ)
    queue.enqueue_nd_range(k2, (N // 2,), LSZ)   # different global size
    queue.finish()
    assert queue.dag_stats()["fused_chains"] == 0
    assert queue.stats["launches"] == 2


def test_no_fusion_with_mismatched_local_size():
    ctx = Context()
    _, bufs, k1, k2 = _two_kernel_setup(ctx)
    queue = ctx.create_queue(ctx.devices[0], fusion="flush")
    queue.enqueue_nd_range(k1, (N,), (64,))
    queue.enqueue_nd_range(k2, (N,), (32,))
    queue.finish()
    assert queue.dag_stats()["fused_chains"] == 0


def test_no_fusion_for_non_elementwise_kernel():
    from repro.core.examples import build_dct
    ctx = Context()
    prog = ctx.create_program(build_rmsnorm_ew, build_dct)
    bufs = {nm: ctx.create_buffer(N) for nm in "xwy"}
    coef = ctx.create_buffer(N)
    out = ctx.create_buffer(N)
    k1 = prog.create_kernel("rmsnorm_ew")
    k1.set_args(x=bufs["x"], w=bufs["w"], y=bufs["y"], inv_rms=0.5)
    k2 = prog.create_kernel("dct")
    k2.set_args(inp=bufs["y"], coef=coef, out=out, width=1)
    queue = ctx.create_queue(ctx.devices[0], fusion="flush")
    queue.enqueue_nd_range(k1, (N,), LSZ)
    queue.enqueue_nd_range(k2, (N,), LSZ)
    queue.finish()
    assert queue.dag_stats()["fused_chains"] == 0


def test_externally_observed_intermediate_is_not_elided():
    """A read of the intermediate in the same window forbids *elision*
    (the chain may still fuse — the store stays and writes through)."""
    ctx = Context()
    _, bufs, k1, k2 = _two_kernel_setup(ctx)
    queue = ctx.create_queue(ctx.devices[0], fusion="flush")
    xh, wh, rh = _host_inputs()
    queue.enqueue_write_buffer(bufs["x"], xh)
    queue.enqueue_write_buffer(bufs["w"], wh)
    queue.enqueue_write_buffer(bufs["r"], rh)
    e1 = queue.enqueue_nd_range(k1, (N,), LSZ)
    e2 = queue.enqueue_nd_range(k2, (N,), LSZ)
    y_out = np.zeros(N, np.float32)
    queue.enqueue_read_buffer(bufs["y"], y_out, wait_for=[e2])
    queue.finish()
    stats = queue.dag_stats()
    assert stats["fused_chains"] == 1          # fusion is still legal
    assert stats["bytes_elided"] == 0          # but elision is not
    assert bufs["y"].materialized
    # the observed intermediate holds exactly the unfused value
    expected = (xh * wh * np.float32(0.5)).astype(np.float32)
    assert np.array_equal(y_out, expected)


def test_sub_buffer_aliased_intermediate_blocks_fusion():
    from repro.runtime.memory import create_sub_buffer
    ctx = Context()
    prog = ctx.create_program(build_rmsnorm_ew, build_residual_add)
    bufs = {nm: ctx.create_buffer(N) for nm in "xwryz"}
    _ = bufs["y"].data                 # materialize so a view is legal
    y_view = create_sub_buffer(bufs["y"], 0, N * 4)
    k1 = prog.create_kernel("rmsnorm_ew")
    k1.set_args(x=bufs["x"], w=bufs["w"], y=bufs["y"], inv_rms=0.5)
    k2 = prog.create_kernel("residual_add")
    k2.set_args(y=y_view, r=bufs["r"], z=bufs["z"])  # aliased view
    queue = ctx.create_queue(ctx.devices[0], fusion="flush")
    queue.enqueue_nd_range(k1, (N,), LSZ)
    queue.enqueue_nd_range(k2, (N,), LSZ)
    queue.finish()
    assert queue.dag_stats()["fused_chains"] == 0
    assert queue.stats["launches"] == 2


def test_no_fusion_when_consumer_does_not_read_producer_output():
    """Two independent elementwise kernels (no chained buffer) must not
    fuse: there is no producer→consumer edge."""
    ctx = Context()
    prog = ctx.create_program(build_rmsnorm_ew)
    a = {nm: ctx.create_buffer(N) for nm in "xwy"}
    b = {nm: ctx.create_buffer(N) for nm in "xwy"}
    k1 = prog.create_kernel("rmsnorm_ew")
    k1.set_args(x=a["x"], w=a["w"], y=a["y"], inv_rms=0.5)
    k2 = prog.create_kernel("rmsnorm_ew")
    k2.set_args(x=b["x"], w=b["w"], y=b["y"], inv_rms=0.5)
    queue = ctx.create_queue(ctx.devices[0], fusion="flush")
    queue.enqueue_nd_range(k1, (N,), LSZ)
    queue.enqueue_nd_range(k2, (N,), LSZ)
    queue.finish()
    assert queue.dag_stats()["fused_chains"] == 0


# --------------------------------------------------------------------------
# co-execution conformance: fused chain, 1 vs 2 devices
# --------------------------------------------------------------------------

def test_fused_chain_coexec_two_devices_bitwise():
    ctx = Context()
    q_ref, _, _, _ = _run_chain(ctx, "off")
    cache = ctx.devices[0].compile_cache
    spec = build_fused_spec(
        CHAIN_BUILDERS, ["rmsnorm_ew", "residual_add", "quantize"],
        CHAIN_EDGES, CHAIN_ALIASES, cache=cache)
    xh, wh, rh = _host_inputs()
    kern = spec.program.create_kernel(spec.kernel_name)
    kern.set_args(k0_x=xh, k0_w=wh, k1_r=rh,
                  k2_q=np.zeros(N, np.float32),
                  k0_inv_rms=0.5, k2_scale=16.0)
    co1 = ctx.create_co_executor(ctx.devices[:1])
    out1 = co1.launch(kern, (N,), LSZ)["k2_q"]
    devs2 = ctx.platform.co_devices(2)
    co2 = ctx.create_co_executor(devs2)
    out2 = co2.launch(kern.clone(), (N,), LSZ)["k2_q"]
    assert np.array_equal(np.asarray(out1), q_ref)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
