"""Sharding-rule table, adaptation, and dry-run spec plumbing (no 512-dev
requirement: these run on the single CPU device with tiny meshes)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed.sharding import (
    BASELINE_RULES, DECODE_RULES, LONG_DECODE_RULES, adapt_rules_for, divisible, prune_to_mesh)
from repro.models import model_defs, cache_logical_axes, init_caches
from repro.models.params import param_pspecs, ParamDef


def tiny_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_builds():
    r = BASELINE_RULES
    assert r.spec("batch", None, "mlp") == P(("pod", "data"), None, "model")


def test_prune_drops_missing_axes():
    mesh = tiny_mesh()      # no "pod"
    r = prune_to_mesh(BASELINE_RULES, mesh)
    assert r.batch == ("data",)
    assert r.heads == "model"


def test_adapt_replicates_indivisible_dims():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake a 16-wide model axis via a real mesh is impossible on 1 CPU;
    # test the logic with the divisibility helper directly
    assert divisible(32, mesh, "model")
    r = adapt_rules_for(BASELINE_RULES, mesh, n_kv=3, n_experts=40,
                        n_heads=9, vocab=49155)
    # 1-wide axes divide everything -> nothing changes
    assert r.kv_heads == BASELINE_RULES.kv_heads


class FakeMesh:
    """Mesh stand-in with arbitrary axis sizes for divisibility logic."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_adapt_on_production_shape():
    mesh = FakeMesh({"data": 16, "model": 16})
    r = adapt_rules_for(BASELINE_RULES, mesh, n_kv=3, n_experts=40,
                        n_heads=9, vocab=49155 + 253)
    assert r.kv_heads is None          # 3 % 16 != 0
    assert r.heads is None             # 9 % 16
    assert r.experts is None           # 40 % 16
    assert r.moe_capacity == "model"   # token-parallel fallback (§Perf H2)
    r2 = adapt_rules_for(BASELINE_RULES, mesh, n_kv=8, n_experts=16,
                         n_heads=32, vocab=32256)
    assert r2.heads == "model" and r2.experts == "model"


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_pspecs_no_axis_conflicts(arch):
    """Every full-config param leaf yields a PartitionSpec with no mesh
    axis used twice (the error the dry-run would hit at lowering)."""
    cfg = configs.get_config(arch)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = adapt_rules_for(BASELINE_RULES, mesh, n_kv=cfg.n_kv,
                            n_experts=cfg.n_experts, n_heads=cfg.n_heads,
                            vocab=cfg.padded_vocab)
    specs = param_pspecs(model_defs(cfg), rules)
    for spec in jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)):
        used = []
        for entry in spec:
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            used.extend(axes)
        assert len(used) == len(set(used)), f"{arch}: duplicate axis {spec}"


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_dims_divide_production_axes(arch):
    """Every sharded param dim divides the 16-wide production axes after
    rule adaptation — the invariant that makes lowering succeed."""
    cfg = configs.get_config(arch)
    mesh = FakeMesh({"data": 16, "model": 16, "pod": 2})
    rules = adapt_rules_for(BASELINE_RULES, mesh, n_kv=cfg.n_kv,
                            n_experts=cfg.n_experts, n_heads=cfg.n_heads,
                            vocab=cfg.padded_vocab)
    defs = model_defs(cfg)
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    for d in leaves:
        for size, logical in zip(d.shape, d.logical):
            if logical is None:
                continue
            axis = getattr(rules, logical)
            if axis is None:
                continue
            axes = (axis,) if isinstance(axis, str) else axis
            total = int(np.prod([mesh.shape[a] for a in axes
                                 if a in mesh.shape]))
            assert size % total == 0, \
                f"{arch}: dim {logical}={size} not divisible by {total}"


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_cache_axes_cover_cache_tree(arch):
    cfg = configs.get_config(arch)
    caches = init_caches(cfg, 4, 64, abstract=True)
    ax = cache_logical_axes(cfg)
    assert set(ax) == set(caches)
    for k, v in caches.items():
        assert len(ax[k]) == len(v.shape), k


def test_decode_rules_shard_cache_seq():
    assert DECODE_RULES.cache_seq == "model"
    assert DECODE_RULES.act_seq is None
    assert LONG_DECODE_RULES.batch is None
    assert LONG_DECODE_RULES.cache_seq == ("data", "model")


def test_constrain_is_noop_outside_mesh():
    from repro.distributed.sharding import constrain
    x = jnp.ones((4, 4))
    y = constrain(x, BASELINE_RULES, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
