"""Pass-manager pipeline tests (docs/compiler.md).

Covers the middle-end acceptance contract: golden canonical-IR snapshots
after every CFG-mutating pass, structural verifier positives/negatives
(malformed CFG -> VerifierError naming the pass), requires/establishes
enforcement, ParallelRegionMD facts, and stage-level plan sharing — the
autotuner's 3-target sweep runs region formation exactly once per kernel
and all targets produce bitwise-identical results from one shared
WorkGroupPlan.

Regenerate the golden files after intentional pipeline changes:

  REPRO_UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_passes.py
"""

import os

import numpy as np
import pytest

from repro.core import (CompilationCache, PassManager, PlanKey,
                        VerifierError, canonical_ir, compile_count,
                        compile_kernel, plan_count, run_ndrange, verify_ir)
from repro.core.ir import (BasicBlock, CondBranch, Function, Instr, Jump,
                           Phi, Return, Value)
from repro.core.examples import build_condbar, build_dct, build_reduce2
from repro.core.passes import DEFAULT_PASSES, Pass, build_plan
from repro.core.regions import lower_to_regions, WGInfo

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


# --------------------------------------------------------------------------
# exemplar kernels (deterministic builds -> stable canonical IR)
# --------------------------------------------------------------------------

GOLDEN_KERNELS = {"reduce2": build_reduce2, "condbar": build_condbar,
                  "dct": build_dct}


def pipeline_trace(build_fn) -> str:
    """Canonical IR after the input + every CFG-mutating pass, plus the
    final plan summary — the golden-snapshot surface."""
    fn = build_fn()
    lines = ["== input ==", canonical_ir(fn)]

    def on_pass(p, st):
        if p.mutates_cfg:
            lines.append(f"== after {p.name} ==")
            lines.append(canonical_ir(st.fn))

    pm = PassManager(verify=True, on_pass=on_pass)
    plan = pm.run(fn)
    lines.append("== plan ==")
    lines.append(plan.describe())
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
# golden-IR snapshots
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GOLDEN_KERNELS))
def test_golden_ir_snapshots(name):
    got = pipeline_trace(GOLDEN_KERNELS[name])
    path = os.path.join(GOLDEN_DIR, f"{name}.txt")
    if os.environ.get("REPRO_UPDATE_GOLDEN"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            f.write(got)
        pytest.skip(f"golden updated: {path}")
    assert os.path.exists(path), \
        f"golden file missing; run with REPRO_UPDATE_GOLDEN=1 ({path})"
    with open(path) as f:
        want = f.read()
    assert got == want, (
        f"canonical IR drifted from golden snapshot {path}; if the "
        f"pipeline change is intentional, regenerate with "
        f"REPRO_UPDATE_GOLDEN=1")


def test_trace_is_deterministic():
    assert pipeline_trace(build_reduce2) == pipeline_trace(build_reduce2)


# --------------------------------------------------------------------------
# structural verifier
# --------------------------------------------------------------------------

def _tiny_fn() -> Function:
    fn = Function("tiny")
    blk = BasicBlock("entry")
    blk.terminator = Return()
    fn.blocks["entry"] = blk
    fn.entry = "entry"
    return fn


def test_verifier_accepts_well_formed():
    verify_ir(_tiny_fn(), ["single-exit"], pass_name="test")


def test_verifier_missing_terminator():
    fn = _tiny_fn()
    fn.blocks["entry"].terminator = None
    with pytest.raises(VerifierError, match="no terminator"):
        verify_ir(fn, pass_name="normalize")


def test_verifier_edge_to_missing_block():
    fn = _tiny_fn()
    fn.blocks["entry"].terminator = Jump("nowhere")
    with pytest.raises(VerifierError, match="missing block"):
        verify_ir(fn, pass_name="normalize")


def test_verifier_unreachable_block():
    fn = _tiny_fn()
    orphan = BasicBlock("orphan")
    orphan.terminator = Return()
    fn.blocks["orphan"] = orphan
    with pytest.raises(VerifierError, match="unreachable"):
        verify_ir(fn, pass_name="normalize")


def test_verifier_multiple_exits_when_single_required():
    fn = _tiny_fn()
    other = BasicBlock("other")
    other.terminator = Return()
    fn.blocks["other"] = other
    fn.blocks["entry"].terminator = CondBranch(Value("bool"), "other",
                                               "entry2")
    e2 = BasicBlock("entry2")
    e2.terminator = Return()
    fn.blocks["entry2"] = e2
    with pytest.raises(VerifierError, match="single exit"):
        verify_ir(fn, ["single-exit"], pass_name="normalize")


def test_verifier_barrier_not_isolated():
    fn = _tiny_fn()
    fn.blocks["entry"].instrs = [Instr("barrier", [], None),
                                 Instr("local_id", [], Value("int32"),
                                       {"dim": 0})]
    with pytest.raises(VerifierError, match="not isolated"):
        verify_ir(fn, ["barriers-isolated"], pass_name="normalize")


def test_verifier_phi_in_phi_free_ir():
    fn = _tiny_fn()
    fn.blocks["entry"].phis = [Phi(Value("int32"), {})]
    with pytest.raises(VerifierError, match="phi"):
        verify_ir(fn, ["phi-free"], pass_name="out_of_ssa")


def test_verifier_vreg_dtype_conflict():
    fn = _tiny_fn()
    fn.blocks["entry"].instrs = [
        Instr("vreg_read", [], Value("int32"),
              {"vreg": "r.x", "dtype": "int32"}),
        Instr("vreg_write", [1.0], None,
              {"vreg": "r.x", "dtype": "float32"})]
    with pytest.raises(VerifierError, match="vreg"):
        verify_ir(fn, ["phi-free"], pass_name="out_of_ssa")


def test_verifier_error_names_the_pass():
    """A malformed CFG produced mid-pipeline is attributed to the pass
    that emitted it."""

    def corrupt(st):
        # point a terminator at a block that does not exist
        first = st.fn.blocks[st.fn.entry]
        first.terminator = Jump("does_not_exist")

    bad = Pass("corrupt_cfg", corrupt)
    pm = PassManager(passes=(DEFAULT_PASSES[0], bad), verify=True)
    with pytest.raises(VerifierError, match="corrupt_cfg") as ei:
        pm.run(build_condbar())
    assert ei.value.pass_name == "corrupt_cfg"


def test_manager_enforces_requires():
    needs = Pass("needs_phi_free", lambda st: None,
                 requires=("phi-free",))
    pm = PassManager(passes=(needs,), verify=False)
    with pytest.raises(VerifierError, match="needs_phi_free"):
        pm.run(build_condbar())


def test_misordered_pipeline_fails_with_attribution():
    """Analysis products are contract properties too: consuming a product
    before its producer ran raises an attributed VerifierError, not an
    AttributeError on a missing artifact."""
    by_name = {p.name: p for p in DEFAULT_PASSES}
    misordered = [by_name[n] for n in
                  ("normalize", "inject_loop_barriers", "out_of_ssa",
                   "tail_duplicate", "structure_regions")]
    pm = PassManager(passes=misordered, verify=False)
    with pytest.raises(VerifierError, match="structure_regions"):
        pm.run(build_condbar())


def test_default_pipeline_verifies_clean():
    """Every pass of the default pipeline upholds the invariants it and
    its predecessors declare, on all exemplar kernels."""
    for name, build in GOLDEN_KERNELS.items():
        PassManager(verify=True).run(build())


# --------------------------------------------------------------------------
# WorkGroupPlan + ParallelRegionMD
# --------------------------------------------------------------------------

def test_plan_product_is_complete():
    plan = build_plan(build_reduce2())
    assert plan.wg.regions and plan.order
    assert set(plan.md) == set(plan.wg.regions)
    assert set(plan.region_plans) <= set(plan.wg.regions)
    assert plan.pass_times and all(t >= 0 for t in plan.pass_times.values())
    # md also rides on the regions themselves (IR-attached metadata)
    for bar, r in plan.wg.regions.items():
        assert r.attrs["md"] is plan.md[bar]


def test_parallel_region_md_facts():
    # every region's WI loop is parallel by construction (§4: the
    # llvm.mem.parallel_loop_access analogue)
    plan = build_plan(build_reduce2())
    assert all(m.wi_parallel for m in plan.md.values())
    # the b-loop implicit barriers mark their regions lockstep (§4.5)
    assert any(m.lockstep for m in plan.md.values())
    # barrier branches are WG-uniform here, so exits are provably uniform
    assert all(m.uniform_exits for m in plan.md.values())

    # horizontal parallelization (§4.6) manufactures lockstep regions out
    # of a barrier-free kernel
    with_h = build_plan(build_dct(), horizontal=True)
    without_h = build_plan(build_dct(), horizontal=False)
    assert any(m.lockstep for m in with_h.md.values())
    assert not any(m.lockstep for m in without_h.md.values())
    assert len(with_h.wg.regions) > len(without_h.wg.regions)


def test_lower_to_regions_compat_wrapper():
    """The legacy entry point still returns a WGInfo (now produced by the
    pass manager) and counts as one pipeline run."""
    p0 = plan_count()
    wg = lower_to_regions(build_condbar())
    assert isinstance(wg, WGInfo)
    assert plan_count() - p0 == 1
    assert len(wg.regions) >= 2


# --------------------------------------------------------------------------
# stage-level plan sharing
# --------------------------------------------------------------------------

def _bufs(n=8):
    # reduce2 is a 2-wide reduction: local size 2, one output per group
    rng = np.random.default_rng(7)
    return {"inp": rng.standard_normal(n).astype(np.float32),
            "out": np.zeros(n // 2, np.float32)}


def test_autotune_sweep_builds_plan_once():
    """Acceptance criterion: a cold target="auto" compile of one kernel
    runs the target-independent prefix exactly once across the 3-target
    sweep (stage counter == 1), while each target still lowers once."""
    from repro.core import TuningTable, set_default_table
    cache = CompilationCache()
    set_default_table(TuningTable())
    try:
        p0, c0 = plan_count(), compile_count()
        k = compile_kernel(build_reduce2, (2,), target="auto", cache=cache)
        bufs = _bufs()
        out = k(bufs, (8,))
        assert plan_count() - p0 == 1, \
            "region formation re-ran during the autotune sweep"
        assert compile_count() - c0 == 3, "expected one lowering per target"
        assert cache.stats.plan_builds == 1
        assert cache.stats.plan_hits == 2
        ref = run_ndrange(build_reduce2(), (8,), (2,),
                          {k2: v.copy() for k2, v in _bufs().items()})
        np.testing.assert_allclose(out["out"], ref["out"], rtol=1e-5)
    finally:
        set_default_table(None)


def test_plan_shared_across_local_sizes():
    """PlanKey has no local_size: re-specializing a kernel for another
    work-group size reuses the plan (only target lowering re-runs)."""
    cache = CompilationCache()
    compile_kernel(build_condbar, (8,), cache=cache)
    compile_kernel(build_condbar, (16,), cache=cache)
    assert cache.stats.plan_builds == 1 and cache.stats.plan_hits == 1
    assert cache.stats.compiles == 2


def test_plan_key_excludes_target_options():
    k1 = PlanKey.make("abc", horizontal=True, merge_uniform=True,
                      use_vml=False)
    k2 = PlanKey.make("abc", horizontal=True, merge_uniform=True,
                      use_vml=True)
    assert k1 == k2, "use_vml is target-level; must not split plans"
    k3 = PlanKey.make("abc", horizontal=False, merge_uniform=True)
    assert k1 != k3, "horizontal changes the middle-end product"


def test_all_targets_bitwise_identical_from_shared_plan():
    """All three targets consume one WorkGroupPlan object and must agree
    bitwise — the plan is the single source of truth for regions,
    schedule, uniformity and context layout."""
    cache = CompilationCache()
    kernels = {t: compile_kernel(build_reduce2, (2,), target=t, cache=cache)
               for t in ("loop", "vector", "pallas")}
    plans = {t: k.work_group_plan for t, k in kernels.items()}
    assert plans["loop"] is plans["vector"] is plans["pallas"], \
        "targets must share one plan object"
    assert cache.stats.plan_builds == 1

    outs = {t: k(_bufs(), (8,)) for t, k in kernels.items()}
    for t in ("vector", "pallas"):
        for name in outs["loop"]:
            assert np.array_equal(outs["loop"][name], outs[t][name]), \
                f"{t} diverged bitwise from loop on {name}"


def test_verifier_runs_under_env_flag(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY_IR", "1")
    pm = PassManager()
    assert pm.verify
    pm.run(build_reduce2())  # must not raise
    monkeypatch.setenv("REPRO_VERIFY_IR", "0")
    assert not PassManager().verify
