"""Property-based fuzzing of the kernel compiler: random race-free SPMD
kernels (random arithmetic, uniform/varying branches, uniform loops,
barriers at uniform points) must produce identical results on every
static target and the fiber oracle.

This is the strongest §4 correctness evidence we can generate: each
random program exercises region formation, context-array allocation,
uniform merging, and divergence handling in combination.

The buffer-aliasing specs extend the fuzz surface to the hierarchical
memory subsystem (docs/memory.md): two kernel arguments bound to
*overlapping sub-buffers* of one parent allocation, launched through the
command queue, must agree bitwise with a numpy emulation of the aliasing
on every target — and the launch must publish span-granular
invalidations to the parent's residency binding.
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import KernelBuilder, compile_kernel, run_ndrange
from repro.runtime import (CommandQueue, Platform, ResidencyTracker,
                           create_buffer, create_sub_buffer)

LSZ = 8


class ProgramSpec:
    """A reproducible random-program description."""

    def __init__(self, ops):
        self.ops = ops      # list of op tuples


def spec_strategy():
    op = st.one_of(
        st.tuples(st.just("add_gid"), st.floats(-2, 2, allow_nan=False,
                                                width=32)),
        st.tuples(st.just("mul_const"), st.floats(0.25, 2,
                                                  allow_nan=False,
                                                  width=32)),
        st.tuples(st.just("acc_loop"), st.integers(1, 4)),      # uniform loop
        st.tuples(st.just("branch_parity"), st.floats(-2, 2,
                                                      allow_nan=False,
                                                      width=32)),
        st.tuples(st.just("neighbor_swap"), st.integers(1, LSZ - 1)),
        st.tuples(st.just("barrier_scale"), st.floats(0.5, 1.5,
                                                      allow_nan=False,
                                                      width=32)),
    )
    return st.lists(op, min_size=1, max_size=6).map(ProgramSpec)


def build_from_spec(spec: ProgramSpec):
    def build():
        b = KernelBuilder("fuzz")
        x = b.arg_buffer("x", "float32")
        tmp = b.local_array("tmp", "float32", LSZ)
        lid = b.local_id(0)
        acc = b.var(x[lid], name="acc")
        for i, (kind, arg) in enumerate(spec.ops):
            if kind == "add_gid":
                acc.set(acc.get() + b.global_id(0) * float(arg))
            elif kind == "mul_const":
                acc.set(acc.get() * float(arg))
            elif kind == "acc_loop":        # uniform trip count
                j = b.var(b.const(0), name=f"j{i}")
                with b.while_loop() as loop:
                    loop.cond(j.get() < int(arg))
                    acc.set(acc.get() + 0.5)
                    j.set(j.get() + 1)
            elif kind == "branch_parity":   # varying branch
                with b.if_(lid % 2 == 0):
                    acc.set(acc.get() + float(arg))
            elif kind == "neighbor_swap":   # race-free: write, sync, read
                tmp[lid] = acc.get()
                b.barrier()
                acc.set(tmp[(lid + int(arg)) % b.local_size(0)])
                b.barrier()
            elif kind == "barrier_scale":   # unconditional barrier
                b.barrier()
                acc.set(acc.get() * float(arg))
        x[lid] = acc.get()
        return b.finish()
    return build


@settings(max_examples=25, deadline=None)
@given(spec=spec_strategy(), seed=st.integers(0, 2**16))
def test_random_kernels_agree_across_targets(spec, seed):
    rng = np.random.default_rng(seed)
    x0 = rng.normal(size=LSZ).astype(np.float32)
    build = build_from_spec(spec)
    ref = run_ndrange(build(), (LSZ,), (LSZ,), {"x": x0.copy()})
    for target in ("vector", "loop"):
        k = compile_kernel(build, (LSZ,), target=target)
        out = k({"x": x0.copy()}, (LSZ,))
        np.testing.assert_allclose(
            out["x"], ref["x"], rtol=2e-5, atol=2e-5,
            err_msg=f"target={target} ops={spec.ops}")


# ---------------------------------------------------------------------------
# Buffer-aliasing specs: kernel args bound to overlapping sub-buffers
# ---------------------------------------------------------------------------

class AliasSpec:
    """A reproducible aliased-kernel description: ops mixing reads of the
    write-view ``x`` and the overlapping read-view ``y``."""

    def __init__(self, ops, overlap):
        self.ops = ops              # list of (kind, arg)
        self.overlap = overlap      # y's element offset into the parent

    def __repr__(self):             # pragma: no cover - failure messages
        return f"AliasSpec(ops={self.ops}, overlap={self.overlap})"


def alias_spec_strategy():
    op = st.one_of(
        st.tuples(st.just("add_y"), st.integers(0, LSZ - 1)),
        st.tuples(st.just("mul_const"), st.floats(0.25, 2, allow_nan=False,
                                                  width=32)),
        st.tuples(st.just("add_gid"), st.floats(-2, 2, allow_nan=False,
                                                width=32)),
        st.tuples(st.just("sub_y"), st.integers(0, LSZ - 1)),
    )
    return st.builds(AliasSpec, st.lists(op, min_size=1, max_size=5),
                     st.integers(1, LSZ))


def build_alias_kernel(spec: AliasSpec):
    """x[g] updated from reads of x and the aliased view y (read-only),
    so the single write target keeps the program race-free."""
    def build():
        b = KernelBuilder("alias")
        x = b.arg_buffer("x", "float32")
        y = b.arg_buffer("y", "float32")
        g = b.global_id(0)
        acc = b.var(x[g], name="acc")
        for kind, arg in spec.ops:
            if kind == "add_y":
                acc.set(acc.get() + y[(g + int(arg)) % LSZ])
            elif kind == "sub_y":
                acc.set(acc.get() - y[(g + int(arg)) % LSZ] * 0.5)
            elif kind == "mul_const":
                acc.set(acc.get() * float(arg))
            elif kind == "add_gid":
                acc.set(acc.get() + b.global_id(0) * float(arg))
        x[g] = acc.get()
        return b.finish()
    return build


def emulate_alias(spec: AliasSpec, parent: np.ndarray) -> np.ndarray:
    """Numpy oracle of the aliased launch: snapshot both views, apply the
    op stream, write the result back through the x view only."""
    xs = parent[:LSZ].copy()
    ys = parent[spec.overlap:spec.overlap + LSZ].copy()
    g = np.arange(LSZ, dtype=np.float32)
    acc = xs.copy()
    for kind, arg in spec.ops:
        if kind == "add_y":
            acc = acc + ys[(np.arange(LSZ) + int(arg)) % LSZ]
        elif kind == "sub_y":
            acc = (acc - ys[(np.arange(LSZ) + int(arg)) % LSZ]
                   * np.float32(0.5))
        elif kind == "mul_const":
            acc = acc * np.float32(arg)
        elif kind == "add_gid":
            acc = acc + g * np.float32(arg)
    out = parent.copy()
    out[:LSZ] = acc.astype(np.float32)
    return out


@pytest.fixture(scope="module")
def alias_plat():
    return Platform()


@settings(max_examples=10, deadline=None)
@given(spec=alias_spec_strategy(), seed=st.integers(0, 2**16))
def test_random_kernels_with_aliased_subbuffers_agree(alias_plat, spec,
                                                      seed):
    """Overlapping sub-buffer args through the queue: every target's
    parent allocation ends bitwise-identical to the numpy emulation, and
    the launch invalidates the written span for other device copies."""
    rng = np.random.default_rng(seed)
    init = rng.normal(size=2 * LSZ).astype(np.float32)
    expect = emulate_alias(spec, init)
    build = build_alias_kernel(spec)
    for driver in ("basic", "vector", "pallas"):
        dev = alias_plat.get_devices(driver)[0]
        q = CommandQueue(dev)
        parent = create_buffer(dev, 2 * LSZ, "float32")
        tracker = ResidencyTracker()
        parent.bind_residency(tracker, "parent", dev.info.name)
        tracker.acquire_spans("parent", "elsewhere", parent.nbytes)
        q.enqueue_write_buffer(parent, init)
        xv = create_sub_buffer(parent, 0, LSZ * 4)
        yv = create_sub_buffer(parent, spec.overlap * 4, LSZ * 4)
        k = dev.build_kernel(build, (LSZ,))
        q.enqueue_ndrange_kernel(k, (LSZ,), {"x": xv, "y": yv})
        q.finish()
        np.testing.assert_allclose(
            parent.data, expect, rtol=2e-5, atol=2e-5,
            err_msg=f"driver={driver} {spec!r}")
        # the residency/invalidate path ran: the whole-parent write of
        # enqueue_write_buffer plus both view write-backs stale the full
        # parent span on the other holder
        assert tracker.stale_spans("parent", "elsewhere") == \
            [(0, parent.nbytes)]
        assert tracker.resident("parent", dev.info.name, parent.nbytes)
        parent.release()


@settings(max_examples=8, deadline=None)
@given(spec=spec_strategy())
def test_random_kernels_uniform_merging_consistent(spec):
    """merge_uniform on/off must not change results, only context size."""
    rng = np.random.default_rng(0)
    x0 = rng.normal(size=LSZ).astype(np.float32)
    build = build_from_spec(spec)
    k1 = compile_kernel(build, (LSZ,), merge_uniform=True)
    k2 = compile_kernel(build, (LSZ,), merge_uniform=False)
    o1 = k1({"x": x0.copy()}, (LSZ,))
    o2 = k2({"x": x0.copy()}, (LSZ,))
    np.testing.assert_allclose(o1["x"], o2["x"], rtol=1e-6)
    assert k1.context_stats["context_bytes"] <= \
        k2.context_stats["context_bytes"]
