"""Property-based fuzzing of the kernel compiler: random race-free SPMD
kernels (random arithmetic, uniform/varying branches, uniform loops,
barriers at uniform points) must produce identical results on every
static target and the fiber oracle.

This is the strongest §4 correctness evidence we can generate: each
random program exercises region formation, context-array allocation,
uniform merging, and divergence handling in combination.
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import KernelBuilder, compile_kernel, run_ndrange

LSZ = 8


class ProgramSpec:
    """A reproducible random-program description."""

    def __init__(self, ops):
        self.ops = ops      # list of op tuples


def spec_strategy():
    op = st.one_of(
        st.tuples(st.just("add_gid"), st.floats(-2, 2, allow_nan=False,
                                                width=32)),
        st.tuples(st.just("mul_const"), st.floats(0.25, 2,
                                                  allow_nan=False,
                                                  width=32)),
        st.tuples(st.just("acc_loop"), st.integers(1, 4)),      # uniform loop
        st.tuples(st.just("branch_parity"), st.floats(-2, 2,
                                                      allow_nan=False,
                                                      width=32)),
        st.tuples(st.just("neighbor_swap"), st.integers(1, LSZ - 1)),
        st.tuples(st.just("barrier_scale"), st.floats(0.5, 1.5,
                                                      allow_nan=False,
                                                      width=32)),
    )
    return st.lists(op, min_size=1, max_size=6).map(ProgramSpec)


def build_from_spec(spec: ProgramSpec):
    def build():
        b = KernelBuilder("fuzz")
        x = b.arg_buffer("x", "float32")
        tmp = b.local_array("tmp", "float32", LSZ)
        lid = b.local_id(0)
        acc = b.var(x[lid], name="acc")
        for i, (kind, arg) in enumerate(spec.ops):
            if kind == "add_gid":
                acc.set(acc.get() + b.global_id(0) * float(arg))
            elif kind == "mul_const":
                acc.set(acc.get() * float(arg))
            elif kind == "acc_loop":        # uniform trip count
                j = b.var(b.const(0), name=f"j{i}")
                with b.while_loop() as loop:
                    loop.cond(j.get() < int(arg))
                    acc.set(acc.get() + 0.5)
                    j.set(j.get() + 1)
            elif kind == "branch_parity":   # varying branch
                with b.if_(lid % 2 == 0):
                    acc.set(acc.get() + float(arg))
            elif kind == "neighbor_swap":   # race-free: write, sync, read
                tmp[lid] = acc.get()
                b.barrier()
                acc.set(tmp[(lid + int(arg)) % b.local_size(0)])
                b.barrier()
            elif kind == "barrier_scale":   # unconditional barrier
                b.barrier()
                acc.set(acc.get() * float(arg))
        x[lid] = acc.get()
        return b.finish()
    return build


@settings(max_examples=25, deadline=None)
@given(spec=spec_strategy(), seed=st.integers(0, 2**16))
def test_random_kernels_agree_across_targets(spec, seed):
    rng = np.random.default_rng(seed)
    x0 = rng.normal(size=LSZ).astype(np.float32)
    build = build_from_spec(spec)
    ref = run_ndrange(build(), (LSZ,), (LSZ,), {"x": x0.copy()})
    for target in ("vector", "loop"):
        k = compile_kernel(build, (LSZ,), target=target)
        out = k({"x": x0.copy()}, (LSZ,))
        np.testing.assert_allclose(
            out["x"], ref["x"], rtol=2e-5, atol=2e-5,
            err_msg=f"target={target} ops={spec.ops}")


@settings(max_examples=8, deadline=None)
@given(spec=spec_strategy())
def test_random_kernels_uniform_merging_consistent(spec):
    """merge_uniform on/off must not change results, only context size."""
    rng = np.random.default_rng(0)
    x0 = rng.normal(size=LSZ).astype(np.float32)
    build = build_from_spec(spec)
    k1 = compile_kernel(build, (LSZ,), merge_uniform=True)
    k2 = compile_kernel(build, (LSZ,), merge_uniform=False)
    o1 = k1({"x": x0.copy()}, (LSZ,))
    o2 = k2({"x": x0.copy()}, (LSZ,))
    np.testing.assert_allclose(o1["x"], o2["x"], rtol=1e-6)
    assert k1.context_stats["context_bytes"] <= \
        k2.context_stats["context_bytes"]
