"""Deterministic continuous-batching scheduler tests (docs/serving.md).

Covers the scheduler invariants the tentpole promises, each as a small
deterministic scenario:

* mid-decode eviction refills the slot **on the same step**;
* token streams bitwise-identical to serial one-request-at-a-time
  execution (real jitted model, mixed prompt lengths, co-tenant slots);
* OOM preemption requeues without losing a request, surfacing the typed
  :class:`~repro.runtime.bufalloc.OutOfMemory`;
* ``kv_stats`` shows pages returned per *eviction* (not per group);
* short tails are masked empty slots, never duplicated requests (the
  old ``_make_groups`` padding bug);
* an injected device-side DAG failure surfaces the typed error on the
  affected request while siblings complete (ROADMAP item 5 seed).

The scheduler-only scenarios run on the deterministic
:class:`~repro.serving.executor.StubExecutor` — same engine, same DAG,
same BufferPool paging, no tracing — with
``StubExecutor.expected_tokens`` as the closed-form oracle.
"""

import numpy as np
import pytest

from repro.core.errors import (DeviceLostError, InvalidArgError,
                               ReproError)
from repro.runtime.bufalloc import OutOfMemory
from repro.serving import Request, RequestState, ServingEngine, StubExecutor


def stub_engine(slots=2, max_seq=64, **kw):
    ex = StubExecutor(batch_slots=slots, max_seq=max_seq)
    return ServingEngine(None, None, None, batch_slots=slots,
                         max_seq=max_seq, executor=ex, **kw), ex


def req(rng, plen=None, max_new=4, **kw):
    plen = plen or int(rng.integers(3, 9))
    return Request(prompt=rng.integers(0, 500, plen).astype(np.int32),
                   max_new_tokens=max_new, **kw)


def expect(r):
    return StubExecutor.expected_tokens(r.prompt, r.max_new_tokens,
                                        eos_token=r.eos_token)


# --------------------------------------------------------------------------
# same-step refill
# --------------------------------------------------------------------------

def test_eviction_refills_slot_on_same_step():
    eng, ex = stub_engine(slots=1)
    a = Request(prompt=np.arange(3, dtype=np.int32), max_new_tokens=2)
    b = Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=3)
    eng.submit(a)
    eng.submit(b)
    eng.step()                      # prefill a -> token 0
    out = eng.step()                # decode finishes a; b refills NOW
    assert a in out and a.done
    # b was admitted and prefilled within the same step() call
    assert b.state == RequestState.RUNNING
    assert len(b.out_tokens) == 1
    eng.drain()
    assert b.out_tokens == expect(b)


def test_long_request_no_longer_stalls_neighbours():
    """One long generation plus many short ones: with continuous
    batching the shorts flow through the freed slot while the long one
    keeps decoding; the fixed baseline barriers on the long request."""
    def serve(scheduler):
        eng, ex = stub_engine(slots=2, scheduler=scheduler)
        rng = np.random.default_rng(0)
        long = req(rng, plen=5, max_new=24)
        shorts = [req(rng, max_new=2) for _ in range(5)]
        for r in [long] + shorts:
            eng.submit(r)
        eng.drain()
        assert long.out_tokens == expect(long)
        for r in shorts:
            assert r.out_tokens == expect(r)
        return ex.decode_calls

    continuous, fixed = serve("continuous"), serve("fixed")
    # fixed-slot pays a full barriered round per short-request group
    assert continuous < fixed


# --------------------------------------------------------------------------
# bitwise-identical to serial execution (real model)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_continuous_tokens_bitwise_identical_to_serial():
    import jax

    from repro import configs
    from repro.distributed.sharding import BASELINE_RULES
    from repro.models import init_params

    cfg = configs.get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, int(n)).astype(np.int32)
               for n in (4, 6, 5, 7)]
    budgets = [3, 5, 2, 4]

    # serial oracle: one request at a time, batch width 1
    serial = ServingEngine(cfg, params, BASELINE_RULES, batch_slots=1,
                           max_seq=32)
    serial_out = []
    for p, m in zip(prompts, budgets):
        r = Request(prompt=p.copy(), max_new_tokens=m)
        serial.generate([r])
        serial_out.append(r.out_tokens)

    # continuous engine: all requests co-resident across 2 slots, with
    # staggered arrivals so slot assignments interleave
    eng = ServingEngine(cfg, params, BASELINE_RULES, batch_slots=2,
                        max_seq=32)
    reqs = [Request(prompt=p.copy(), max_new_tokens=m)
            for p, m in zip(prompts, budgets)]
    pending = list(reqs)
    while pending or eng.scheduler_stats["waiting"] or \
            eng.scheduler_stats["running"]:
        if pending:
            eng.submit(pending.pop(0))
        eng.step()
    for r, ref in zip(reqs, serial_out):
        assert r.done and r.out_tokens == ref, \
            "continuous batching changed a request's token stream"


# --------------------------------------------------------------------------
# OOM preemption
# --------------------------------------------------------------------------

def test_oom_preemption_requeues_without_loss():
    ex = StubExecutor(batch_slots=2, max_seq=64, bytes_per_token=64)
    # page = 4 tokens * 64 B; budget of 12 pages cannot hold two
    # requests growing to ~38 tokens each
    eng = ServingEngine(None, None, None, batch_slots=2, max_seq=64,
                        executor=ex, page_tokens=4,
                        kv_budget_bytes=12 * 4 * 64)
    rng = np.random.default_rng(1)
    r1, r2 = req(rng, plen=8, max_new=30), req(rng, plen=9, max_new=30)
    eng.submit(r1)
    eng.submit(r2)
    done = eng.drain()
    assert {id(r) for r in done} == {id(r1), id(r2)}
    # zero dropped: both completed despite preemption, typed error kept
    assert r1.done and r2.done
    assert eng.scheduler_stats["preemptions"] >= 1
    assert isinstance(eng.last_oom, OutOfMemory)
    assert isinstance(eng.last_oom, ReproError)
    assert eng.last_oom.code == -4
    # recompute-style preemption regenerated identical streams
    assert r1.out_tokens == expect(r1)
    assert r2.out_tokens == expect(r2)
    # the preempted request observed at least one restart
    assert r1.preemptions + r2.preemptions == \
        eng.scheduler_stats["preemptions"]
    assert eng.kv_stats["pages_live"] == 0


def test_preemption_victim_is_lowest_priority_latest_arrival():
    ex = StubExecutor(batch_slots=2, max_seq=64, bytes_per_token=64)
    eng = ServingEngine(None, None, None, batch_slots=2, max_seq=64,
                        executor=ex, page_tokens=4,
                        kv_budget_bytes=10 * 4 * 64)
    rng = np.random.default_rng(2)
    hi = req(rng, plen=6, max_new=28, priority=1)
    lo = req(rng, plen=6, max_new=28, priority=0)
    eng.submit(hi)
    eng.submit(lo)
    eng.drain()
    assert hi.done and lo.done
    assert lo.preemptions >= 1, "low priority should be the victim"
    assert hi.preemptions == 0
    assert hi.out_tokens == expect(hi) and lo.out_tokens == expect(lo)


def test_sole_resident_oom_fails_typed():
    """A request that cannot fit even alone fails with the typed
    OutOfMemory instead of livelocking the scheduler."""
    ex = StubExecutor(batch_slots=1, max_seq=64, bytes_per_token=64)
    eng = ServingEngine(None, None, None, batch_slots=1, max_seq=64,
                        executor=ex, page_tokens=4,
                        kv_budget_bytes=3 * 4 * 64)   # 12 tokens max
    r = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=30)
    eng.submit(r)
    eng.drain()
    assert not r.done and r.state == RequestState.FAILED
    assert isinstance(r.error, OutOfMemory)
    assert eng.kv_stats["pages_live"] == 0


# --------------------------------------------------------------------------
# paged KV accounting
# --------------------------------------------------------------------------

def test_kv_stats_pages_returned_per_eviction():
    eng, ex = stub_engine(slots=2, page_tokens=4)
    rng = np.random.default_rng(3)
    reqs = [req(rng, plen=6, max_new=3) for _ in range(4)]
    frees_after = []
    evicted = 0
    for r in reqs:
        eng.submit(r)
    while any(not (r.done or r.error) for r in reqs):
        done = eng.step()
        if done:
            evicted += len(done)
            frees_after.append(eng.kv_stats["frees"])
    # frees grow with every eviction step (pages return per request,
    # not one block per group at the end)
    assert evicted == 4
    assert all(b > a for a, b in zip(frees_after, frees_after[1:])), \
        frees_after
    st = eng.kv_stats
    # every allocated page came back, page by page
    assert st["pages_live"] == 0 and st["kv_used_bytes"] == 0
    sched = eng.scheduler_stats
    assert sched["pages_freed"] == sched["pages_allocated"]
    # each request needed ceil((plen + new) / page_tokens) >= 2 pages
    assert sched["pages_allocated"] >= 2 * len(reqs)


def test_kv_pages_sized_from_executor_footprint():
    ex = StubExecutor(batch_slots=2, max_seq=64, bytes_per_token=128)
    eng = ServingEngine(None, None, None, batch_slots=2, max_seq=64,
                        executor=ex, page_tokens=8)
    st = eng.kv_stats
    assert st["bytes_per_token"] == 128
    assert st["page_bytes"] == 128 * 8
    assert st["kv_bytes_per_group"] == ex.cache_bytes(2, 64)


# --------------------------------------------------------------------------
# short tails: masked empty slots, no duplicate compute
# --------------------------------------------------------------------------

def test_tail_requests_not_duplicated():
    """Regression for the _make_groups padding bug: 3 requests on 2
    slots used to pad the tail group with a duplicated request."""
    eng, ex = stub_engine(slots=2)
    rng = np.random.default_rng(4)
    reqs = [req(rng, max_new=3) for _ in range(3)]
    done = eng.generate(reqs)
    assert len(done) == 3
    # exactly one prefill per submitted request — no duplicate compute
    assert ex.prefill_calls == 3
    for r in reqs:
        assert r.out_tokens == expect(r)


def test_single_request_on_wide_engine():
    eng, ex = stub_engine(slots=4)
    r = Request(prompt=np.arange(5, dtype=np.int32), max_new_tokens=4)
    eng.submit(r)
    eng.drain()
    assert r.done and ex.prefill_calls == 1
    assert r.out_tokens == expect(r)


# --------------------------------------------------------------------------
# fault injection (ROADMAP item 5 seed)
# --------------------------------------------------------------------------

def test_decode_fault_fails_one_request_siblings_complete():
    eng, ex = stub_engine(slots=2)
    rng = np.random.default_rng(5)
    good, bad, late = req(rng, max_new=6), req(rng, max_new=6), \
        req(rng, max_new=2)
    eng.submit(good)
    eng.submit(bad)
    eng.submit(late)
    eng.inject_fault(bad, stage="decode")
    eng.drain()
    # the injected device-side failure surfaced as the typed error on
    # exactly the affected request's result
    assert not bad.done and bad.state == RequestState.FAILED
    assert isinstance(bad.error, DeviceLostError)
    assert isinstance(bad.error, ReproError) and bad.error.code == -2
    # siblings (co-resident and queued-behind) completed, bit-exact
    assert good.done and good.out_tokens == expect(good)
    assert late.done and late.out_tokens == expect(late)
    # the failed request's pages came back
    assert eng.kv_stats["pages_live"] == 0


def test_prefill_fault_fails_one_request_siblings_complete():
    eng, ex = stub_engine(slots=2)
    rng = np.random.default_rng(6)
    good, bad = req(rng, max_new=4), req(rng, max_new=4)
    eng.submit(good)
    eng.submit(bad)
    eng.inject_fault(bad, stage="prefill",
                     error=DeviceLostError("boom"))
    eng.drain()
    assert isinstance(bad.error, DeviceLostError)
    assert str(bad.error) == "boom"
    assert good.done and good.out_tokens == expect(good)
    assert eng.kv_stats["pages_live"] == 0


def test_inject_fault_validates():
    eng, ex = stub_engine()
    r = Request(prompt=np.arange(4, dtype=np.int32))
    with pytest.raises(InvalidArgError):
        eng.inject_fault(r)             # not submitted yet
    eng.submit(r)
    with pytest.raises(InvalidArgError):
        eng.inject_fault(r, stage="warp-core")
    with pytest.raises(InvalidArgError):
        eng.inject_fault(r, stage="device")   # replica loss: no request
    with pytest.raises(InvalidArgError):
        eng.inject_fault(stage="decode")      # per-request: needs one


# --------------------------------------------------------------------------
# replica-level device loss (mesh failure ladder, docs/mesh.md)
# --------------------------------------------------------------------------

def test_device_loss_fails_all_residents_at_once_typed():
    eng, ex = stub_engine(slots=2)
    rng = np.random.default_rng(7)
    a, b = req(rng, max_new=8), req(rng, max_new=8)
    eng.submit(a)
    eng.submit(b)
    eng.step()                          # both resident, decoding
    eng.inject_fault(stage="device")
    out = eng.step()                    # the loss fires mid-decode
    # every resident failed at once, with the SAME typed error object
    assert {r.id for r in out} == {a.id, b.id}
    assert all(r.state == RequestState.FAILED for r in out)
    assert isinstance(a.error, DeviceLostError) and a.error.code == -2
    assert a.error is b.error is eng.device_lost
    # pages drained to zero on the dead replica
    assert eng.kv_stats["pages_live"] == 0
    assert eng.kv_stats["kv_used_bytes"] == 0


def test_device_loss_leaves_waiting_requests_reclaimable():
    eng, ex = stub_engine(slots=1)
    rng = np.random.default_rng(8)
    resident, queued = req(rng, max_new=8), req(rng, max_new=4)
    eng.submit(resident)
    eng.submit(queued)
    eng.step()
    eng.inject_fault(stage="device")
    eng.step()
    # the engine is terminal: it cannot run the queued work nor accept
    # more — both surface the typed error instead of hanging
    with pytest.raises(DeviceLostError):
        eng.drain()
    with pytest.raises(DeviceLostError):
        eng.submit(req(rng))
    assert eng.step() == []             # terminal: steps are no-ops
    # the waiting request is untouched (no error) and reclaimable for
    # migration; once reclaimed the engine drains empty
    assert queued.error is None
    assert eng.release_waiting() == [queued]
    assert eng.release_waiting() == []
    assert eng.drain() == []


def test_device_loss_on_one_engine_leaves_siblings_unaffected():
    """Regression (ISSUE 9 satellite): a replica-level loss is scoped to
    its engine — requests on a sibling engine sharing the process (and
    the default platform) complete bit-exact."""
    lost_eng, _ = stub_engine(slots=2)
    ok_eng, _ = stub_engine(slots=2)
    rng = np.random.default_rng(9)
    doomed = [req(rng, max_new=6) for _ in range(2)]
    fine = [req(rng, max_new=6) for _ in range(3)]
    for r in doomed:
        lost_eng.submit(r)
    for r in fine:
        ok_eng.submit(r)
    lost_eng.step()
    ok_eng.step()
    lost_eng.inject_fault(stage="device")
    lost_eng.step()
    ok_eng.drain()
    assert all(isinstance(r.error, DeviceLostError) for r in doomed)
    assert all(r.done and r.out_tokens == expect(r) for r in fine)
    assert lost_eng.kv_stats["pages_live"] == 0
    assert ok_eng.kv_stats["pages_live"] == 0


def test_front_submit_runs_before_earlier_arrivals():
    eng, ex = stub_engine(slots=1)
    rng = np.random.default_rng(10)
    first, second, migrated = req(rng), req(rng), req(rng, max_new=2)
    eng.submit(first)
    eng.submit(second)
    eng.submit(migrated, front=True)    # mesh requeue path
    eng.drain()
    # single slot => strict completion order: front-submitted first
    assert migrated.finish_step <= first.finish_step <= second.finish_step
    assert migrated.out_tokens == expect(migrated)


# --------------------------------------------------------------------------
# admission / API
# --------------------------------------------------------------------------

def test_submit_rejects_impossible_prompts():
    eng, ex = stub_engine(slots=2, max_seq=16)
    with pytest.raises(InvalidArgError):
        eng.submit(Request(prompt=np.zeros(0, np.int32)))
    with pytest.raises(InvalidArgError):
        eng.submit(Request(prompt=np.zeros(16, np.int32)))


def test_eos_token_stops_generation():
    eng, ex = stub_engine()
    rng = np.random.default_rng(8)
    r = req(rng, plen=5, max_new=40)
    stream = StubExecutor.expected_tokens(r.prompt, 40)
    r.eos_token = stream[3]             # stop at the 4th token
    eng.submit(r)
    eng.drain()
    assert r.done and r.out_tokens == stream[:4]


def test_fixed_scheduler_is_a_refill_barrier():
    eng, ex = stub_engine(slots=2, scheduler="fixed")
    rng = np.random.default_rng(9)
    reqs = [req(rng, max_new=m) for m in (2, 5, 3)]
    for r in reqs:
        eng.submit(r)
    eng.step()                           # admits exactly the first two
    assert eng.scheduler_stats["running"] == 2
    assert reqs[2].state == RequestState.WAITING
    eng.step()
    eng.step()                           # reqs[0] done; slot stays empty
    assert reqs[0].done
    assert reqs[2].state == RequestState.WAITING, \
        "fixed scheduler refilled before the barrier"
    eng.drain()
    for r in reqs:
        assert r.out_tokens == expect(r)


def test_scheduler_arg_validated():
    with pytest.raises(InvalidArgError):
        ServingEngine(None, None, None, batch_slots=1, max_seq=16,
                      executor=StubExecutor(1, 16), scheduler="magic")
    with pytest.raises(InvalidArgError):
        ServingEngine(None, None, None, batch_slots=2, max_seq=16,
                      executor=StubExecutor(4, 16))   # shape mismatch
