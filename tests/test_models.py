"""Per-architecture smoke tests: every assigned arch instantiates its
REDUCED config and runs forward + loss + prefill/decode on CPU, asserting
shapes, finiteness, and decode-vs-teacher-forced consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.distributed.sharding import BASELINE_RULES
from repro.models import (forward, loss_fn, init_params, init_caches,
                          cache_logical_axes, model_defs)
from repro.models.params import param_pspecs, count_params

B, S = 2, 32


def make_batch(cfg, rng):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_loss(arch):
    cfg = configs.get_smoke(arch)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg,
                                                 BASELINE_RULES))(params,
                                                                  batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    logits, aux, _ = forward(params, batch["tokens"], cfg, BASELINE_RULES,
                             aux_inputs={k: v for k, v in batch.items()
                                         if k not in ("tokens", "targets")},
                             mode="train")
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode through the cache must match a teacher-forced full
    forward at the same position (bf16 tolerance)."""
    cfg = configs.get_smoke(arch)
    rng = np.random.default_rng(1)
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, rng)
    aux = {k: v for k, v in batch.items() if k not in ("tokens", "targets")}

    caches = init_caches(cfg, B, S + 8)
    logits_p, _, caches = forward(params, batch["tokens"], cfg,
                                  BASELINE_RULES, aux_inputs=aux,
                                  caches=caches, mode="prefill")
    tok = jnp.argmax(logits_p[:, -1:], axis=-1).astype(jnp.int32)
    logits_d, _, caches = forward(params, tok, cfg, BASELINE_RULES,
                                  aux_inputs=aux, caches=caches,
                                  mode="decode")
    full = jnp.concatenate([batch["tokens"], tok], axis=1)
    logits_full, _, _ = forward(params, full, cfg, BASELINE_RULES,
                                aux_inputs=aux, mode="train")
    a = np.asarray(logits_d[:, 0], np.float32)
    b = np.asarray(logits_full[:, -1], np.float32)
    # compare normalized top-token agreement + logit closeness
    assert np.argmax(a, -1).tolist() == np.argmax(b, -1).tolist() or \
        np.max(np.abs(a - b)) < 0.25
    assert np.max(np.abs(a - b)) < 0.5


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_param_table(arch):
    """The FULL config's parameter table builds (no allocation) and every
    leaf has a consistent logical-spec entry."""
    cfg = configs.get_config(arch)
    defs = model_defs(cfg)
    n = count_params(defs)
    assert n > 1e8, f"{arch}: only {n} params"
    specs = param_pspecs(defs, BASELINE_RULES)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: x is None)
    assert leaves


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-7b"])
def test_ssm_archs_have_state_caches(arch):
    cfg = configs.get_smoke(arch)
    caches = init_caches(cfg, 2, 64)
    assert "ssd" in caches and "conv_x" in caches
    ax = cache_logical_axes(cfg)
    assert set(ax) == set(caches)


def test_moe_load_balance_aux_positive():
    cfg = configs.get_smoke("phi3.5-moe-42b-a6.6b")
    rng = np.random.default_rng(3)
    params = init_params(cfg, jax.random.PRNGKey(3))
    batch = make_batch(cfg, rng)
    _, metrics = loss_fn(params, batch, cfg, BASELINE_RULES)
    assert float(metrics["aux"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz


def test_streaming_ce_matches_standard():
    """Fused vocab-chunked CE (blocked_ce.py): loss identical, grads
    exact in f32 (in bf16 the STANDARD path loses precision via its
    logits-cast cotangent; streaming never materializes logits)."""
    import dataclasses
    base = configs.get_smoke("llama-3.2-vision-11b")
    cfg0 = dataclasses.replace(base, dtype="float32")
    cfg1 = dataclasses.replace(base, dtype="float32",
                               use_streaming_ce=True, ce_chunk=128)
    rng = np.random.default_rng(0)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    batch = make_batch(cfg0, rng)
    (l0, _), g0 = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg0, BASELINE_RULES),
        has_aux=True)(params)
    (l1, _), g1 = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg1, BASELINE_RULES),
        has_aux=True)(params)
    assert float(l0) == pytest.approx(float(l1), abs=1e-5)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-3)
