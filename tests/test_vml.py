"""Vecmathlib (paper §5) accuracy tests: polynomial/bit-twiddling
implementations vs the libm-quality jnp references, over wide ranges and
both float dtypes, plus hypothesis sweeps."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro import vml


# (lo, hi, reference, rtol, atol) — atol covers zero crossings / underflow
# where relative error is meaningless (e.g. sin near k*pi)
RANGES = {
    "exp": (-80.0, 80.0, jnp.exp, 4e-6, 0.0),
    "log": (1e-30, 1e30, jnp.log, 4e-6, 1e-6),
    "sin": (-50.0, 50.0, jnp.sin, 2e-5, 2e-7),
    "cos": (-50.0, 50.0, jnp.cos, 2e-5, 2e-7),
    "sqrt": (0.0, 1e30, jnp.sqrt, 2e-6, 0.0),
    "rsqrt": (1e-30, 1e30, jax.lax.rsqrt, 4e-6, 0.0),
    "reciprocal": (1e-30, 1e30, lambda x: 1.0 / x, 4e-6, 0.0),
    "tanh": (-20.0, 20.0, jnp.tanh, 4e-5, 2e-7),
    "sigmoid": (-30.0, 30.0, jax.nn.sigmoid, 4e-5, 2e-7),
    "erf": (-5.0, 5.0, jax.scipy.special.erf, 1e-3, 1e-6),
}


@pytest.mark.parametrize("name", sorted(RANGES))
def test_vml_accuracy_f32(name):
    lo, hi, ref_fn, rtol, atol = RANGES[name]
    rng = np.random.default_rng(42)
    if lo >= 0:   # log-uniform for positive-domain functions
        x = np.exp(rng.uniform(np.log(max(lo, 1e-30)),
                               np.log(hi), 20_000)).astype(np.float32)
    else:
        x = rng.uniform(lo, hi, 20_000).astype(np.float32)
    got = np.asarray(getattr(vml, name)(jnp.asarray(x)), np.float64)
    want = np.asarray(ref_fn(jnp.asarray(x)), np.float64)
    err = np.abs(got - want) - (atol + rtol * np.abs(want))
    worst = np.nanmax(err)
    assert worst <= 0, \
        f"{name}: worst excess err {worst:.2e} at x={x[np.nanargmax(err)]}"


def test_vml_special_values():
    x = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan], jnp.float32)
    assert np.isnan(float(vml.exp(x)[4]))
    assert float(vml.exp(x)[2]) == np.inf
    assert float(vml.exp(x)[3]) == 0.0
    assert float(vml.sqrt(x)[0]) == 0.0
    # fabs/signbit/copysign: pure bit manipulation (§5.1)
    assert float(vml.fabs(jnp.float32(-3.5))) == 3.5
    assert bool(vml.signbit(jnp.float32(-0.0)))
    assert not bool(vml.signbit(jnp.float32(0.0)))
    assert float(vml.copysign(jnp.float32(2.0), jnp.float32(-1.0))) == -2.0


def test_vml_bfloat16_roundtrip():
    """bf16 inputs evaluate in f32 and cast back (the paper's 'evaluate
    single precision in single precision' point)."""
    x = jnp.linspace(-4, 4, 256).astype(jnp.bfloat16)
    for name in ("exp", "sin", "tanh", "silu", "gelu_tanh", "sigmoid"):
        y = getattr(vml, name)(x)
        assert y.dtype == jnp.bfloat16, name


@settings(max_examples=40, deadline=None)
@given(st.floats(-80, 80, allow_nan=False, width=32))
def test_exp_pointwise(x):
    got = float(vml.exp(jnp.float32(x)))
    want = float(np.exp(np.float64(x)))
    assert got == pytest.approx(want, rel=1e-5, abs=1e-38)


@settings(max_examples=40, deadline=None)
@given(st.floats(-50, 50, allow_nan=False, width=32))
def test_sin_pointwise(x):
    got = float(vml.sin(jnp.float32(x)))
    want = float(np.sin(np.float64(x)))
    assert got == pytest.approx(want, rel=1e-4, abs=2e-5)


def test_activations_match_jax():
    x = jnp.linspace(-10, 10, 4096, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(vml.silu(x)),
                               np.asarray(jax.nn.silu(x)),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(vml.gelu_tanh(x)),
                               np.asarray(jax.nn.gelu(x, approximate=True)),
                               atol=2e-5, rtol=2e-5)


def test_bit_manipulation_gradients():
    """Regression: bitcast-based fabs/copysign silently produced ZERO
    gradients (found via exploding grad norms at 30-layer depth — the
    silu gate lost its x·sigmoid' term).  The bit-twiddled primitives
    carry custom JVPs now."""
    x = jnp.linspace(-4.0, 4.0, 33)
    for name, ref in (("silu", jax.nn.silu),
                      ("gelu_tanh",
                       lambda v: jax.nn.gelu(v, approximate=True)),
                      ("sigmoid", jax.nn.sigmoid),
                      ("erf", jax.scipy.special.erf)):
        g = jax.vmap(jax.grad(getattr(vml, name)))(x)
        gr = jax.vmap(jax.grad(ref))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                                   atol=2e-5, rtol=1e-4, err_msg=name)
    gf = jax.vmap(jax.grad(vml.fabs))(x)
    want = np.where(np.asarray(x) < 0, -1.0, 1.0)   # jax convention at 0
    np.testing.assert_allclose(np.asarray(gf), want)
