"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs the ref.py
pure-jnp oracle (interpret=True executes the kernel body on CPU)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def rnd(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale).astype(dtype)


ATTN_SHAPES = [
    # B, S, H, KV, D, causal
    (1, 128, 4, 4, 64, True),
    (2, 128, 4, 2, 64, True),
    (2, 256, 8, 1, 64, True),
    (1, 256, 4, 4, 128, False),
]


@pytest.mark.parametrize("B,S,H,KV,D,causal", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(B, S, H, KV, D, causal, dtype):
    rng = np.random.default_rng(0)
    q = rnd(rng, (B, S, H, D), dtype)
    k = rnd(rng, (B, S, KV, D), dtype)
    v = rnd(rng, (B, S, KV, D), dtype)
    out = ops.attention(q, k, v, causal=causal, use_pallas=True,
                        block_q=128, block_k=128)
    want = ref.attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


DECODE_SHAPES = [
    (1, 4, 4, 64, 256),
    (2, 8, 2, 64, 512),
    (4, 8, 1, 128, 256),
]


@pytest.mark.parametrize("B,H,KV,D,S", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_kernel(B, H, KV, D, S, dtype):
    rng = np.random.default_rng(1)
    q = rnd(rng, (B, H, D), dtype)
    kc = rnd(rng, (B, KV, S, D), dtype)
    vc = rnd(rng, (B, KV, S, D), dtype)
    lengths = jnp.asarray(rng.integers(1, S, (B,)), jnp.int32)
    out = ops.decode_attention(q, kc, vc, lengths, use_pallas=True)
    want = ref.decode_attention(q, kc, vc, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("rows,d", [(8, 256), (16, 512), (4, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(rows, d, dtype):
    rng = np.random.default_rng(2)
    x = rnd(rng, (rows, d), dtype)
    w = rnd(rng, (d,), jnp.float32)
    out = ops.rmsnorm(x, w, use_pallas=True)
    want = ref.rmsnorm(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


SSD_SHAPES = [
    (1, 128, 4, 64, 16, 64),
    (2, 256, 8, 32, 32, 64),
    (1, 64, 2, 64, 64, 32),
]


@pytest.mark.parametrize("B,L,H,P,N,chunk", SSD_SHAPES)
def test_ssd_scan_kernel(B, L, H, P, N, chunk):
    rng = np.random.default_rng(3)
    x = rnd(rng, (B, L, H, P), scale=0.1)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, (H,)), jnp.float32)
    Bm = rnd(rng, (B, L, 1, N), scale=0.1)
    Cm = rnd(rng, (B, L, 1, N), scale=0.1)
    y1, s1 = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, use_pallas=True)
    y2, s2 = ref.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, return_state=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_ssd_scan_matches_sequential_recurrence():
    """The chunked SSD formulation equals the literal per-step recurrence."""
    rng = np.random.default_rng(4)
    B, L, H, P, N = 1, 32, 2, 8, 4
    x = rnd(rng, (B, L, H, P), scale=0.3)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, (H,)), jnp.float32)
    Bm = rnd(rng, (B, L, 1, N), scale=0.3)
    Cm = rnd(rng, (B, L, 1, N), scale=0.3)
    y_chunk = ref.ssd_scan(x, dt, A, Bm, Cm, chunk=8)

    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        y_t, state = ref.ssd_decode_step(state, x[:, t], dt[:, t], A,
                                         Bm[:, t], Cm[:, t])
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in half and carrying the state must equal one
    pass over the full sequence (prefill->decode handoff invariant)."""
    rng = np.random.default_rng(5)
    B, L, H, P, N = 1, 64, 2, 16, 8
    x = rnd(rng, (B, L, H, P), scale=0.2)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, (H,)), jnp.float32)
    Bm = rnd(rng, (B, L, 1, N), scale=0.2)
    Cm = rnd(rng, (B, L, 1, N), scale=0.2)
    y_full, s_full = ref.ssd_scan(x, dt, A, Bm, Cm, chunk=16,
                                  return_state=True)
    half = L // 2
    y1, s1 = ref.ssd_scan(x[:, :half], dt[:, :half], A, Bm[:, :half],
                          Cm[:, :half], chunk=16, return_state=True)
    y2, s2 = ref.ssd_scan(x[:, half:], dt[:, half:], A, Bm[:, half:],
                          Cm[:, half:], chunk=16, initial_state=s1,
                          return_state=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)
