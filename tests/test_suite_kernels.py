"""Conformance tests for the repro.suite kernels (docs/scoreboard.md).

Every suite kernel must reproduce its NumPy oracle *bitwise* on every
compiled target and every point of its tuning space — the suite's data
conventions (integer-valued float32 operands, dyadic stencil weights,
association-matched oracles) exist precisely to make that comparison
well-defined under FMA contraction.  Co-executed launches must match the
single-device result bitwise too (the scheduler's split/merge identity).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.runtime import Context
from repro.suite import SUITE, param_key, suite_kernels

TARGETS = ("loop", "vector", "pallas")


@pytest.fixture(scope="module")
def ctx():
    return Context()


def _launch(ctx, sk, shape, params, inputs, target=None, device=None):
    kern = ctx.create_program(sk.build(shape, params)).create_kernel()
    kern.set_args(**{k: v.copy() for k, v in inputs.items()})
    gsz, lsz = sk.launch_dims(shape, params)
    return ctx.launch(kern, gsz, lsz, target=target, device=device)


def _assert_bitwise(out, expected, label):
    for name, exp in expected.items():
        got = np.asarray(out[name])
        assert got.tobytes() == exp.tobytes(), (
            f"{label}: output {name!r} differs from oracle "
            f"(max abs diff {np.abs(got.astype(np.float64) - exp.astype(np.float64)).max()})")


def test_registry_shape():
    """The suite is the scoreboard's contract: >= 5 kernels, each with
    ci+full shapes, >= 2 tuning configs, and distinct config keys."""
    assert len(SUITE) >= 5
    for sk in suite_kernels():
        assert {"full", "ci"} <= set(sk.shapes)
        for which in ("full", "ci"):
            space = sk.space(sk.shapes[which])
            assert len(space) >= 2
            keys = [param_key(p) for p in space]
            assert len(set(keys)) == len(keys)
        assert sk.flops(sk.shapes["ci"]) > 0
        assert sk.bytes_moved(sk.shapes["ci"]) > 0


@pytest.mark.parametrize("name", sorted(SUITE))
def test_conformance_all_targets_all_configs(ctx, name):
    """Bitwise oracle equality on every (config, target) cell."""
    sk = SUITE[name]
    shape = sk.shapes["ci"]
    for params in sk.space(shape):
        inputs = sk.make_inputs(shape, params)
        expected = sk.oracle(inputs, shape, params)
        assert set(sk.outputs) == set(expected)
        for tgt in TARGETS:
            out = _launch(ctx, sk, shape, params, inputs, target=tgt)
            _assert_bitwise(out, expected,
                            f"{name}[{param_key(params)}] on {tgt}")


@pytest.mark.parametrize("name", ["gemm", "hist"])
def test_coexec_matches_single_device(ctx, name):
    """2-device co-execution is bitwise-identical to the single-device
    launch (and hence to the oracle): the scheduler's split/merge must
    be invisible, including for 2-D NDRanges and group-indexed outputs."""
    sk = SUITE[name]
    shape = sk.shapes["ci"]
    params = sk.space(shape)[0]
    inputs = sk.make_inputs(shape, params)
    expected = sk.oracle(inputs, shape, params)
    gsz, lsz = sk.launch_dims(shape, params)

    co = ctx.create_co_executor(ctx.platform.co_devices(2))
    kern = ctx.create_program(sk.build(shape, params)).create_kernel()
    kern.set_args(**{k: v.copy() for k, v in inputs.items()})
    for mode in ("static", "steal"):
        out = co.launch(kern, gsz, lsz, mode=mode)
        _assert_bitwise(out, expected, f"{name} coexec[{mode}]")
    co.finish()


@pytest.mark.parametrize("name", ["spmv", "scan"])
def test_fiber_reference_agrees(name):
    """The fiber interpreter (the DSL's semantics oracle) agrees with
    the NumPy oracle bitwise — i.e. the oracles encode the kernels'
    actual accumulation order, not just the right mathematics."""
    from repro.core.interp import run_ndrange  # noqa: TID251 — oracle use
    sk = SUITE[name]
    shape = sk.shapes["ci"]
    params = sk.space(shape)[0]
    inputs = sk.make_inputs(shape, params)
    expected = sk.oracle(inputs, shape, params)
    gsz, lsz = sk.launch_dims(shape, params)
    out = run_ndrange(sk.build(shape, params)(), gsz, lsz,
                      {k: v.copy() for k, v in inputs.items()})
    _assert_bitwise(out, expected, f"{name} fiber")


def test_inputs_deterministic():
    """Input generation is a pure function of (kernel, shape): two calls
    yield identical operands, so sweep configurations are comparable."""
    sk = SUITE["gemm"]
    shape = sk.shapes["ci"]
    a = sk.make_inputs(shape, sk.space(shape)[0])
    b = sk.make_inputs(shape, sk.space(shape)[1])
    for name in ("A", "B"):
        assert a[name].tobytes() == b[name].tobytes()


def test_mul_add_inputs_are_fma_safe():
    """The FMA-safety convention holds: every multiply-accumulate
    kernel's float operands are integer-valued (exactly representable
    products/sums), so bitwise comparison is target-independent."""
    for name in ("gemm", "spmv", "stencil1d", "stencil2d"):
        sk = SUITE[name]
        shape = sk.shapes["ci"]
        inputs = sk.make_inputs(shape, sk.space(shape)[0])
        for arg, v in inputs.items():
            if v.dtype == np.float32 and arg not in sk.outputs:
                assert np.all(v == np.round(v)), (name, arg)
