"""Property fuzz for the suite's tiled GEMM (repro.suite.kernels).

The GEMM builder's hard cases are the tiling edges: matrix dimensions
that are not multiples of the tile size (ragged boundary tiles on every
side), K smaller than one tile, and local sizes that do not divide the
global size evenly.  A seeded random sweep runs on every install;
hypothesis (when installed — the CI profile, see conftest.py) widens the
same properties.  Everything checks bitwise equality with the NumPy
oracle on the vector target — the lane-predicated mapping, where a
missed guard shows up as garbage in the ragged rows.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.runtime import Context
from repro.suite import SUITE

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:               # seeded sweeps below still run
    HAVE_HYPOTHESIS = False

_CTX = Context()


def _check_gemm(m, n, k, ts, unroll, target):
    sk = SUITE["gemm"]
    shape = {"m": m, "n": n, "k": k}
    params = {"ts": ts, "unroll": unroll}
    inputs = sk.make_inputs(shape, params)
    expected = sk.oracle(inputs, shape, params)["C"]
    kern = _CTX.create_program(sk.build(shape, params)).create_kernel()
    kern.set_args(**{a: v.copy() for a, v in inputs.items()})
    gsz, lsz = sk.launch_dims(shape, params)
    assert all(g % l == 0 for g, l in zip(gsz, lsz)), \
        "launch_dims must pad global size to a local-size multiple"
    out = _CTX.launch(kern, gsz, lsz, target=target)
    got = np.asarray(out["C"])
    assert got.shape == expected.shape
    assert got.tobytes() == expected.tobytes(), (
        f"gemm m={m} n={n} k={k} ts={ts} unroll={unroll} {target}: "
        f"max abs diff "
        f"{np.abs(got.astype(np.float64) - expected.astype(np.float64)).max()}")


def _check_stencil1d(n, lsz, use_local):
    sk = SUITE["stencil1d"]
    shape = {"n": n}
    params = {"lsz": lsz, "use_local": int(use_local)}
    inputs = sk.make_inputs(shape, params)
    expected = sk.oracle(inputs, shape, params)["y"]
    kern = _CTX.create_program(sk.build(shape, params)).create_kernel()
    kern.set_args(**{a: v.copy() for a, v in inputs.items()})
    gsz, lsz_t = sk.launch_dims(shape, params)
    out = _CTX.launch(kern, gsz, lsz_t, target="vector")
    assert np.asarray(out["y"]).tobytes() == expected.tobytes(), \
        (n, lsz, use_local)


# ---------------------------------------------------------------------------
# seeded sweeps (run on every install, no hypothesis needed)
# ---------------------------------------------------------------------------

def test_gemm_ragged_seeded_sweep():
    """Deterministic ragged sample: every combination of a dimension
    below / at / above one tile, including degenerate 1-wide shapes."""
    rng = np.random.default_rng(7)
    cases = [(1, 1, 1), (1, 8, 3), (9, 1, 8), (8, 8, 8), (9, 9, 9)]
    cases += [tuple(rng.integers(1, 34, size=3)) for _ in range(6)]
    for m, n, k in cases:
        for ts in (4, 8):
            _check_gemm(int(m), int(n), int(k), ts, 1, "vector")


def test_gemm_ragged_loop_vector_agree_seeded():
    """Loop and vector targets agree bitwise on ragged shapes — the
    serial mapping has no lane predication, so agreement means the
    guards (not the masking machinery) carry the semantics."""
    for m, n, k in [(5, 11, 7), (16, 3, 16), (33, 33, 1)]:
        for target in ("loop", "vector"):
            _check_gemm(m, n, k, 8, 8, target)


def test_stencil1d_local_size_not_dividing_seeded():
    """local_size exceeding or not dividing n: padded launch with
    guarded stores must match the oracle, halo path on and off."""
    for n in (1, 5, 31, 33, 170):
        for lsz in (16, 64):
            for use_local in (0, 1):
                _check_stencil1d(n, lsz, use_local)


# ---------------------------------------------------------------------------
# hypothesis widening (ci/dev profiles, see conftest.py)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25)
    @given(m=st.integers(1, 33), n=st.integers(1, 33), k=st.integers(1, 33),
           ts=st.sampled_from([2, 4, 8]),
           full_unroll=st.booleans())
    def test_gemm_ragged_tiles_vector(m, n, k, ts, full_unroll):
        """Ragged tiles on all three dimensions, vector target: any
        guard or clamp bug corrupts the boundary rows/columns."""
        _check_gemm(m, n, k, ts, ts if full_unroll else 1, "vector")

    @settings(max_examples=10)
    @given(n=st.integers(1, 200), lsz=st.sampled_from([16, 32, 64]),
           use_local=st.booleans())
    def test_stencil1d_ragged_global_size(n, lsz, use_local):
        _check_stencil1d(n, lsz, use_local)

else:                             # keep -q output honest about coverage

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_gemm_ragged_tiles_vector():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_stencil1d_ragged_global_size():
        pass
