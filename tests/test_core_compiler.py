"""Kernel-compiler tests: the paper's §4 machinery.

Every kernel is validated against ``run_ndrange`` — a fiber-style
interpreter that executes work-items with real barrier suspension
(the Clover/Twin-Peaks semantics the paper compares against) — across
both static targets (vector / loop) with and without the horizontal
inner-loop parallelization pass.
"""

import numpy as np
import pytest

from repro.core import KernelBuilder, compile_kernel, run_ndrange


def build_vecadd():
    b = KernelBuilder("vecadd")
    A, B, C = (b.arg_buffer(n, "float32") for n in "ABC")
    gid = b.global_id(0)
    C[gid] = A[gid] + B[gid]
    return b.finish()


def build_unconditional_barrier():
    b = KernelBuilder("uncond")
    x = b.arg_buffer("x", "float32")
    tmp = b.local_array("tmp", "float32", 8)
    lid = b.local_id(0)
    tmp[lid] = x[lid] * 2.0
    b.barrier()
    x[lid] = tmp[(lid + 1) % b.local_size(0)]
    return b.finish()


def build_reduction():
    b = KernelBuilder("reduce")
    inp = b.arg_buffer("inp", "float32")
    out = b.arg_buffer("out", "float32")
    scratch = b.local_array("scratch", "float32", 8)
    lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
    scratch[lid] = inp[gid]
    b.barrier()
    s = b.var(b.const(4), name="s")
    with b.while_loop() as loop:
        loop.cond(s.get() > 0)
        with b.if_(lid < s.get()):
            scratch[lid] = scratch[lid] + scratch[lid + s.get()]
        b.barrier()
        s.set(s.get() / 2)
    with b.if_(lid == 0):
        out[grp] = scratch[0]
    return b.finish()


def build_conditional_barrier():
    b = KernelBuilder("condbar")
    x = b.arg_buffer("x", "float32")
    flag = b.arg_scalar("flag", "int32")
    lid = b.local_id(0)
    with b.if_(flag > 0):
        x[lid] = x[lid] * 2.0
        b.barrier()
        t = b.var(x[(lid + 1) % b.local_size(0)], name="t")
        b.barrier()
        x[lid] = x[lid] + t.get()
    x[lid] = x[lid] + 1.0
    return b.finish()


def build_bloop():
    """Barrier inside a kernel loop (paper §4.5 b-loops).  Race-free:
    all work-items read, sync, write, sync — two barriers per iteration."""
    b = KernelBuilder("bloop")
    x = b.arg_buffer("x", "float32")
    n = b.arg_scalar("n", "int32")
    lid = b.local_id(0)
    i = b.var(b.const(0), name="i")
    with b.while_loop() as loop:
        loop.cond(i.get() < n)
        t = b.var(x[lid] + x[(lid + 1) % b.local_size(0)], name="t")
        b.barrier()
        x[lid] = t.get()
        b.barrier()
        i.set(i.get() + 1)
    return b.finish()


def build_dct_like():
    """Uniform-trip-count inner loop (paper §4.6 / Fig. 9 DCT pattern)."""
    b = KernelBuilder("dct")
    inp = b.arg_buffer("inp", "float32")
    coef = b.arg_buffer("coef", "float32")
    out = b.arg_buffer("out", "float32")
    width = b.arg_scalar("width", "int32")
    lid = b.local_id(0)
    acc = b.var(0.0, name="acc")
    k = b.var(b.const(0), name="k")
    with b.while_loop() as loop:
        loop.cond(k.get() < width)
        acc.set(acc.get() + coef[k.get()] * inp[lid * width + k.get()])
        k.set(k.get() + 1)
    out[lid] = acc.get()
    return b.finish()


def build_divergent():
    b = KernelBuilder("div")
    x = b.arg_buffer("x", "float32")
    lid = b.global_id(0)
    acc = b.var(0.0, name="acc")
    i = b.var(b.const(0), name="i")
    with b.while_loop() as loop:
        loop.cond(i.get() < lid)         # work-item-dependent trip count
        acc.set(acc.get() + 1.0)
        i.set(i.get() + 1)
    with b.if_(lid % 2 == 0):
        acc.set(acc.get() * 10.0)
    x[lid] = acc.get()
    return b.finish()


CASES = {
    "vecadd": (build_vecadd,
               lambda rng: {"A": rng.normal(size=16).astype(np.float32),
                            "B": rng.normal(size=16).astype(np.float32),
                            "C": np.zeros(16, np.float32)},
               (16,), (8,), None),
    "uncond": (build_unconditional_barrier,
               lambda rng: {"x": rng.normal(size=8).astype(np.float32)},
               (8,), (8,), None),
    "reduce": (build_reduction,
               lambda rng: {"inp": rng.normal(size=16).astype(np.float32),
                            "out": np.zeros(2, np.float32)},
               (16,), (8,), None),
    "condbar_taken": (build_conditional_barrier,
                      lambda rng: {"x": rng.normal(size=8).astype(np.float32)},
                      (8,), (8,), {"flag": 1}),
    "condbar_nottaken": (build_conditional_barrier,
                         lambda rng: {"x": rng.normal(size=8)
                                      .astype(np.float32)},
                         (8,), (8,), {"flag": 0}),
    "bloop": (build_bloop,
              lambda rng: {"x": rng.normal(size=8).astype(np.float32)},
              (8,), (8,), {"n": 3}),
    "dct": (build_dct_like,
            lambda rng: {"inp": rng.normal(size=8 * 4).astype(np.float32),
                         "coef": rng.normal(size=4).astype(np.float32),
                         "out": np.zeros(8, np.float32)},
            (8,), (8,), {"width": 4}),
    "divergent": (build_divergent,
                  lambda rng: {"x": np.zeros(8, np.float32)},
                  (8,), (8,), None),
}


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("target", ["vector", "loop"])
@pytest.mark.parametrize("horizontal", [True, False])
def test_kernel_matches_fiber_oracle(case, target, horizontal):
    build, mkbufs, gsz, lsz, scalars = CASES[case]
    rng = np.random.default_rng(hash(case) % 2**31)
    bufs = mkbufs(rng)
    ref = run_ndrange(build(), gsz, lsz,
                      {k: v.copy() for k, v in bufs.items()}, scalars)
    k = compile_kernel(build, lsz, target=target, horizontal=horizontal)
    out = k({key: v.copy() for key, v in bufs.items()}, gsz, scalars)
    for key in bufs:
        np.testing.assert_allclose(out[key], ref[key], rtol=1e-5,
                                   err_msg=f"{case}/{target}/hz={horizontal}"
                                           f" buffer {key}")


def test_region_counts():
    """Barriers split the kernel into the expected parallel regions."""
    k = compile_kernel(build_vecadd, (8,))
    assert k.num_regions >= 1
    k_uncond = compile_kernel(build_unconditional_barrier, (8,))
    assert k_uncond.num_regions > k.num_regions


def test_context_arrays_only_for_cross_region_variables():
    """§4.7: private vars living across regions get context arrays; vars
    local to one region stay scalar."""
    k1 = compile_kernel(build_vecadd, (8,))
    assert k1.context_stats["slots"] == 0
    k2 = compile_kernel(build_conditional_barrier, (8,))
    assert k2.context_stats["slots"] > 0


def test_conditional_barrier_both_paths_agree_with_oracle():
    """Tail-duplication correctness: the barrier-taken and not-taken paths
    must both replay the fiber semantics exactly (§4.4, Fig. 6)."""
    rng = np.random.default_rng(0)
    for flag in (0, 1):
        x = rng.normal(size=8).astype(np.float32)
        ref = run_ndrange(build_conditional_barrier(), (8,), (8,),
                          {"x": x.copy()}, {"flag": flag})
        k = compile_kernel(build_conditional_barrier, (8,))
        out = k({"x": x.copy()}, (8,), {"flag": flag})
        np.testing.assert_allclose(out["x"], ref["x"], rtol=1e-6)


def test_bloop_lockstep_semantics():
    """§4.5: each loop iteration's barrier synchronizes all work-items
    before the next iteration (result depends on it)."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=8).astype(np.float32)
    ref = run_ndrange(build_bloop(), (8,), (8,), {"x": x.copy()}, {"n": 4})
    for tgt in ("vector", "loop"):
        k = compile_kernel(build_bloop, (8,), target=tgt)
        out = k({"x": x.copy()}, (8,), {"n": 4})
        np.testing.assert_allclose(out["x"], ref["x"], rtol=1e-5)


def test_multiple_workgroups():
    rng = np.random.default_rng(2)
    bufs = {"inp": rng.normal(size=64).astype(np.float32),
            "out": np.zeros(8, np.float32)}
    ref = run_ndrange(build_reduction(), (64,), (8,),
                      {k: v.copy() for k, v in bufs.items()})
    k = compile_kernel(build_reduction, (8,))
    out = k({key: v.copy() for key, v in bufs.items()}, (64,))
    np.testing.assert_allclose(out["out"], ref["out"], rtol=1e-5)


def build_binarysearch():
    """Regression: uniform-planned vars updated under varying control
    (the ctx-slot shape bug found via the Fig. 12 suite)."""
    b = KernelBuilder("bsearch")
    hay = b.arg_buffer("hay", "float32")
    needle = b.arg_buffer("needle", "float32")
    out = b.arg_buffer("out", "float32")
    n = b.arg_scalar("n", "int32")
    g = b.global_id(0)
    lo = b.var(b.const(0), name="lo")
    hi = b.var(n, name="hi")
    it = b.var(b.const(0), name="it")
    with b.while_loop() as loop:
        loop.cond(it.get() < 6)
        mid = b.var((lo.get() + hi.get()) / 2, name="mid")
        with b.if_(hay[mid.get()] < needle[g]):
            lo.set(mid.get())
        with b.if_(hay[mid.get()] >= needle[g]):
            hi.set(mid.get())
        it.set(it.get() + 1)
    out[g] = lo.get()
    return b.finish()


@pytest.mark.parametrize("target", ["vector", "loop"])
def test_binarysearch_divergent_control(target):
    rng = np.random.default_rng(9)
    hay = np.sort(rng.random(64).astype(np.float32))
    bufs = {"hay": hay, "needle": rng.random(16).astype(np.float32),
            "out": np.zeros(16, np.float32)}
    ref = run_ndrange(build_binarysearch(), (16,), (16,),
                      {k: v.copy() for k, v in bufs.items()}, {"n": 64})
    k = compile_kernel(build_binarysearch, (16,), target=target)
    out = k({key: v.copy() for key, v in bufs.items()}, (16,), {"n": 64})
    np.testing.assert_allclose(out["out"], ref["out"], rtol=1e-6)


@pytest.mark.parametrize("case", ["vecadd", "reduce", "dct", "divergent"])
def test_pallas_target_matches_oracle(case):
    """The Pallas mapping (work-group -> grid cell, locals in VMEM,
    interpret=True on CPU) agrees with the fiber oracle."""
    build, mkbufs, gsz, lsz, scalars = CASES[case]
    rng = np.random.default_rng(hash(case) % 2**31)
    bufs = mkbufs(rng)
    ref = run_ndrange(build(), gsz, lsz,
                      {k: v.copy() for k, v in bufs.items()}, scalars)
    k = compile_kernel(build, lsz, target="pallas")
    out = k({key: v.copy() for key, v in bufs.items()}, gsz, scalars)
    for key in bufs:
        np.testing.assert_allclose(out[key], ref[key], rtol=1e-5,
                                   err_msg=f"pallas/{case} buffer {key}")


def test_vml_inside_kernels():
    """use_vml=True routes kernel transcendentals through Vecmathlib
    (paper §5 integration point)."""
    def build():
        b = KernelBuilder("vmlk")
        x = b.arg_buffer("x", "float32")
        g = b.global_id(0)
        x[g] = x[g].exp() if hasattr(x[g], "exp") else x[g]
        return b.finish()
    try:
        k = compile_kernel(build, (8,), use_vml=True)
    except Exception:
        pytest.skip("DSL lacks transcendental ops; vml exercised via models")
