"""Tests for the performance-portability scoreboard (repro.suite.scoreboard).

Covers the acceptance contract: a complete kernel x target matrix with
every cell bitwise-equal to its oracle, the autotuned winner at the
minimum of its sweep, winning parameters persisted in the TuningTable
and reused (not re-swept) on the next run, and the per-kernel roofline
arithmetic in launch/roofline.kernel_report.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core.autotune import TuningTable
from repro.launch.roofline import kernel_report
from repro.runtime import Context
from repro.suite import SUITE, Scoreboard, calibrate, render_markdown
from repro.suite.scoreboard import check_gates

# a fast 2-kernel subset exercises every cell type (incl. a 2-D NDRange)
FAST = ["stencil1d", "stencil2d"]


@pytest.fixture(scope="module")
def ctx():
    return Context()


def _fast_board(ctx, table, **kw):
    opts = dict(ctx=ctx, table=table, shape_set="ci", warmup=0, repeats=1,
                max_configs=2, include_coexec=True, include_auto=False,
                calibration_n=1 << 10)
    opts.update(kw)
    return Scoreboard(**opts)


@pytest.fixture(scope="module")
def report(ctx, tmp_path_factory):
    table = TuningTable(tmp_path_factory.mktemp("scoreboard") / "tuning.json")
    return _fast_board(ctx, table).run(kernels=FAST)


def test_matrix_complete(report):
    assert report["schema"] == "bench_scoreboard/v1"
    assert set(report["kernels"]) == set(FAST)
    for name, entry in report["kernels"].items():
        cells = entry["cells"]
        # 3 compiled targets + the co-execution column
        assert {"loop", "vector", "pallas", "coexec2"} <= set(cells)
        for tgt, cell in cells.items():
            assert cell["bitwise"], (name, tgt)
            assert cell["time_us"] > 0
            assert cell["roofline"]["fraction"] > 0


def test_winner_beats_worst(report):
    for name, entry in report["kernels"].items():
        for tgt in ("loop", "vector", "pallas"):
            cell = entry["cells"][tgt]
            timings = cell["timings_us"]
            assert len(timings) >= 2
            assert cell["best_us"] == min(timings.values())
            assert cell["best_us"] <= cell["worst_us"]
            assert cell["speedup_vs_worst"] >= 1.0


def test_gates_pass(report):
    gates = check_gates(report, min_fraction=0.0)
    assert gates["ok"], gates
    assert gates["bitwise"] and not gates["bitwise_failures"]
    assert gates["winner_beats_worst"] and not gates["winner_failures"]


def test_gate_detects_bitwise_failure(report):
    broken = json.loads(json.dumps(report))  # deep copy
    broken["kernels"][FAST[0]]["cells"]["vector"]["bitwise"] = False
    gates = check_gates(broken, min_fraction=0.0)
    assert not gates["ok"] and not gates["bitwise"]
    assert gates["bitwise_failures"] == [f"{FAST[0]}/vector"]


def test_gate_min_fraction(report):
    gates = check_gates(report, min_fraction=1e9, fraction_target="vector")
    assert not gates["fraction_ok"] and not gates["ok"]
    failed = {f.split(":")[0] for f in gates["fraction_failures"]}
    assert failed == set(FAST)


def test_sweep_persists_and_is_reused(ctx, tmp_path):
    """Second run against the same table re-measures only the recorded
    winner (sweep_cached=True) and lands on identical parameters."""
    path = tmp_path / "tuning.json"
    first = _fast_board(ctx, TuningTable(path)).run(kernels=["stencil1d"])

    raw = json.loads(path.read_text())
    assert raw["sweeps"], "winning sweep not persisted to the TuningTable"
    for rec in raw["sweeps"].values():
        assert set(rec) == {"params", "timings_us"}

    second = _fast_board(ctx, TuningTable(path)).run(kernels=["stencil1d"])
    for tgt in ("loop", "vector", "pallas"):
        c1 = first["kernels"]["stencil1d"]["cells"][tgt]
        c2 = second["kernels"]["stencil1d"]["cells"][tgt]
        assert not c1["sweep_cached"]
        assert c2["sweep_cached"], tgt
        assert c2["params"] == c1["params"]


def test_render_markdown(report):
    md = render_markdown(report)
    for name in FAST:
        assert f"\n| {name} " in md
    for col in ("loop", "vector", "pallas", "coexec2"):
        assert col in md
    # header + separator + one row per kernel
    assert md.count("\n|") >= len(FAST) + 1


def test_calibrate_positive(ctx):
    peaks = calibrate(ctx, "loop", n=1 << 10, warmup=0, repeats=1)
    assert peaks["peak_flops"] > 0
    assert peaks["peak_bw"] > 0


def test_kernel_report_math():
    r = kernel_report(kernel="gemm", target="vector", flops=2e9,
                      bytes_moved=1e8, time_s=1.0, peak_flops=4e9,
                      peak_bw=1e9)
    assert r.t_compute == pytest.approx(0.5)
    assert r.t_memory == pytest.approx(0.1)
    assert r.t_bound == pytest.approx(0.5)
    assert r.dominant == "compute"
    assert r.fraction == pytest.approx(0.5)
    assert r.achieved_gflops == pytest.approx(2.0)
    d = r.to_dict()
    assert d["kernel"] == "gemm" and d["fraction"] == pytest.approx(0.5)


@pytest.mark.parametrize("bad", [
    dict(flops=0.0), dict(time_s=0.0), dict(peak_bw=-1.0),
    dict(peak_flops=float("nan")), dict(bytes_moved=float("inf")),
])
def test_kernel_report_validates(bad):
    kw = dict(kernel="k", target="loop", flops=1.0, bytes_moved=1.0,
              time_s=1.0, peak_flops=1.0, peak_bw=1.0)
    kw.update(bad)
    with pytest.raises(ValueError):
        kernel_report(**kw)


def test_tuning_table_sweep_roundtrip(tmp_path):
    path = tmp_path / "t.json"
    t = TuningTable(path)
    key = TuningTable.make_sweep_key("gemm", "vector", "m=4,n=4")
    assert t.get_sweep(key) is None
    t.record_sweep(key, {"ts": 8}, {"ts=4": 10.0, "ts=8": 5.0})
    rec = TuningTable(path).get_sweep(key)
    assert rec == {"params": {"ts": 8},
                   "timings_us": {"ts=4": 10.0, "ts=8": 5.0}}
    # a poisoned measurement is dropped, never recorded as a warm start
    t.record_sweep(key, {"ts": 4}, {"ts=4": float("nan")})
    assert t.get_sweep(key)["params"] == {"ts": 8}


def test_suite_unknown_kernel_rejected(ctx, tmp_path):
    board = _fast_board(ctx, TuningTable(tmp_path / "t.json"))
    with pytest.raises(KeyError):
        board.run(kernels=["nonexistent"])
    assert "nonexistent" not in SUITE


def test_numpy_unchanged_inputs(ctx, tmp_path):
    """Scoreboard runs must not mutate the suite's cached input arrays
    across cells — each launch gets fresh copies."""
    sk = SUITE["stencil1d"]
    shape = sk.shapes["ci"]
    params = sk.space(shape)[0]
    before = {k: v.copy() for k, v in sk.make_inputs(shape, params).items()}
    _fast_board(ctx, TuningTable(tmp_path / "t.json"),
                include_coexec=False).run(kernels=["stencil1d"])
    after = sk.make_inputs(shape, params)
    for k, v in before.items():
        assert np.array_equal(v, after[k])
