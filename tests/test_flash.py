"""Flash (custom-VJP blocked) attention: value + gradient vs naive
reference, including hypothesis-driven shape sweeps."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.flash import blocked_attention


def naive(q, k, v, causal):
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    kk = jnp.repeat(k, H // KV, axis=2)
    vv = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(D)
    if causal:
        m = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("B,Sq,Sk,H,KV,D,causal,bq,bk", [
    (2, 64, 64, 4, 2, 16, True, 16, 32),
    (1, 33, 33, 3, 3, 8, True, 16, 8),
    (2, 17, 40, 4, 1, 16, False, 8, 16),
    (1, 128, 128, 2, 2, 32, True, 128, 128),   # single block
])
def test_flash_matches_naive(B, Sq, Sk, H, KV, D, causal, bq, bk):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, KV, D)), jnp.float32)
    f = lambda q, k, v: blocked_attention(q, k, v, causal=causal,
                                          block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(f(q, k, v)),
                               np.asarray(naive(q, k, v, causal)),
                               atol=2e-5, rtol=2e-5)
    g1 = jax.grad(lambda *a: (f(*a) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (naive(*a, causal) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 2),
    Sq=st.integers(1, 40),
    H=st.sampled_from([1, 2, 4]),
    kv_div=st.sampled_from([1, 2]),
    D=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    bq=st.sampled_from([4, 8, 16]),
    bk=st.sampled_from([4, 8, 16]),
)
def test_flash_property(B, Sq, H, kv_div, D, causal, bq, bk):
    if H % kv_div:
        kv_div = 1
    KV = H // kv_div
    Sk = Sq  # self-attention shape
    rng = np.random.default_rng(Sq * 131 + H)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, KV, D)), jnp.float32)
    out = blocked_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = naive(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_flash_rowsum_invariant():
    """Softmax rows integrate to 1: attention of all-ones V is all-ones."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    v = jnp.ones((1, 32, 2, 8), jnp.float32)
    out = blocked_attention(q, k, v, causal=True, block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)
