"""Stateful property harness for the replicated serving mesh.

The PR-6 pattern (tests/test_serving_props.py) lifted one level: a
:class:`MeshDriver` drives random interleavings of the mesh op
vocabulary — submit, step, **replica kill**, **replica recovery**,
**replica stall** — against a real :class:`~repro.serving.ServingMesh`
(real engines, real event DAGs, real KV paging, real router) over
per-replica deterministic :class:`~repro.serving.executor.StubExecutor`s
under a *virtual clock*, so stalls cost no wall time.

Invariants checked after every op and at teardown (docs/mesh.md):

* every submitted request retires **exactly once** — finished or failed
  typed, never dropped, never retired twice (migration requeues, it
  does not retire);
* a request is always in exactly one place: waiting/resident on exactly
  one live replica, parked as an orphan, or retired;
* token streams are **oracle prefixes** while running and bitwise equal
  to ``StubExecutor.expected_tokens`` when finished — regardless of
  which replica (or how many, after migrations) served them;
* KV pages never leak: per-replica page accounting matches the resident
  slots every step, and a DEAD replica's pages are zero *immediately*;
* unhealthy replicas never receive new work: submits route to HEALTHY
  replicas whenever one exists, and DEAD replicas hold no work;
* all-replicas-dead surfaces the typed
  :class:`~repro.core.errors.DeviceLostError` /
  :class:`~repro.runtime.bufalloc.OutOfMemory` — never a hang.

The seeded random walk always runs; the hypothesis
:class:`MeshMachine` (under the ``ci``/``dev`` profiles from
tests/conftest.py) adds minimized counterexamples where available.
"""

import itertools
import random

import numpy as np
import pytest

from repro.core.errors import DeviceLostError, ReproError
from repro.runtime.bufalloc import OutOfMemory
from repro.serving import (ReplicaState, Request, RequestState,
                           ServingMesh, StubExecutor)
from repro.training.straggler import StragglerConfig

try:
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:               # plain tests below still run
    HAVE_HYPOTHESIS = False

REPLICAS = 3
SLOTS = 2
MAX_SEQ = 64
PAGE_TOKENS = 4
MAX_PROMPT = 8
MAX_NEW = 12


def virtual_clock(tick_s: float = 0.001):
    """A deterministic monotone clock: every call advances one tick."""
    counter = itertools.count()
    return lambda: next(counter) * tick_s


def make_mesh(n_replicas=REPLICAS, **kw):
    kw.setdefault("straggler_cfg",
                  StragglerConfig(window=6, slow_factor=3.0,
                                  persist_steps=2))
    kw.setdefault("timer", virtual_clock())
    return ServingMesh(
        n_replicas=n_replicas, batch_slots=SLOTS, max_seq=MAX_SEQ,
        page_tokens=PAGE_TOKENS,
        executor_factory=lambda i: StubExecutor(batch_slots=SLOTS,
                                                max_seq=MAX_SEQ),
        **kw)


class MeshDriver:
    """The machine body: a real mesh + the closed-form oracle.

    Requests are tracked by *object identity* — engine-local ids are
    reassigned when a request migrates to a sibling replica."""

    def __init__(self, n_replicas=REPLICAS, **kw):
        self.mesh = make_mesh(n_replicas, **kw)
        self.requests = []        # every request ever submitted
        self.retired = set()      # id(obj) observed terminal, once
        self.allowed_errors = (DeviceLostError, OutOfMemory)

    # -- ops -------------------------------------------------------------------
    def submit(self, plen, max_new, seed):
        rng = np.random.default_rng(seed)
        r = Request(prompt=rng.integers(0, 500, plen).astype(np.int32),
                    max_new_tokens=max_new)
        states = {rep.index: rep.engine.scheduler_stats["waiting"]
                  for rep in self.mesh.replicas}
        self.mesh.submit(r)
        # router contract: the request landed on a HEALTHY replica
        # whenever one exists (unhealthy never receive new work)
        healthy_exists = any(rep.state == ReplicaState.HEALTHY
                             for rep in self.mesh.replicas)
        for rep in self.mesh.replicas:
            if rep.engine.scheduler_stats["waiting"] > \
                    states[rep.index]:
                assert rep.state != ReplicaState.DEAD
                if healthy_exists:
                    assert rep.state == ReplicaState.HEALTHY, \
                        f"submit routed to {rep.state} replica"
        self.requests.append(r)
        return r

    def step(self):
        for r in self.mesh.step():
            self._retire(r)

    def kill(self, i, keep_one=True):
        alive = self.mesh.alive()
        if keep_one and len(alive) <= 1:
            return
        rep = alive[i % len(alive)]
        self.mesh.kill_replica(rep.index)

    def recover(self, i):
        dead = [r for r in self.mesh.replicas
                if r.state == ReplicaState.DEAD]
        if dead:
            self.mesh.recover_replica(dead[i % len(dead)].index)

    def stall(self, i, seconds):
        rep = self.mesh.replicas[i % len(self.mesh.replicas)]
        rep.step_time_override = seconds or None

    def drain(self):
        try:
            for r in self.mesh.drain():
                self._retire(r)
        except ReproError:
            # all replicas dead: orphans were failed typed, never hung
            assert not self.mesh.alive()
        # requests failed as orphans (all replicas dead) never flow
        # through step(); account their typed terminal state here
        for r in self.requests:
            if id(r) not in self.retired and \
                    r.state == RequestState.FAILED:
                self._retire(r)

    # -- the oracle ------------------------------------------------------------
    def _oracle(self, r):
        return StubExecutor.expected_tokens(r.prompt, r.max_new_tokens,
                                            eos_token=r.eos_token)

    def _retire(self, r):
        assert id(r) not in self.retired, "request retired twice"
        self.retired.add(id(r))
        if r.done:
            assert r.state == RequestState.FINISHED
            # bitwise-identical to serving alone, no matter how many
            # replicas touched it on the way
            assert r.out_tokens == self._oracle(r), \
                "stream diverged from the oracle after migration"
        else:
            assert r.state == RequestState.FAILED
            assert isinstance(r.error, self.allowed_errors), r.error

    def check_invariants(self):
        locations = {}            # id(obj) -> where it lives
        for rep in self.mesh.replicas:
            eng = rep.engine
            kv = eng.kv_stats
            live_pages = sum(len(s.pages) for s in eng._slots
                             if s is not None)
            assert kv["pages_live"] == live_pages
            if rep.state == ReplicaState.DEAD:
                # a dead replica's pages drained the moment it died,
                # and it holds no work
                assert kv["pages_live"] == 0
                assert eng.scheduler_stats["waiting"] == 0
                assert eng.scheduler_stats["running"] == 0
            for r in eng._waiting:
                assert id(r) not in locations, "request in two places"
                locations[id(r)] = f"waiting:{rep.key}"
            for s in eng._slots:
                if s is None:
                    continue
                assert id(s.request) not in locations
                locations[id(s.request)] = f"running:{rep.key}"
                oracle = self._oracle(s.request)
                assert s.request.out_tokens == \
                    oracle[:len(s.request.out_tokens)], \
                    "running stream is not an oracle prefix"
        for r in self.mesh._orphans:
            assert id(r) not in locations
            locations[id(r)] = "orphan"
        # zero drops: submitted == located exactly once or retired
        for r in self.requests:
            here = id(r) in locations
            done = id(r) in self.retired
            assert here or done, "request dropped"
            assert not (here and done), "request both live and retired"
        assert self.mesh.mesh_stats["drops"] == 0

    def check_drained(self):
        assert {id(r) for r in self.requests} == self.retired, \
            "drain left requests behind"
        for rep in self.mesh.replicas:
            assert rep.engine.kv_stats["pages_live"] == 0, \
                f"{rep.key} leaked KV pages"


# --------------------------------------------------------------------------
# hypothesis-free: seeded random walk (runs on every install)
# --------------------------------------------------------------------------

def test_mesh_random_walk_seeded():
    for seed in range(4):
        rnd = random.Random(seed)
        d = MeshDriver()
        for _ in range(80):
            op = rnd.random()
            if op < 0.35 and len(d.requests) < 30:
                d.submit(plen=rnd.randint(2, MAX_PROMPT),
                         max_new=rnd.randint(1, MAX_NEW),
                         seed=rnd.randint(0, 10**6))
            elif op < 0.42:
                d.kill(rnd.randint(0, 9))
            elif op < 0.50:
                d.recover(rnd.randint(0, 9))
            elif op < 0.56:
                d.stall(rnd.randint(0, 9),
                        rnd.choice([0.0, 0.05, 0.5]))
            else:
                d.step()
            d.check_invariants()
        d.drain()
        d.check_invariants()
        d.check_drained()


# --------------------------------------------------------------------------
# deterministic failure-ladder scenarios
# --------------------------------------------------------------------------

def _submit_n(d, n, seed=0, max_new=6):
    rng = random.Random(seed)
    return [d.submit(plen=rng.randint(2, MAX_PROMPT), max_new=max_new,
                     seed=rng.randint(0, 10**6)) for _ in range(n)]


def test_kill_during_prefill_migrates_and_matches_oracle():
    d = MeshDriver()
    reqs = _submit_n(d, 6, seed=1)
    victim = next(rep for rep in d.mesh.replicas if rep.load > 0)
    # armed before the first step: the loss fires through the victim's
    # prefill commands
    d.mesh.kill_replica(victim.index)
    d.step()
    d.check_invariants()
    assert victim.state == ReplicaState.DEAD
    assert victim.engine.kv_stats["pages_live"] == 0
    d.drain()
    d.check_drained()
    assert all(r.done and r.out_tokens == d._oracle(r) for r in reqs)
    assert d.mesh.mesh_stats["migrated"] >= 1
    assert d.mesh.mesh_stats["drops"] == 0
    assert isinstance(d.mesh.last_device_loss, DeviceLostError)


def test_kill_during_decode_migrates_and_matches_oracle():
    d = MeshDriver()
    reqs = _submit_n(d, 6, seed=2, max_new=10)
    d.step()                     # prefills done, decode under way
    victim = next(rep for rep in d.mesh.replicas if rep.load > 0)
    mid_flight = [s.request for s in victim.engine._slots
                  if s is not None and s.request.out_tokens]
    assert mid_flight             # genuinely killed mid-decode
    d.mesh.kill_replica(victim.index)
    d.step()
    d.check_invariants()
    assert victim.engine.device_lost is not None
    d.drain()
    d.check_drained()
    # recompute after migration is bitwise-safe (greedy decode)
    assert all(r.done and r.out_tokens == d._oracle(r) for r in reqs)


def test_kill_all_then_recover_requeues_orphans():
    d = MeshDriver(n_replicas=2)
    reqs = _submit_n(d, 5, seed=3)
    d.step()
    for rep in d.mesh.replicas:
        d.mesh.kill_replica(rep.index)
    d.step()                     # both die: victims park as orphans
    d.check_invariants()
    assert not d.mesh.alive()
    assert len(d.mesh._orphans) == len(reqs)
    d.mesh.recover_replica(0)    # fresh engine; orphans requeue
    d.check_invariants()
    assert not d.mesh._orphans
    d.drain()
    d.check_drained()
    assert all(r.done and r.out_tokens == d._oracle(r) for r in reqs)


def test_all_replicas_dead_surfaces_typed_never_hangs():
    d = MeshDriver(n_replicas=2)
    reqs = _submit_n(d, 4, seed=4)
    for rep in d.mesh.replicas:
        d.mesh.kill_replica(rep.index)
    d.step()
    # drain surfaces the typed loss (after failing the orphans), and
    # submit refuses new work with the same typed error
    with pytest.raises(DeviceLostError):
        d.mesh.drain()
    assert all(isinstance(r.error, DeviceLostError) for r in reqs)
    with pytest.raises(DeviceLostError):
        d.submit(plen=4, max_new=2, seed=0)
    d.drain()                    # idempotent: accounts the failures
    d.check_drained()


def test_oom_on_mesh_surfaces_typed_out_of_memory():
    # one replica, budget below a single request's footprint: the typed
    # OutOfMemory must retire the request, not hang the mesh
    d = MeshDriver(n_replicas=1,
                   kv_budget_bytes=PAGE_TOKENS * 64 * 1)
    r = d.submit(plen=MAX_PROMPT, max_new=8, seed=5)
    d.drain()
    d.check_invariants()
    d.check_drained()
    assert r.state == RequestState.FAILED
    assert isinstance(r.error, OutOfMemory)


def test_straggler_drains_then_rejoins():
    d = MeshDriver()
    d.stall(0, 0.5)              # replica 0 runs 500x slower (virtual)
    _submit_n(d, 6, seed=6, max_new=8)
    flagged = False
    for _ in range(30):
        d.step()
        d.check_invariants()
        if d.mesh.replicas[0].state == ReplicaState.DRAINING:
            flagged = True
            # de-weighted and drained: new work routes elsewhere
            r = d.submit(plen=4, max_new=2, seed=7)
            assert not any(w is r for w in
                           d.mesh.replicas[0].engine._waiting)
            break
    assert flagged, "persistent straggler never drained"
    d.stall(0, 0.0)
    d.drain()
    d.check_drained()
    # emptied while draining -> rejoined the healthy set
    assert d.mesh.replicas[0].state == ReplicaState.HEALTHY


def test_router_prefers_fast_replicas():
    mesh = make_mesh()
    # teach the EWMA that replica 2 is 8x faster
    for _ in range(6):
        mesh._model.observe(0, 1, 0.8)
        mesh._model.observe(1, 1, 0.8)
        mesh._model.observe(2, 1, 0.1)
    counts = {0: 0, 1: 0, 2: 0}
    rng = np.random.default_rng(8)
    for _ in range(12):
        r = Request(prompt=rng.integers(0, 500, 4).astype(np.int32),
                    max_new_tokens=2)
        mesh.submit(r)
        for rep in mesh.replicas:
            counts[rep.index] = max(counts[rep.index], rep.load)
    # the fast replica absorbed the deepest queue
    assert counts[2] == max(counts.values())
    mesh.drain()


# --------------------------------------------------------------------------
# hypothesis state machine (minimized counterexamples where available)
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    class MeshMachine(RuleBasedStateMachine):
        @initialize()
        def setup(self):
            self.d = MeshDriver()

        @rule(plen=st.integers(2, MAX_PROMPT),
              max_new=st.integers(1, MAX_NEW),
              seed=st.integers(0, 10**6))
        def submit(self, plen, max_new, seed):
            if len(self.d.requests) < 40 and self.d.mesh.alive():
                self.d.submit(plen, max_new, seed)

        @rule()
        def step(self):
            self.d.step()

        @rule(n=st.integers(2, 5))
        def step_many(self, n):
            for _ in range(n):
                self.d.step()

        @rule(i=st.integers(0, 9))
        def kill(self, i):
            self.d.kill(i)

        @rule(i=st.integers(0, 9))
        def recover(self, i):
            self.d.recover(i)

        @rule(i=st.integers(0, 9),
              s=st.sampled_from([0.0, 0.05, 0.5]))
        def stall(self, i, s):
            self.d.stall(i, s)

        @invariant()
        def invariants(self):
            if hasattr(self, "d"):
                self.d.check_invariants()

        def teardown(self):
            if hasattr(self, "d"):
                self.d.drain()
                self.d.check_invariants()
                self.d.check_drained()

    TestMeshMachine = MeshMachine.TestCase
