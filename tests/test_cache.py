"""Compilation cache + target autotuner (docs/caching.md).

Covers the acceptance contract of the cache subsystem: content-addressed
key stability under IR-preserving DSL re-definition, hit/miss/eviction
semantics, the disk tier, compile-count == 1 across repeated launches,
autotuner winner persistence and pinning, and steady-state serving with
zero recompilation.
"""

import numpy as np
import pytest

from repro.core import (AutotunedKernel, CacheKey, CompilationCache,
                        KernelBuilder, TuningTable, canonical_ir,
                        compile_count, compile_kernel, ir_hash, run_ndrange)


# --------------------------------------------------------------------------
# kernel builders (each call returns a structurally identical fresh CFG)
# --------------------------------------------------------------------------

def build_vecadd():
    b = KernelBuilder("vecadd")
    A, B, C = (b.arg_buffer(n, "float32") for n in "ABC")
    gid = b.global_id(0)
    C[gid] = A[gid] + B[gid]
    return b.finish()


def build_vecadd_again():
    """The same DSL code as build_vecadd, defined independently — fresh
    Value ids, fresh block counters, same canonical IR."""
    b = KernelBuilder("vecadd")
    A, B, C = (b.arg_buffer(n, "float32") for n in "ABC")
    gid = b.global_id(0)
    C[gid] = A[gid] + B[gid]
    return b.finish()


def build_vecmul():
    b = KernelBuilder("vecmul")
    A, B, C = (b.arg_buffer(n, "float32") for n in "ABC")
    gid = b.global_id(0)
    C[gid] = A[gid] * B[gid]
    return b.finish()


def build_reduction():
    """Loop + barrier + divergence: exercises phis/vregs in the hash."""
    b = KernelBuilder("reduce")
    inp = b.arg_buffer("inp", "float32")
    out = b.arg_buffer("out", "float32")
    scratch = b.local_array("scratch", "float32", 8)
    lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
    scratch[lid] = inp[gid]
    b.barrier()
    s = b.var(b.const(4), name="s")
    with b.while_loop() as loop:
        loop.cond(s.get() > 0)
        with b.if_(lid < s.get()):
            scratch[lid] = scratch[lid] + scratch[lid + s.get()]
        b.barrier()
        s.set(s.get() / 2)
    with b.if_(lid == 0):
        out[grp] = scratch[0]
    return b.finish()


def _vecadd_bufs(n=32):
    rng = np.random.default_rng(0)
    return {"A": rng.standard_normal(n).astype(np.float32),
            "B": rng.standard_normal(n).astype(np.float32),
            "C": np.zeros(n, np.float32)}


# --------------------------------------------------------------------------
# canonical IR hashing
# --------------------------------------------------------------------------

def test_canonical_ir_stable_across_redefinition():
    assert canonical_ir(build_vecadd()) == canonical_ir(build_vecadd_again())
    assert ir_hash(build_vecadd()) == ir_hash(build_vecadd_again())


def test_canonical_ir_stable_for_loops_and_barriers():
    assert canonical_ir(build_reduction()) == canonical_ir(build_reduction())


def test_different_kernels_hash_differently():
    assert ir_hash(build_vecadd()) != ir_hash(build_vecmul())


def test_cache_key_separates_specializations():
    fn = build_vecadd()
    k1 = CacheKey.make(build_vecadd(), (8,), "vector", horizontal=True)
    k2 = CacheKey.make(fn, (8,), "vector", horizontal=True)
    assert k1 == k2
    assert k1 != CacheKey.make(fn, (16,), "vector", horizontal=True)
    assert k1 != CacheKey.make(fn, (8,), "loop", horizontal=True)
    assert k1 != CacheKey.make(fn, (8,), "vector", horizontal=False)


# --------------------------------------------------------------------------
# hit / miss / eviction
# --------------------------------------------------------------------------

def test_cache_hit_returns_identical_kernel():
    cache = CompilationCache()
    k1 = compile_kernel(build_vecadd, (8,), cache=cache)
    k2 = compile_kernel(build_vecadd_again, (8,), cache=cache)
    assert k1 is k2
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert cache.stats.compiles == 1


def test_compile_count_one_across_repeated_launches():
    """Acceptance criterion: the second launch of an identical kernel/config
    performs zero region-formation or target-lowering work."""
    cache = CompilationCache()
    bufs = _vecadd_bufs()
    first = compile_kernel(build_vecadd, (8,), cache=cache)
    ref = first(bufs, (32,))
    c0 = compile_count()
    for _ in range(5):
        k = compile_kernel(build_vecadd_again, (8,), cache=cache)
        out = k(bufs, (32,))
    assert compile_count() - c0 == 0, "steady-state launch recompiled"
    assert cache.stats.compiles == 1
    np.testing.assert_allclose(out["C"], ref["C"])
    np.testing.assert_allclose(out["C"], bufs["A"] + bufs["B"], rtol=1e-6)


def test_cache_miss_on_changed_config():
    cache = CompilationCache()
    compile_kernel(build_vecadd, (8,), cache=cache)
    compile_kernel(build_vecadd, (16,), cache=cache)          # new local size
    compile_kernel(build_vecadd, (8,), target="loop", cache=cache)
    compile_kernel(build_vecadd, (8,), use_vml=True, cache=cache)
    assert cache.stats.compiles == 4 and cache.stats.hits == 0


def test_lru_eviction():
    cache = CompilationCache(capacity=2)
    compile_kernel(build_vecadd, (8,), cache=cache)    # {add}
    compile_kernel(build_vecmul, (8,), cache=cache)    # {add, mul}
    compile_kernel(build_vecadd, (8,), cache=cache)    # hit; mul is LRU
    compile_kernel(build_reduction, (8,), cache=cache)  # evicts mul
    assert cache.stats.evictions == 1
    compile_kernel(build_vecadd, (8,), cache=cache)    # still resident
    compile_kernel(build_vecmul, (8,), cache=cache)    # evicted -> recompile
    assert cache.stats.compiles == 4
    assert len(cache) == 2


def test_uncached_compile_recompiles():
    c0 = compile_count()
    compile_kernel(build_vecadd, (8,), cache=False)
    compile_kernel(build_vecadd, (8,), cache=False)
    assert compile_count() - c0 == 2


def test_cached_results_match_oracle():
    cache = CompilationCache()
    bufs = {"inp": np.arange(16, dtype=np.float32),
            "out": np.zeros(2, np.float32)}
    ref = run_ndrange(build_reduction(), (16,), (8,),
                      {k: v.copy() for k, v in bufs.items()})
    for _ in range(2):
        k = compile_kernel(build_reduction, (8,), cache=cache)
        got = k({k2: v.copy() for k2, v in bufs.items()}, (16,))
        np.testing.assert_allclose(got["out"], ref["out"], rtol=1e-5)
    assert cache.stats.compiles == 1


# --------------------------------------------------------------------------
# disk tier
# --------------------------------------------------------------------------

def test_disk_tier_cross_process_reuse(tmp_path):
    d = str(tmp_path / "kcache")
    c1 = CompilationCache(disk_dir=d)
    compile_kernel(build_vecadd, (8,), cache=c1)
    assert c1.stats.disk_writes == 1

    # fresh cache (fresh process analogue): load from disk, don't compile
    c2 = CompilationCache(disk_dir=d)
    c0 = compile_count()
    k = compile_kernel(build_vecadd_again, (8,), cache=c2)
    assert compile_count() - c0 == 0
    assert c2.stats.disk_hits == 1 and c2.stats.compiles == 0
    bufs = _vecadd_bufs()
    out = k(bufs, (32,))
    np.testing.assert_allclose(out["C"], bufs["A"] + bufs["B"], rtol=1e-6)


# --------------------------------------------------------------------------
# autotuner
# --------------------------------------------------------------------------

def test_autotuner_records_and_reuses_winner(tmp_path):
    path = str(tmp_path / "tuning.json")
    table = TuningTable(path)
    cache = CompilationCache()
    k = AutotunedKernel(build_vecadd(), build_vecadd, (8,), {},
                        ("loop", "vector"), table, cache, compile_kernel)
    bufs = _vecadd_bufs()
    out = k(bufs, (32,))
    np.testing.assert_allclose(out["C"], bufs["A"] + bufs["B"], rtol=1e-6)
    assert k.last_winner in ("loop", "vector")
    assert len(table) == 1 and cache.stats.tune_decisions == 1

    # second launch of the same shape: table lookup, no new tune decision
    winner = k.last_winner
    k(bufs, (32,))
    assert k.last_winner == winner
    assert cache.stats.tune_decisions == 1

    # a fresh process: reload the table from disk, winner survives
    table2 = TuningTable(path)
    key = TuningTable.make_key(ir_hash(build_vecadd()), (8,), (32,), [])
    assert table2.get(key) == winner


def test_autotuner_new_shape_triggers_new_decision(tmp_path):
    table = TuningTable(str(tmp_path / "t.json"))
    cache = CompilationCache()
    k = AutotunedKernel(build_vecadd(), build_vecadd, (8,), {},
                        ("loop", "vector"), table, cache, compile_kernel)
    k(_vecadd_bufs(32), (32,))
    k(_vecadd_bufs(64), (64,))
    assert len(table) == 2


def test_autotuner_pin_bypasses_measurement(tmp_path):
    table = TuningTable(str(tmp_path / "t.json"))
    table.pin("vecadd", "loop")
    cache = CompilationCache()
    k = AutotunedKernel(build_vecadd(), build_vecadd, (8,), {},
                        ("loop", "vector"), table, cache, compile_kernel)
    bufs = _vecadd_bufs()
    out = k(bufs, (32,))
    assert k.last_winner == "loop"
    assert len(table) == 0, "pinned kernel must not be measured"
    np.testing.assert_allclose(out["C"], bufs["A"] + bufs["B"], rtol=1e-6)


def test_compile_kernel_target_auto_end_to_end(tmp_path, monkeypatch):
    from repro.core import set_default_table
    set_default_table(TuningTable(str(tmp_path / "t.json")))
    try:
        k = compile_kernel(build_vecadd, (8,), target="auto",
                           cache=CompilationCache())
        assert isinstance(k, AutotunedKernel)
        bufs = _vecadd_bufs()
        out = k(bufs, (32,))
        np.testing.assert_allclose(out["C"], bufs["A"] + bufs["B"],
                                   rtol=1e-6)
        assert k.num_regions >= 1
    finally:
        set_default_table(None)


# --------------------------------------------------------------------------
# runtime integration: enqueue path + device cache
# --------------------------------------------------------------------------

def test_queue_enqueue_kernel_steady_state(monkeypatch):
    from repro.runtime.platform import Platform, create_buffer
    from repro.runtime.queue import CommandQueue

    # exact compile/hit assertions need a memory-only device cache: an
    # ambient REPRO_KERNEL_CACHE_DIR would turn first compiles into disk
    # hits persisted by earlier runs
    monkeypatch.delenv("REPRO_KERNEL_CACHE_DIR", raising=False)
    plat = Platform()
    dev = plat.get_devices()[0]
    q = CommandQueue(dev)
    buf = create_buffer(dev, 8, "float32")
    host = np.arange(8, dtype=np.float32)
    out = np.zeros(8, np.float32)

    def build():
        b = KernelBuilder("scale")
        x = b.arg_buffer("x", "float32")
        gid = b.global_id(0)
        x[gid] = x[gid] * 2.0
        return b.finish()

    ev = q.enqueue_write_buffer(buf, host)
    for _ in range(6):
        ev = q.enqueue_kernel(build, (8,), (8,), {"x": buf}, wait_for=[ev])
    q.enqueue_read_buffer(buf, out, wait_for=[ev])
    q.finish()
    np.testing.assert_allclose(out, host * 64)
    assert q.stats["launches"] == 6
    assert q.stats["enqueue_compiles"] == 1, \
        "steady-state enqueue must be a hash lookup"
    st = dev.cache_stats()
    assert st["compiles"] == 1 and st["hits"] == 5


def test_concurrent_autotuned_enqueues_tune_once(monkeypatch, tmp_path):
    """Single-flight tuning: concurrent first launches on the auto device
    must produce exactly one recorded decision and one compile per
    candidate target."""
    from repro.core import set_default_table
    from repro.runtime.platform import Platform, create_buffer
    from repro.runtime.queue import CommandQueue

    monkeypatch.delenv("REPRO_KERNEL_CACHE_DIR", raising=False)
    set_default_table(TuningTable(str(tmp_path / "t.json")))
    try:
        plat = Platform()
        dev = plat.get_devices("auto")[0]
        q = CommandQueue(dev, out_of_order=True, workers=4)
        bufs = [create_buffer(dev, 8, "float32") for _ in range(6)]
        for b_ in bufs:
            q.enqueue_write_buffer(b_, np.zeros(8, np.float32))
        # out-of-order queues run commands independently unless
        # synchronized by events — the kernels must wait on the barrier
        bar = q.enqueue_barrier()

        def build():
            b = KernelBuilder("inc")
            x = b.arg_buffer("x", "float32")
            gid = b.global_id(0)
            x[gid] = x[gid] + 1.0
            return b.finish()

        evs = [q.enqueue_kernel(build, (8,), (8,), {"x": b_},
                                wait_for=[bar])
               for b_ in bufs]
        outs = [np.zeros(8, np.float32) for _ in bufs]
        for b_, o, e in zip(bufs, outs, evs):
            q.enqueue_read_buffer(b_, o, wait_for=[e])
        q.finish()
        assert all(np.allclose(o, 1.0) for o in outs)
        st = dev.cache_stats()
        assert st["tune_decisions"] == 1, "tuning raced"
        # one pipeline run per candidate target, all launches share them
        assert st["compiles"] <= 3
    finally:
        set_default_table(None)


# --------------------------------------------------------------------------
# serving steady state
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_engine_no_steady_state_recompilation():
    import jax
    from repro import configs
    from repro.distributed.sharding import BASELINE_RULES
    from repro.models import init_params
    from repro.serving import ServingEngine, Request

    cfg = configs.get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, BASELINE_RULES, batch_slots=2,
                        max_seq=32)

    def batch():
        rng = np.random.default_rng(0)
        return [Request(prompt=rng.integers(0, cfg.vocab, 4)
                        .astype(np.int32), max_new_tokens=3)
                for _ in range(2)]

    eng.generate(batch())
    after_warmup = dict(eng.compile_stats)
    assert after_warmup["prefill_compiles"] == 1
    assert after_warmup["decode_compiles"] == 1

    for _ in range(3):
        eng.generate(batch())
    st = eng.compile_stats
    assert st["prefill_compiles"] == after_warmup["prefill_compiles"], \
        "steady-state prefill recompiled"
    assert st["decode_compiles"] == after_warmup["decode_compiles"], \
        "steady-state decode recompiled"
    assert st["decode_steps"] > after_warmup["decode_steps"]
