"""Serving engine, data pipeline, and the pocl-style runtime layer."""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.data import synth_batch, data_iterator
from repro.distributed.sharding import BASELINE_RULES
from repro.models import init_params, forward
from repro.serving import ServingEngine, Request


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def test_engine_greedy_matches_teacher_forced():
    cfg = configs.get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, BASELINE_RULES, batch_slots=2,
                        max_seq=64)
    prompt = np.arange(6, dtype=np.int32) + 3
    reqs = [Request(prompt=prompt, max_new_tokens=5)]
    done = eng.generate(reqs)
    assert len(done) == 1 and len(done[0].out_tokens) == 5

    # teacher-forced greedy reference
    toks = list(prompt)
    for _ in range(5):
        logits, _, _ = forward(params,
                               jnp.asarray([toks], jnp.int32), cfg,
                               BASELINE_RULES, mode="train")
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert done[0].out_tokens == toks[len(prompt):]


def test_engine_batches_more_requests_than_slots():
    cfg = configs.get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = ServingEngine(cfg, params, BASELINE_RULES, batch_slots=2,
                        max_seq=32)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=3) for _ in range(5)]
    done = eng.generate(reqs)
    assert len(done) == 5
    assert all(len(r.out_tokens) == 3 for r in done)
    # every dispatch went through the event DAG: one prefill per request
    # (an odd tail is a masked empty slot, never a duplicated request —
    # the old _make_groups padding bug) plus the shared decode commands
    dag = eng.dag_stats
    assert dag["prefill_events"] == 5
    assert dag["decode_events"] >= 2
    assert dag["events"] == dag["prefill_events"] + dag["decode_events"]
    assert dag["wall_s"] > 0 and dag["busy_s"] > 0
    st = eng.compile_stats
    assert st["prefill_calls"] == 5, "tail slot duplicated a request"


def test_engine_dag_overlap_matches_serial_results():
    """Concurrent group dispatch must not change any group's tokens:
    compare a 4-worker engine against a serial (1-worker) engine."""
    cfg = configs.get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, 5).astype(np.int32)
               for _ in range(4)]

    def serve(workers):
        eng = ServingEngine(cfg, params, BASELINE_RULES, batch_slots=1,
                            max_seq=32, dag_workers=workers)
        reqs = [Request(prompt=p.copy(), max_new_tokens=4)
                for p in prompts]
        return [r.out_tokens for r in eng.generate(reqs)]

    assert serve(4) == serve(1)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_synth_batch_deterministic():
    cfg = configs.get_smoke("smollm-135m")
    a = synth_batch(cfg, 4, 16, step=7, seed=1)
    b = synth_batch(cfg, 4, 16, step=7, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(cfg, 4, 16, step=8, seed=1)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_synth_batch_targets_shifted():
    cfg = configs.get_smoke("smollm-135m")
    b = synth_batch(cfg, 2, 16, step=0)
    assert b["tokens"].shape == (2, 16)
    assert b["targets"].shape == (2, 16)
    # copy structure: second half repeats the first half
    half = 17 // 2
    np.testing.assert_array_equal(
        b["tokens"][:, half:half * 2 - 1], b["tokens"][:, :half - 1])


def test_iterator_resume_regenerates_stream():
    cfg = configs.get_smoke("smollm-135m")
    it = data_iterator(cfg, 2, 8, start_step=0)
    first = [next(it) for _ in range(3)]
    it2 = data_iterator(cfg, 2, 8, start_step=2)
    resumed = next(it2)
    np.testing.assert_array_equal(first[2]["tokens"], resumed["tokens"])


def test_modality_stubs_present():
    vlm = configs.get_smoke("llama-3.2-vision-11b")
    b = synth_batch(vlm, 2, 8, 0)
    assert b["img_embeds"].shape == (2, vlm.n_img_tokens, vlm.d_model)
    whisper = configs.get_smoke("whisper-small")
    b = synth_batch(whisper, 2, 8, 0)
    assert b["frames"].shape == (2, whisper.enc_seq, whisper.d_model)


# --------------------------------------------------------------------------
# runtime (pocl host layer)
# --------------------------------------------------------------------------

def test_platform_devices_and_queue_ordering():
    from repro.runtime.platform import Platform, create_buffer
    from repro.runtime.queue import CommandQueue

    plat = Platform()
    devs = plat.get_devices()
    assert devs, "platform exposes no devices"
    dev = devs[0]
    assert dev.query("max_work_group_size") >= 1

    from repro.core import KernelBuilder

    def build():
        b = KernelBuilder("scale")
        x = b.arg_buffer("x", "float32")
        gid = b.global_id(0)
        x[gid] = x[gid] * 2.0
        return b.finish()

    kern = dev.build_kernel(build, (8,))
    q = CommandQueue(dev)
    buf = create_buffer(dev, 8, "float32")
    host = np.arange(8, dtype=np.float32)
    out = np.zeros(8, np.float32)
    e1 = q.enqueue_write_buffer(buf, host)
    e2 = q.enqueue_ndrange_kernel(kern, (8,), {"x": buf}, wait_for=[e1])
    e3 = q.enqueue_read_buffer(buf, out, wait_for=[e2])
    q.finish()
    assert e1.done and e2.done and e3.done
    np.testing.assert_allclose(out, host * 2)


def test_out_of_order_queue_respects_deps():
    from repro.runtime.platform import Platform, create_buffer
    from repro.runtime.queue import CommandQueue

    plat = Platform()
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True)
    order = []

    def mk(tag):
        def fn():
            time.sleep(0.01)
            order.append(tag)
        return fn

    e1 = q._enqueue("a", mk("a"), [])
    e2 = q._enqueue("b", mk("b"), [e1])
    e3 = q._enqueue("c", mk("c"), [e2])
    q.finish()
    assert order == ["a", "b", "c"]


def test_bufalloc_backed_buffers():
    from repro.runtime.platform import Platform, create_buffer
    plat = Platform()
    dev = plat.get_devices()[0]
    b1 = create_buffer(dev, 128, "float32")
    b2 = create_buffer(dev, 128, "float32")
    assert b1.chunk.start != b2.chunk.start
    b1.release()
    b2.release()
