"""Public-API surface lock (docs/host_api.md §Stability).

Snapshots the ``__all__`` of every public ``repro.*`` package into
``tests/golden/api_surface.json`` and fails when the surface drifts —
an accidental export (or a dropped one) is an API change and must be
made deliberately.  Regenerate after intentional changes:

  REPRO_UPDATE_API=1 PYTHONPATH=src python -m pytest tests/test_api_surface.py
"""

import importlib
import json
import os

# every package that declares a public surface; adding a package here is
# itself a surface change and lands in the snapshot
MODULES = [
    "repro.core",
    "repro.core.errors",
    "repro.core.program",
    "repro.runtime",
    "repro.runtime.context",
    "repro.serving",
    "repro.suite",
    "repro.models",
    "repro.vml",
]

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "api_surface.json")


def current_surface():
    surface = {}
    for mod in MODULES:
        m = importlib.import_module(mod)
        names = sorted(set(getattr(m, "__all__")))
        assert len(names) == len(getattr(m, "__all__")), \
            f"{mod}.__all__ has duplicate entries"
        missing = [n for n in names if not hasattr(m, n)]
        assert not missing, f"{mod}.__all__ exports missing names {missing}"
        surface[mod] = names
    return surface


def test_api_surface_locked():
    surface = current_surface()
    if os.environ.get("REPRO_UPDATE_API"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(surface, f, indent=1, sort_keys=True)
            f.write("\n")
        return
    assert os.path.exists(GOLDEN), \
        "no API snapshot; regenerate with REPRO_UPDATE_API=1"
    with open(GOLDEN) as f:
        locked = json.load(f)
    problems = []
    for mod in sorted(set(locked) | set(surface)):
        old = set(locked.get(mod, []))
        new = set(surface.get(mod, []))
        for n in sorted(new - old):
            problems.append(f"{mod}: NEW export {n!r}")
        for n in sorted(old - new):
            problems.append(f"{mod}: REMOVED export {n!r}")
    assert not problems, (
        "public API surface drifted; if intentional, regenerate with "
        "REPRO_UPDATE_API=1:\n  " + "\n  ".join(problems))
