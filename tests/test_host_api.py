"""First-class Context / Program / Kernel host API (docs/host_api.md).

Covers the host-object-model acceptance contract: typed set_arg
signature validation against the IR, Program build logs on verifier
failure, Kernel.clone under a 4-worker out-of-order queue, one Kernel
object producing bitwise-identical results through single-device and
2-device co-executed launches with unchanged compile counts, typed
buffer-creation validation, the shared plan tier across devices, the
ReproError status hierarchy, and the deprecation shims over the old
entry points (which must keep working)."""

import numpy as np
import pytest

from repro.core import (BuildError, InvalidArgError, InvalidBufferError,
                        KernelBuilder, ReproError, VerifierError,
                        compile_count, status_name)
from repro.core import program as program_mod
from repro.runtime import (CommandError, Context, DependencyError,
                           MapError, OutOfMemory, create_buffer,
                           default_platform)

_uniq = iter(range(10_000))


def make_scale_builder(name=None):
    """A uniquely-named scale kernel builder (unique IR => no cache
    aliasing between tests measuring compile counts)."""
    name = name or f"hostapi_scale{next(_uniq)}"

    def build():
        b = KernelBuilder(name)
        x = b.arg_buffer("x", "float32")
        s = b.arg_scalar("s", "float32")
        g = b.global_id(0)
        x[g] = x[g] * s
        return b.finish()
    return name, build


def make_reduce_builder(name=None):
    """Kernel with a LOCAL array + barrier (tests local-arg rules)."""
    name = name or f"hostapi_reduce{next(_uniq)}"

    def build():
        b = KernelBuilder(name)
        inp = b.arg_buffer("inp", "float32")
        out = b.arg_buffer("out", "float32")
        scratch = b.local_array("scratch", "float32", 8)
        lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
        scratch[lid] = inp[gid]
        b.barrier()
        s = b.var(b.const(4), name="s")
        with b.while_loop() as loop:
            loop.cond(s.get() > 0)
            with b.if_(lid < s.get()):
                scratch[lid] = scratch[lid] + scratch[lid + s.get()]
            b.barrier()
            s.set(s.get() / 2)
        with b.if_(lid == 0):
            out[grp] = scratch[0]
        return b.finish()
    return name, build


# --------------------------------------------------------------------------
# Program: names, build, build log
# --------------------------------------------------------------------------

def test_program_kernel_names_and_build_log():
    ctx = Context()
    n1, b1 = make_scale_builder()
    n2, b2 = make_reduce_builder()
    prog = ctx.create_program(b1, b2)
    assert sorted(prog.kernel_names()) == sorted([n1, n2])
    prog.build()
    log = prog.build_log()
    assert n1 in log and n2 in log and "middle-end ok" in log
    # duplicate kernel names are rejected, typed
    with pytest.raises(InvalidArgError):
        ctx.create_program(b1, b1)
    with pytest.raises(InvalidArgError):
        prog.create_kernel("nope")
    # two kernels -> create_kernel() needs an explicit name
    with pytest.raises(InvalidArgError):
        prog.create_kernel()


def test_program_build_log_on_verifier_failure(monkeypatch):
    """A middle-end verifier failure surfaces as BuildError with the
    verifier report in the program build log (CL_BUILD_PROGRAM_FAILURE
    + CL_PROGRAM_BUILD_LOG semantics)."""
    ctx = Context()
    name, build = make_scale_builder()
    prog = ctx.create_program(build)
    # warm the plan tier via a lazy (unverified) specialization first:
    # build() must still run its own verification pipeline — a plan-tier
    # hit is not a proof
    prog.create_kernel().bind(ctx.devices[0], (8,))
    assert ctx.cache.stats.plan_builds == 1

    def broken_build_plan(fn, **kw):
        raise VerifierError("tail_duplicate",
                            "block 'b3' unreachable after replication")
    monkeypatch.setattr(program_mod, "build_plan", broken_build_plan)
    with pytest.raises(BuildError) as ei:
        prog.build()
    assert name in str(ei.value)
    log = prog.build_log()
    assert "tail_duplicate" in log and "unreachable" in log
    assert ei.value.build_log == log
    # VerifierError itself is a BuildError in the typed hierarchy
    assert ei.value.__cause__.code == -45


# --------------------------------------------------------------------------
# Kernel: set_arg signature validation
# --------------------------------------------------------------------------

def test_set_arg_signature_mismatches():
    ctx = Context()
    _, build = make_reduce_builder()
    k = ctx.create_program(build).create_kernel()
    f32 = np.zeros(64, np.float32)

    # positional order: non-local buffers first, then scalars
    assert [n for n, kind, _ in k.arg_info()] == ["inp", "out"]
    k.set_arg(0, f32)                     # ok
    k.set_arg("out", np.zeros(8, np.float32))

    with pytest.raises(InvalidArgError, match="no argument"):
        k.set_arg("nope", f32)
    with pytest.raises(InvalidArgError, match="out of range"):
        k.set_arg(7, f32)
    with pytest.raises(InvalidArgError, match="LOCAL"):
        k.set_arg("scratch", f32)         # auto-materialized, not settable
    with pytest.raises(InvalidArgError, match="dtype"):
        k.set_arg("inp", f32.astype(np.float64))
    with pytest.raises(InvalidArgError, match="buffer"):
        k.set_arg("inp", 3.0)             # scalar for a buffer arg
    with pytest.raises(InvalidArgError, match="int index or str"):
        k.set_arg(1.5, f32)

    _, sbuild = make_scale_builder()
    ks = ctx.create_program(sbuild).create_kernel()
    with pytest.raises(InvalidArgError, match="scalar"):
        ks.set_arg("s", f32)              # buffer for a scalar arg
    with pytest.raises(InvalidArgError):
        ks.set_arg("s", True)             # bool is not a kernel scalar
    with pytest.raises(InvalidArgError, match="complex"):
        ks.set_arg("s", 1 + 2j)           # complex for a float32 scalar

    def build_int_scalar():
        b = KernelBuilder(f"hostapi_int{next(_uniq)}")
        x = b.arg_buffer("x", "float32")
        n = b.arg_scalar("n", "int32")
        g = b.global_id(0)
        x[g] = x[g] + n
        return b.finish()
    ki = ctx.create_program(build_int_scalar).create_kernel()
    ki.set_arg("n", 2.0)                  # integral float: fine
    with pytest.raises(InvalidArgError, match="fractional"):
        ki.set_arg("n", 2.7)              # silent truncation refused

    # launches with unset args are CL_INVALID_KERNEL_ARGS
    ks2 = ctx.create_program(make_scale_builder()[1]).create_kernel()
    ks2.set_arg("s", 2.0)
    with pytest.raises(InvalidArgError, match="unset"):
        ctx.launch(ks2, (64,), (8,))
    # error carries the OpenCL-style status code
    try:
        ks2.set_arg("bogus", 1)
    except InvalidArgError as e:
        assert e.code == -50 and e.code_name == "CL_INVALID_ARG_VALUE"
        assert isinstance(e, ValueError)  # pre-hierarchy compat


def test_launch_path_buffer_class_checks():
    """Device buffers belong on queues; host arrays on ctx.launch;
    a device buffer handed to a co-executed launch is rejected."""
    ctx = Context()
    _, build = make_scale_builder()
    k = ctx.create_program(build).create_kernel()
    buf = ctx.create_buffer(64, "float32")
    k.set_args(x=buf, s=2.0)
    with pytest.raises(InvalidArgError, match="accepts"):
        ctx.launch(k, (64,), (8,))        # device buffer on host path
    co = ctx.create_co_executor(ctx.platform.co_devices(2))
    with pytest.raises(InvalidArgError, match="accepts"):
        co.launch(k, (64,), (8,))         # device buffer on co path


# --------------------------------------------------------------------------
# create_buffer validation (the input-validation bugfix)
# --------------------------------------------------------------------------

def test_create_buffer_validation():
    ctx = Context()
    dev = default_platform().get_devices()[0]
    for bad in (0, -3, 2.5, "8", None, True):
        with pytest.raises(InvalidBufferError):
            ctx.create_buffer(bad)
        with pytest.raises(InvalidBufferError):
            create_buffer(dev, bad)
    for bad_dtype in ("floatXX", "not-a-dtype"):
        with pytest.raises(InvalidBufferError):
            ctx.create_buffer(8, bad_dtype)
    with pytest.raises(InvalidBufferError) as ei:
        create_buffer(dev, 0)
    assert ei.value.code == -61
    assert isinstance(ei.value, ValueError)     # pre-hierarchy compat
    # numpy integer counts are fine
    buf = ctx.create_buffer(np.int64(16), "float32")
    assert buf.n_elems == 16
    buf.release()


def test_context_pooled_buffers_and_membership():
    ctx = Context()
    # pooled context buffers are lazy (fusion elision, docs/memory.md):
    # the chunk only hits the pool on first real use
    b1 = ctx.create_buffer(1024, "float32")
    assert not b1.materialized
    b1.data[0] = 1.0                          # first real use: materializes
    assert b1.materialized
    b1.release()
    b2 = ctx.create_buffer(1024, "float32")   # same size class: pool hit
    _ = b2.data
    stats = ctx.pool_stats()[ctx.devices[0].info.name]
    assert stats["hits"] >= 1
    b2.release()
    # an explicitly-scoped context rejects outside devices
    # (CL_INVALID_DEVICE); a platform-spanning one adopts devices the
    # platform grew after context creation
    foreign = ctx.platform.co_devices(1)[0]
    with pytest.raises(InvalidArgError, match="not part of this context"):
        Context(devices=ctx.devices[:1]).create_buffer(8, device=foreign)
    adopted = ctx.create_buffer(8, device=foreign)   # spanning: adopted
    assert foreign in ctx.devices
    adopted.release()
    # an explicit empty device list is an error, not "all devices"
    with pytest.raises(InvalidArgError, match="at least one device"):
        Context(devices=[])


def test_buffer_dtype_aliases_accepted():
    """Equivalent dtype spellings (np.float32, 'f4', 'float32') are the
    same dtype for set_arg validation."""
    ctx = Context()
    _, build = make_scale_builder()
    k = ctx.create_program(build).create_kernel()
    k.set_arg("x", ctx.create_buffer(8, np.float32))
    k.set_arg("x", ctx.create_buffer(8, "f4"))
    k.set_arg("x", np.zeros(8, dtype="<f4"))
    with pytest.raises(InvalidArgError, match="dtype"):
        k.set_arg("x", ctx.create_buffer(8, "f8"))


# --------------------------------------------------------------------------
# Kernel.clone under a 4-worker out-of-order queue
# --------------------------------------------------------------------------

def test_kernel_clone_concurrent_out_of_order_queue():
    ctx = Context()
    _, build = make_scale_builder()
    base = ctx.create_program(build).create_kernel()
    dev = ctx.devices[0]
    q = ctx.create_queue(dev, out_of_order=True, workers=4)
    n = 64
    bufs, events = [], []
    for i in range(8):
        buf = ctx.create_buffer(n, "float32")
        ev_w = q.enqueue_write_buffer(buf, np.arange(n, dtype=np.float32))
        k = base.clone().set_args(x=buf, s=float(i + 1))
        ev = q.enqueue_nd_range(k, (n,), (8,), wait_for=[ev_w])
        bufs.append(buf)
        events.append(ev)
    q.finish()
    host = np.arange(n, dtype=np.float32)
    for i, buf in enumerate(bufs):
        np.testing.assert_array_equal(buf.data, host * (i + 1))
        buf.release()
    assert all(ev.succeeded for ev in events)
    # the base kernel's own binding never changed
    assert base.missing_args() == ["x", "s"]


def test_enqueue_snapshots_args():
    """OpenCL: an enqueue captures the kernel's current args; mutating
    the kernel after enqueue must not affect the queued command."""
    ctx = Context()
    _, build = make_scale_builder()
    k = ctx.create_program(build).create_kernel()
    buf1 = ctx.create_buffer(16, "float32")
    buf2 = ctx.create_buffer(16, "float32")
    q = ctx.create_queue()
    q.enqueue_write_buffer(buf1, np.ones(16, np.float32))
    q.enqueue_write_buffer(buf2, np.ones(16, np.float32))
    k.set_args(x=buf1, s=3.0)
    q.enqueue_nd_range(k, (16,), (8,))
    k.set_args(x=buf2, s=100.0)           # re-bind after enqueue
    q.finish()
    np.testing.assert_array_equal(buf1.data, np.full(16, 3.0, np.float32))
    np.testing.assert_array_equal(buf2.data, np.ones(16, np.float32))
    buf1.release(), buf2.release()


# --------------------------------------------------------------------------
# one Kernel object: single-device vs 2-device co-execution, bitwise
# --------------------------------------------------------------------------

def test_bitwise_single_vs_co_executed_same_kernel():
    ctx = Context()
    _, build = make_reduce_builder()
    prog = ctx.create_program(build).build()
    kernel = prog.create_kernel()
    rng = np.random.default_rng(7)
    inp = rng.standard_normal(256).astype(np.float32)
    kernel.set_args(inp=inp, out=np.zeros(32, np.float32))

    c0 = compile_count()
    single = ctx.launch(kernel, (256,), (8,))
    single_compiles = compile_count() - c0

    co = ctx.create_co_executor(ctx.platform.co_devices(2))
    c0 = compile_count()
    for mode in ("static", "steal"):
        merged = co.launch(kernel.clone(), (256,), (8,), mode=mode)
        assert merged["out"].tobytes() == single["out"].tobytes()
        assert merged["inp"].tobytes() == single["inp"].tobytes()
    co_compiles = compile_count() - c0
    co.finish()

    # compile economics unchanged vs the old entry points: one pipeline
    # run per (device cache, target, local size) — 1 single-device + 2
    # co-devices — and zero recompiles on the second co-executed mode
    assert single_compiles == 1
    assert co_compiles == 2
    # the shared plan tier ran region formation once for all devices
    assert ctx.cache.stats.plan_builds == 1


def test_compile_counts_match_old_paths():
    """The new object model does exactly as many pipeline runs as the
    deprecated entry points for an identical workload."""
    host = np.arange(64, dtype=np.float32)

    ctx = Context()
    dev_old, dev_new = ctx.platform.co_devices(2)

    _, build_old = make_scale_builder()
    c0 = compile_count()
    with pytest.deprecated_call():
        k_old = dev_old.build_kernel(build_old, (8,))
    k_old({"x": host.copy()}, (64,), {"s": 2.0})
    k_old({"x": host.copy()}, (64,), {"s": 2.0})
    old_compiles = compile_count() - c0

    _, build_new = make_scale_builder()
    prog = Context(devices=[dev_new]).create_program(build_new)
    k_new = prog.create_kernel().set_args(x=host.copy(), s=2.0)
    c0 = compile_count()
    binary = k_new.bind(dev_new, (8,))
    out1 = binary({"x": host.copy()}, (64,), {"s": 2.0})
    out2 = binary({"x": host.copy()}, (64,), {"s": 2.0})
    new_compiles = compile_count() - c0

    assert old_compiles == new_compiles == 1
    np.testing.assert_array_equal(np.asarray(out1["x"]),
                                  np.asarray(out2["x"]))


def test_autotuned_device_through_program():
    """An ``auto``-driver device specializes the same Kernel through the
    autotuner (AutotunedKernel consumes the program's builder + shared
    plan tier) — identical results, target chosen by measurement."""
    ctx = Context()
    auto_dev = next(d for d in ctx.devices if d.info.driver == "auto")
    _, build = make_scale_builder()
    k = ctx.create_program(build).create_kernel()
    host = np.arange(32, dtype=np.float32)
    k.set_args(x=host, s=2.5)
    out = ctx.launch(k, (32,), (8,), device=auto_dev)
    np.testing.assert_allclose(out["x"], host * 2.5)
    binary = k.bind(auto_dev, (8,))
    from repro.core import AutotunedKernel
    assert isinstance(binary, AutotunedKernel)
    assert binary.last_winner in ("loop", "vector", "pallas")


# --------------------------------------------------------------------------
# typed error hierarchy
# --------------------------------------------------------------------------

def test_error_hierarchy_and_status_codes():
    assert issubclass(InvalidArgError, ReproError)
    assert issubclass(InvalidArgError, ValueError)
    assert issubclass(InvalidBufferError, InvalidArgError)
    assert issubclass(BuildError, ReproError)
    assert issubclass(BuildError, RuntimeError)
    assert issubclass(VerifierError, BuildError)
    assert issubclass(VerifierError, AssertionError)   # compat
    assert issubclass(MapError, ReproError)
    assert issubclass(MapError, RuntimeError)          # compat
    assert issubclass(DependencyError, CommandError)
    assert issubclass(CommandError, ReproError)
    assert issubclass(OutOfMemory, ReproError)
    assert issubclass(OutOfMemory, MemoryError)
    assert BuildError("x").code == -11
    assert MapError("x").code == -12
    assert DependencyError("x").code == -14
    assert OutOfMemory("x").code == -4
    assert status_name(-50) == "CL_INVALID_ARG_VALUE"
    assert status_name(-11) == "CL_BUILD_PROGRAM_FAILURE"
    assert "UNKNOWN" in status_name(-123456)


def test_map_guards_raise_typed_errors():
    """Map/unmap guards and launch-over-mapped checks raise MapError
    from the ReproError hierarchy (pre-existing guards, now typed)."""
    ctx = Context()
    buf = ctx.create_buffer(64, "float32")
    q = ctx.create_queue()
    region = q.enqueue_map_buffer(buf, "w")
    region.get()
    _, build = make_scale_builder()
    k = ctx.create_program(build).create_kernel().set_args(x=buf, s=2.0)
    ev = q.enqueue_nd_range(k, (64,), (8,))
    with pytest.raises(CommandError):
        q.finish()
    assert isinstance(ev.error, MapError)
    assert isinstance(ev.error, ReproError)
    # the failed event's status surfaces the typed code (-12 MapError)
    assert ev.status == MapError("x").code
    buf.release()


# --------------------------------------------------------------------------
# deprecation shims: old entry points warn but keep working
# --------------------------------------------------------------------------

def test_deprecated_compile_kernel_still_works():
    from repro.core import compile_kernel
    _, build = make_scale_builder()
    with pytest.deprecated_call():
        k = compile_kernel(build, (8,))
    out = k({"x": np.arange(16, dtype=np.float32)}, (16,), {"s": 2.0})
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.arange(16, dtype=np.float32) * 2)


def test_deprecated_build_kernel_still_works():
    ctx = Context()
    _, build = make_scale_builder()
    with pytest.deprecated_call():
        k = ctx.devices[0].build_kernel(build, (8,))
    out = k({"x": np.ones(8, np.float32)}, (8,), {"s": 4.0})
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.full(8, 4.0, np.float32))


def test_deprecated_enqueue_kernel_still_works():
    ctx = Context()
    dev = ctx.devices[0]
    buf = ctx.create_buffer(16, "float32")
    q = ctx.create_queue(dev)
    q.enqueue_write_buffer(buf, np.ones(16, np.float32))
    _, build = make_scale_builder()
    with pytest.deprecated_call():
        q.enqueue_kernel(build, (8,), (16,), {"x": buf}, {"s": 5.0})
    q.finish()
    np.testing.assert_array_equal(buf.data, np.full(16, 5.0, np.float32))
    buf.release()


def test_deprecated_coexecutor_run_still_works():
    ctx = Context()
    co = ctx.create_co_executor(ctx.platform.co_devices(2))
    _, build = make_scale_builder()
    host = np.arange(64, dtype=np.float32)
    with pytest.deprecated_call():
        merged = co.run(build, (8,), (64,), {"x": host.copy()}, {"s": 3.0})
    np.testing.assert_array_equal(merged["x"], host * 3.0)
    co.finish()


# --------------------------------------------------------------------------
# serving engine through a Context
# --------------------------------------------------------------------------

def test_serving_engine_through_context():
    import jax
    from repro import configs
    from repro.distributed.sharding import BASELINE_RULES
    from repro.models import init_params
    from repro.serving import Request, ServingEngine

    cfg = configs.get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ctx = Context()
    eng = ServingEngine(cfg, params, BASELINE_RULES, batch_slots=2,
                        max_seq=32, context=ctx)
    assert eng.context is ctx
    # the KV pool is the context's dedicated KV-class pool over the
    # dispatch device arena (own free lists + counters, shared arena)
    assert eng._kv_pool is ctx.pool_for(ctx.devices[0], min_class=4096)
    assert eng._kv_pool is not ctx.pool_for(ctx.devices[0])
    reqs = [Request(prompt=np.arange(4, dtype=np.int32) + 2,
                    max_new_tokens=3)]
    done = eng.generate(reqs)
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    kv = eng.kv_stats
    assert kv["kv_bytes_per_group"] > 0
    key = f"{ctx.devices[0].info.name}:4096"
    assert ctx.pool_stats()[key]["frees"] >= 1
