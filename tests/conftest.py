"""Shared pytest configuration: hypothesis settings profiles.

Two profiles are registered when hypothesis is installed:

* ``ci``  — more examples, longer stateful runs, and ``derandomize=True``
  (a fixed example-generation seed) so CI failures reproduce exactly;
  selected in .github/workflows/ci.yml via ``HYPOTHESIS_PROFILE=ci``.
* ``dev`` — few examples for fast local iteration; the default, set by
  the ``hypothesis_profile`` ini key in pytest.ini.

The ``HYPOTHESIS_PROFILE`` environment variable overrides the ini key.
Tests that pin their own ``@settings(...)`` values inherit unset fields
(e.g. ``derandomize``) from the loaded profile.
"""

import os


def pytest_addoption(parser):
    parser.addini("hypothesis_profile",
                  "hypothesis settings profile to load (ci | dev)",
                  default="dev")


def pytest_configure(config):
    try:
        from hypothesis import HealthCheck, settings
    except ImportError:        # property tests importorskip themselves
        return
    common = dict(deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile(
        "ci", max_examples=200, stateful_step_count=80,
        derandomize=True, print_blob=True, **common)
    settings.register_profile(
        "dev", max_examples=20, stateful_step_count=30, **common)
    profile = os.environ.get("HYPOTHESIS_PROFILE") \
        or config.getini("hypothesis_profile")
    settings.load_profile(profile)
