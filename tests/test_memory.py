"""Hierarchical memory subsystem (docs/memory.md): sub-buffer rules,
zero-copy map/unmap through the DAG, buffer pooling, span-granular
residency, and the differential conformance of kernels that read/write
through views — across targets, the fiber oracle, and device splits."""

import numpy as np
import pytest

from repro.core import KernelBuilder, run_ndrange
from repro.runtime import (Bufalloc, BufferPool, CoExecutor, CommandQueue,
                           CommandError, MapError, OutOfMemory, Platform,
                           ResidencyTracker, create_buffer,
                           create_sub_buffer)

N = 64
LSZ = 8


@pytest.fixture(scope="module")
def plat():
    return Platform()


def build_axpy():
    """x = x * 2 + 1 — exact in f32 for small-integer inputs, so results
    are bitwise comparable across every target."""
    b = KernelBuilder("axpy")
    x = b.arg_buffer("x", "float32")
    g = b.global_id(0)
    x[g] = x[g] * 2.0 + 1.0
    return b.finish()


def build_scale2():
    """y = x * 2 + 1 (two buffers, co-execution friendly)."""
    b = KernelBuilder("scale2")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    y[g] = x[g] * 2.0 + 1.0
    return b.finish()


# ---------------------------------------------------------------------------
# SubBuffer rules (clCreateSubBuffer)
# ---------------------------------------------------------------------------

class TestSubBuffer:
    def test_view_aliases_parent(self, plat):
        dev = plat.get_devices("basic")[0]
        buf = create_buffer(dev, 16, "float32")
        buf.data = np.arange(16, dtype=np.float32)
        sub = create_sub_buffer(buf, 4 * 4, 8 * 4)     # elements [4, 12)
        assert np.array_equal(sub.data, np.arange(4, 12, dtype=np.float32))
        sub.data = np.full(8, 9.0, np.float32)
        assert buf.data[3] == 3.0 and buf.data[4] == 9.0
        assert buf.data[11] == 9.0 and buf.data[12] == 12.0
        # replacing the parent array must not leave the view dangling
        buf.data = np.zeros(16, np.float32)
        assert sub.data[0] == 0.0
        buf.release()

    def test_alignment_and_bounds_rules(self, plat):
        dev = plat.get_devices("basic")[0]
        buf = create_buffer(dev, 16, "float32")
        old = dev.info.mem_base_addr_align
        try:
            dev.info.mem_base_addr_align = 32
            with pytest.raises(MapError, match="mem_base_addr_align"):
                create_sub_buffer(buf, 4, 32)          # misaligned origin
            create_sub_buffer(buf, 32, 32)             # aligned: fine
        finally:
            dev.info.mem_base_addr_align = old
        with pytest.raises(MapError, match="outside parent"):
            create_sub_buffer(buf, 0, 65)
        with pytest.raises(MapError, match="outside parent"):
            create_sub_buffer(buf, 64, 4)
        with pytest.raises(MapError, match="elements"):
            create_sub_buffer(buf, 4, 6)               # not whole elements
        sub = create_sub_buffer(buf, 0, 32)
        with pytest.raises(MapError, match="sub-buffer from a sub"):
            create_sub_buffer(sub, 0, 16)
        buf.release()

    def test_write_through_view_invalidates_span_only(self, plat):
        """A write through any aliased view must stale exactly the
        overlapping span of the parent's other device copies."""
        dev = plat.get_devices("basic")[0]
        buf = create_buffer(dev, 16, "float32")
        tr = ResidencyTracker()
        buf.bind_residency(tr, "P", "this-dev")
        tr.acquire_spans("P", "other-dev", buf.nbytes)  # other holds a copy
        sub = create_sub_buffer(buf, 4 * 4, 8 * 4)
        sub.mark_written()
        assert tr.stale_spans("P", "other-dev") == [(16, 48)]
        # the writer had no prior copy: valid exactly over what it wrote
        assert tr.stale_spans("P", "this-dev", buf.nbytes) == \
            [(0, 16), (48, 64)]
        # and the whole-buffer write through the parent stales the rest
        buf.mark_written()
        assert tr.stale_spans("P", "other-dev") == [(0, 64)]
        buf.release()


# ---------------------------------------------------------------------------
# Zero-copy map/unmap as DAG commands (clEnqueueMapBuffer)
# ---------------------------------------------------------------------------

class TestMapUnmap:
    def test_map_publishes_zero_copy_view(self, plat):
        dev = plat.get_devices("basic")[0]
        q = CommandQueue(dev)
        buf = create_buffer(dev, N, "float32")
        q.enqueue_write_buffer(buf, np.arange(N, dtype=np.float32))
        region = q.enqueue_map_buffer(buf, "rw")
        arr = region.get()
        assert region.event.kind == "map" and region.active
        assert np.shares_memory(arr, buf.data), "map must be zero-copy"
        arr[0] = 123.0
        q.enqueue_unmap_buffer(region)
        q.finish()
        assert buf.data[0] == 123.0 and region.array is None
        assert not region.active
        buf.release()

    def test_map_sub_range_and_sub_buffer(self, plat):
        dev = plat.get_devices("basic")[0]
        q = CommandQueue(dev)
        buf = create_buffer(dev, 16, "float32")
        sub = create_sub_buffer(buf, 4 * 4, 8 * 4)
        region = q.enqueue_map_buffer(sub, "w", offset=4, nbytes=8)
        arr = region.get()
        assert region.abs_span == (20, 28)     # composed through the view
        arr[:] = [7.0, 8.0]
        q.enqueue_unmap_buffer(region)
        q.finish()
        assert buf.data[5] == 7.0 and buf.data[6] == 8.0
        buf.release()

    def test_overlapping_write_maps_rejected_read_maps_ok(self, plat):
        dev = plat.get_devices("basic")[0]
        q = CommandQueue(dev, out_of_order=True)
        buf = create_buffer(dev, N, "float32")
        r1 = q.enqueue_map_buffer(buf, "r", offset=0, nbytes=32)
        r2 = q.enqueue_map_buffer(buf, "r", offset=16, nbytes=32)
        assert r1.get() is not None and r2.get() is not None
        # the conflicting write map goes on its own queue so its failed
        # event does not poison this queue's finish()
        qbad = CommandQueue(dev, out_of_order=True)
        bad = qbad.enqueue_map_buffer(buf, "w", offset=24, nbytes=8)
        qbad.flush()
        with pytest.raises(CommandError):
            bad.event.wait()
        # disjoint write map is fine
        ok = q.enqueue_map_buffer(buf, "w", offset=128, nbytes=8)
        assert ok.get() is not None
        for r in (r1, r2, ok):
            q.enqueue_unmap_buffer(r)
        q.finish()
        buf.release()

    def test_launch_over_write_mapped_buffer_fails(self, plat):
        dev = plat.get_devices("basic")[0]
        q = CommandQueue(dev, out_of_order=True)
        buf = create_buffer(dev, N, "float32")
        k = dev.build_kernel(build_axpy, (LSZ,))
        region = q.enqueue_map_buffer(buf, "w")
        region.get()
        qbad = CommandQueue(dev, out_of_order=True)
        ev = qbad.enqueue_ndrange_kernel(k, (N,), {"x": buf})
        qbad.flush()
        with pytest.raises(CommandError, match="active map"):
            ev.wait()
        q.enqueue_unmap_buffer(region)
        q.finish()
        ev2 = q.enqueue_ndrange_kernel(k, (N,), {"x": buf})
        q.flush()
        ev2.wait()                             # unmapped: launches again
        buf.release()

    def test_double_unmap_fails(self, plat):
        dev = plat.get_devices("basic")[0]
        q = CommandQueue(dev, out_of_order=True)
        buf = create_buffer(dev, N, "float32")
        region = q.enqueue_map_buffer(buf, "r")
        region.get()
        first = q.enqueue_unmap_buffer(region)
        q.flush()
        first.wait()
        bad = q.enqueue_unmap_buffer(region)
        q.flush()
        with pytest.raises(CommandError, match="inactive"):
            bad.wait()
        buf.release()

    def test_write_invalidate_skips_read_back(self, plat):
        """MAP_WRITE_INVALIDATE must not run the read-back sync hook;
        read maps must."""
        dev = plat.get_devices("basic")[0]
        q = CommandQueue(dev)
        buf = create_buffer(dev, N, "float32")
        synced = []
        buf.on_map_sync = lambda lo, hi: synced.append((lo, hi))
        r = q.enqueue_map_buffer(buf, "r", offset=0, nbytes=32)
        r.get()
        q.enqueue_unmap_buffer(r)
        q.finish()
        assert synced == [(0, 32)]
        wi = q.enqueue_map_buffer(buf, "wi")
        wi.get()
        q.enqueue_unmap_buffer(wi)
        q.finish()
        assert synced == [(0, 32)], "write-invalidate must skip read-back"
        buf.release()

    def test_failed_map_rolls_back_registration(self, plat):
        """A map whose read-back hook raises must not leave a zombie
        active region wedging the buffer."""
        dev = plat.get_devices("basic")[0]
        q = CommandQueue(dev, out_of_order=True)
        buf = create_buffer(dev, N, "float32")

        def boom(lo, hi):
            raise RuntimeError("sync failed")
        buf.on_map_sync = boom
        qbad = CommandQueue(dev, out_of_order=True)
        bad = qbad.enqueue_map_buffer(buf, "r")
        qbad.flush()
        with pytest.raises(CommandError, match="sync failed"):
            bad.event.wait()
        assert not bad.active and buf.map_count == 0
        buf.on_map_sync = None
        ok = q.enqueue_map_buffer(buf, "rw")   # span is not wedged
        assert ok.get() is not None
        q.enqueue_unmap_buffer(ok)
        q.finish()
        buf.release()

    def test_unmap_publishes_residency_invalidation(self, plat):
        dev = plat.get_devices("basic")[0]
        q = CommandQueue(dev)
        buf = create_buffer(dev, 16, "float32")
        tr = ResidencyTracker()
        buf.bind_residency(tr, "M", "this-dev")
        tr.acquire_spans("M", "other-dev", buf.nbytes)
        region = q.enqueue_map_buffer(buf, "w", offset=8, nbytes=16)
        arr = region.get()
        arr[:] = 5.0
        assert tr.stale_spans("M", "other-dev") == [], \
            "invalidation publishes at unmap, not while mapped"
        q.enqueue_unmap_buffer(region)
        q.finish()
        assert tr.stale_spans("M", "other-dev") == [(8, 24)]
        buf.release()


# ---------------------------------------------------------------------------
# BufferPool (size-class pooling over the arena)
# ---------------------------------------------------------------------------

class TestBufferPool:
    def test_class_rounding_and_reuse(self):
        pool = BufferPool(Bufalloc(1 << 20, alignment=64), min_class=256)
        assert pool.class_of(1) == 256
        assert pool.class_of(257) == 512
        assert pool.class_of(512) == 512
        c1 = pool.alloc(300)
        assert c1.size == 512
        pool.free(c1)
        c2 = pool.alloc(400)                   # same class: free-list pop
        assert c2 is c1
        s = pool.stats()
        assert s["hits"] == 1 and s["misses"] == 1

    def test_foreign_chunk_rejected(self):
        arena = Bufalloc(1 << 16)
        pool = BufferPool(arena)
        foreign = arena.alloc(100)
        with pytest.raises(ValueError):
            pool.free(foreign)

    def test_double_free_rejected(self):
        pool = BufferPool(Bufalloc(1 << 16, alignment=64), min_class=256)
        c = pool.alloc(256)
        pool.free(c)
        with pytest.raises(ValueError, match="double free"):
            pool.free(c)
        assert pool.alloc(256) is c            # still singly parked

    def test_trim_returns_bytes_to_arena(self):
        arena = Bufalloc(1 << 16, alignment=64)
        pool = BufferPool(arena, min_class=256)
        chunks = [pool.alloc(256) for _ in range(4)]
        for c in chunks:
            pool.free(c)
        held = arena.allocated_bytes()
        assert held >= 4 * 256 and pool.pooled_bytes() == held
        freed = pool.trim()
        assert freed == held and arena.allocated_bytes() == 0
        arena.check_invariants()

    def test_oom_trims_and_retries(self):
        arena = Bufalloc(1024, alignment=64)
        pool = BufferPool(arena, min_class=256)
        a = pool.alloc(256)
        b = pool.alloc(256)
        pool.free(b)                           # 256 parked on the free list
        pool.free(a)
        big = pool.alloc(1024)                 # only fits if the pool trims
        assert big.size == 1024
        pool.free(big)

    def test_bounded_free_list_overflows_to_arena(self):
        arena = Bufalloc(1 << 16, alignment=64)
        pool = BufferPool(arena, min_class=256, max_free_per_class=2)
        chunks = [pool.alloc(256) for _ in range(4)]
        for c in chunks:
            pool.free(c)
        assert pool.pooled_bytes() == 2 * 256  # the rest went back
        arena.check_invariants()


# ---------------------------------------------------------------------------
# Differential conformance: views and maps across targets + oracle + splits
# ---------------------------------------------------------------------------

TARGET_DRIVERS = ["basic", "vector", "pallas"]


def _oracle_subbuffer_result() -> np.ndarray:
    """Fiber-oracle emulation of: carve halves of a 2N parent, run axpy
    on each half, paste back."""
    parent = np.arange(2 * N, dtype=np.float32)
    lo = run_ndrange(build_axpy(), (N,), (LSZ,),
                     {"x": parent[:N].copy()})["x"]
    hi = run_ndrange(build_axpy(), (N,), (LSZ,),
                     {"x": parent[N:].copy()})["x"]
    return np.concatenate([lo, hi])


def test_subbuffer_kernels_bitwise_identical_across_targets(plat):
    """Kernels writing through two sub-buffer halves of one parent give
    bitwise-identical parents on loop/vector/pallas and the oracle."""
    expect = _oracle_subbuffer_result()
    for driver in TARGET_DRIVERS:
        dev = plat.get_devices(driver)[0]
        q = CommandQueue(dev)
        buf = create_buffer(dev, 2 * N, "float32")
        q.enqueue_write_buffer(buf, np.arange(2 * N, dtype=np.float32))
        k = dev.build_kernel(build_axpy, (LSZ,))
        lo = create_sub_buffer(buf, 0, N * 4)
        hi = create_sub_buffer(buf, N * 4, N * 4)
        q.enqueue_ndrange_kernel(k, (N,), {"x": lo})
        q.enqueue_ndrange_kernel(k, (N,), {"x": hi})
        q.finish()
        assert buf.data.tobytes() == expect.tobytes(), \
            f"driver {driver} diverged through sub-buffer views"
        buf.release()


def test_mapped_region_kernels_bitwise_identical_across_targets(plat):
    """Init through a WRITE_INVALIDATE map, launch, read through a READ
    map: all targets bitwise-match the oracle."""
    init = (np.arange(N, dtype=np.float32) - N // 2)
    expect = run_ndrange(build_axpy(), (N,), (LSZ,), {"x": init.copy()})["x"]
    for driver in TARGET_DRIVERS:
        dev = plat.get_devices(driver)[0]
        q = CommandQueue(dev)
        buf = create_buffer(dev, N, "float32")
        w = q.enqueue_map_buffer(buf, "wi")
        w.get()[...] = init
        q.enqueue_unmap_buffer(w)
        k = dev.build_kernel(build_axpy, (LSZ,))
        q.enqueue_ndrange_kernel(k, (N,), {"x": buf})
        r = q.enqueue_map_buffer(buf, "r")
        out = r.get().copy()
        q.enqueue_unmap_buffer(r)
        q.finish()
        assert out.tobytes() == expect.tobytes(), \
            f"driver {driver} diverged through mapped regions"
        buf.release()


def test_view_initialized_data_identical_on_1_vs_2_device_split(plat):
    """Data staged through sub-buffer + map writes, then co-executed:
    the 2-device split must be bitwise-identical to the 1-device run."""
    dev = plat.get_devices("basic")[0]
    q = CommandQueue(dev)
    staging = create_buffer(dev, 2 * LSZ * LSZ, "float32")
    left = create_sub_buffer(staging, 0, LSZ * LSZ * 4)
    m = q.enqueue_map_buffer(left, "wi")
    m.get()[...] = np.arange(LSZ * LSZ, dtype=np.float32)
    q.enqueue_unmap_buffer(m)
    right = create_sub_buffer(staging, LSZ * LSZ * 4, LSZ * LSZ * 4)
    m = q.enqueue_map_buffer(right, "wi")
    m.get()[...] = np.arange(LSZ * LSZ, dtype=np.float32)[::-1]
    q.enqueue_unmap_buffer(m)
    q.finish()
    host = staging.data.copy()
    staging.release()

    outs = []
    for ndev in (1, 2):
        co = CoExecutor(plat.co_devices(ndev), chunks_per_device=3)
        merged = co.run(build_scale2, (LSZ,), (2 * LSZ * LSZ,),
                        {"x": host, "y": np.zeros(2 * LSZ * LSZ,
                                                  np.float32)},
                        mode="steal")
        outs.append(np.asarray(merged["y"]))
        co.finish()
    assert outs[0].tobytes() == outs[1].tobytes()
    assert outs[0].tobytes() == (host * 2 + 1).astype(np.float32).tobytes()


# ---------------------------------------------------------------------------
# Regression: group_range write-invalidation granularity (satellite fix)
# ---------------------------------------------------------------------------

def test_group_range_invalidation_is_span_granular(plat):
    """Two devices write disjoint halves of y.  Each device's copy must
    go stale only over the *other* device's half — re-running migrates
    exactly one half per device, not the whole buffer (the pre-fix
    behaviour was a whole-buffer invalidate)."""
    n = 512
    co = CoExecutor(plat.co_devices(2))
    x = co.shared_buffer(np.arange(n, dtype=np.float32), "x")
    y = co.shared_buffer(np.zeros(n, np.float32), "y")
    co.run(build_scale2, (64,), (n,), {"x": x, "y": y}, mode="static")
    d0, d1 = co.devices
    half = n // 2 * 4                          # bytes
    # every y element became nonzero, so written spans are exact halves
    assert co.tracker.stale_spans(y.key, d0, y.nbytes) == [(half, n * 4)]
    assert co.tracker.stale_spans(y.key, d1, y.nbytes) == [(0, half)]
    # x was never written: both copies stay fully valid
    assert co.tracker.resident(x.key, d0)
    assert co.tracker.resident(x.key, d1)

    merged = co.run(build_scale2, (64,), (n,), {"x": x, "y": y},
                    mode="static")
    st = co.last_stats
    assert st.partial_migrations == 2, "each device re-migrates partially"
    assert st.bytes_migrated == n * 4, \
        "one half of y per device — a whole-buffer invalidate would " \
        "move twice that"
    assert st.migrations == 2 and st.residency_hits >= 2
    expect = (np.arange(n, dtype=np.float32) * 2 + 1)
    assert np.asarray(merged["y"]).tobytes() == expect.tobytes()
    # transfer commands are event-ordered, typed, and profiled
    assert all(e.kind == "transfer" for e in st.transfer_events)
    assert all(e.succeeded for e in st.transfer_events)
    co.finish()


def test_group_range_invalidation_span_granular_three_devices(plat):
    """N-device generalization of the half-the-bytes regression: three
    devices write disjoint thirds of y, so each device's copy goes stale
    over exactly the *two* thirds the others wrote, and a repeat run
    re-migrates two thirds per device — 2*n*4 bytes total, not the
    3*n*4 a whole-buffer invalidate would move."""
    n = 768                                     # 12 groups of 64: thirds align
    co = CoExecutor(plat.co_devices(3))
    x = co.shared_buffer(np.arange(n, dtype=np.float32), "x")
    y = co.shared_buffer(np.zeros(n, np.float32), "y")
    co.run(build_scale2, (64,), (n,), {"x": x, "y": y}, mode="static")
    d0, d1, d2 = co.devices
    third = n // 3 * 4                          # bytes
    assert co.tracker.stale_spans(y.key, d0, y.nbytes) == \
        [(third, 3 * third)]
    assert co.tracker.stale_spans(y.key, d1, y.nbytes) == \
        [(0, third), (2 * third, 3 * third)]
    assert co.tracker.stale_spans(y.key, d2, y.nbytes) == \
        [(0, 2 * third)]
    # x was never written: all three copies stay fully valid
    for d in (d0, d1, d2):
        assert co.tracker.resident(x.key, d)

    merged = co.run(build_scale2, (64,), (n,), {"x": x, "y": y},
                    mode="static")
    st = co.last_stats
    assert st.partial_migrations == 3, \
        "each of 3 devices re-migrates partially"
    assert st.bytes_migrated == 2 * n * 4, \
        "two thirds of y per device — a whole-buffer invalidate would " \
        "move 3*n*4"
    assert st.migrations == 3 and st.residency_hits >= 3
    expect = (np.arange(n, dtype=np.float32) * 2 + 1)
    assert np.asarray(merged["y"]).tobytes() == expect.tobytes()
    assert all(e.kind == "transfer" and e.succeeded
               for e in st.transfer_events)
    co.finish()


def test_merge_survives_nan_initialized_buffers(plat):
    """NaN canonical elements must not read as 'written by every chunk'
    (NaN != NaN): a non-writing chunk's stale NaNs would clobber the
    other device's real writes in the merge."""
    n = 256
    co = CoExecutor(plat.co_devices(2))
    x = np.arange(n, dtype=np.float32)
    y = np.full(n, np.nan, np.float32)          # poisoned init
    merged = co.run(build_scale2, (64,), (n,), {"x": x, "y": y},
                    mode="static")
    expect = (x * 2 + 1).astype(np.float32)
    assert np.asarray(merged["y"]).tobytes() == expect.tobytes(), \
        "NaN-initialized buffer lost written elements in the merge"
    co.finish()


def test_scattered_write_merge_falls_back_to_whole_invalidate():
    """_mask_to_byte_spans must return None (whole-buffer commit) for
    patterns beyond the run cap — an envelope would let commit_spans
    validate a writer over spans another device wrote."""
    from repro.runtime.scheduler import _mask_to_byte_spans
    mask = np.zeros(1024, bool)
    mask[::2] = True                            # 512 runs: way past the cap
    assert _mask_to_byte_spans(mask, 4) is None
    dense = np.zeros(1024, bool)
    dense[100:300] = True
    assert _mask_to_byte_spans(dense, 4) == [(400, 1200)]
    assert _mask_to_byte_spans(np.zeros(8, bool), 4) == []


def test_migration_transfers_are_dag_ordered(plat):
    """Chunk kernel commands must depend on their device's transfer
    commands: every transfer END timestamp precedes its device's chunk
    START timestamp."""
    n = 256
    co = CoExecutor(plat.co_devices(2))
    x = co.shared_buffer(np.arange(n, dtype=np.float32), "x")
    y = co.shared_buffer(np.zeros(n, np.float32), "y")
    co.run(build_scale2, (64,), (n,), {"x": x, "y": y}, mode="static")
    st = co.last_stats
    assert len(st.transfer_events) == 4        # 2 buffers x 2 devices
    by_queue = {}
    for ev in st.transfer_events:
        by_queue.setdefault(id(ev.queue), []).append(ev)
    for ev in st.events:
        if ev.kind != "kernel":
            continue
        for t in by_queue.get(id(ev.queue), []):
            assert t.end_ns <= ev.start_ns, \
                "kernel chunk started before its transfer finished"
    co.finish()
