"""Distributed-optimization tricks: gradient compression, hierarchical
collectives, straggler monitor, elastic re-mesh planner."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.training.compression import (
    compress, decompress, compress_grads, decompress_grads)
from repro.training.straggler import (StragglerMonitor, StragglerConfig,
                                      plan_elastic_mesh)
from repro.distributed.collectives import hierarchical_psum


def test_compress_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s = compress(g)
    assert q.dtype == jnp.int8
    err = jnp.abs(decompress(q, s) - g)
    assert float(err.max()) <= float(s) / 2 + 1e-7


def test_error_feedback_reduces_bias():
    """With feedback, the accumulated reconstruction over many steps
    tracks the accumulated true gradient (bias -> 0)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, np.float32)
    recon_sum = np.zeros(64, np.float32)
    grads = {"w": None}
    fb = {"w": jnp.zeros(64, jnp.float32)}
    for step in range(50):
        g = jnp.asarray(rng.normal(size=64) * 0.01, jnp.float32)
        true_sum += np.asarray(g)
        qtree, fb = compress_grads({"w": g}, fb)
        recon = decompress_grads(qtree)
        recon_sum += np.asarray(recon["w"])
    # the residual never exceeds one quantization step (feedback carries it)
    assert np.abs(true_sum - recon_sum).max() < 0.01


def test_compression_ratio():
    g = jnp.ones((1024,), jnp.float32)
    q, s = compress(g)
    assert q.nbytes * 4 == g.nbytes    # 4x fewer bytes than f32


def test_hierarchical_psum_matches_flat():
    """On a 1x1 (pod-less) host mesh the wrapper reduces over 'data'."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(8.0)

    f = shard_map(lambda t: hierarchical_psum(t, mesh), mesh=mesh,
                  in_specs=P("data"), out_specs=P("data"),
                  check_rep=False)
    out = f(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


def test_straggler_monitor_flags_persistent_outlier():
    mon = StragglerMonitor(StragglerConfig(window=10, slow_factor=1.5,
                                           persist_steps=3))
    for step in range(6):
        for h in ("host0", "host1", "host2", "host3"):
            mon.record(h, 1.0)
        mon.record("host4", 3.0)        # persistent straggler
        flagged = mon.check()
    assert flagged == ["host4"]


def test_straggler_transient_not_flagged():
    mon = StragglerMonitor(StragglerConfig(persist_steps=3))
    for step in range(6):
        for h in ("a", "b", "c", "d"):
            mon.record(h, 1.0)
        mon.record("e", 3.0 if step == 2 else 1.0)   # one-off blip
        assert mon.check() == []


def test_elastic_mesh_plan():
    assert plan_elastic_mesh(512) == (2, 16, 16)
    assert plan_elastic_mesh(511) == (1, 16, 16)     # lost a chip -> 1 pod
    assert plan_elastic_mesh(256) == (1, 16, 16)
    assert plan_elastic_mesh(255) == (1, 8, 16)
    assert plan_elastic_mesh(16) == (1, 1, 16)
    assert plan_elastic_mesh(15) is None


def test_elastic_plan_keeps_model_axis():
    for chips in (512, 400, 300, 256, 128, 64):
        plan = plan_elastic_mesh(chips)
        assert plan is not None and plan[2] == 16
        assert plan[0] * plan[1] * plan[2] <= chips
