"""Event DAG runtime: status transitions, profiling, out-of-order
scheduling, multi-device co-execution, and buffer residency."""

import threading
import time

import numpy as np
import pytest

from repro.core import KernelBuilder
from repro.runtime import (CommandError, CommandQueue, CoExecutor,
                           DependencyError, EventStatus, Platform,
                           ResidencyTracker, UserEvent, create_buffer,
                           split_groups)


def build_scale():
    b = KernelBuilder("scale")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    y[g] = x[g] * 2.0 + g
    return b.finish()


@pytest.fixture(scope="module")
def plat():
    return Platform()


# --------------------------------------------------------------------------
# event lifecycle + profiling
# --------------------------------------------------------------------------

def test_event_status_ladder_and_profiling(plat):
    dev = plat.get_devices()[0]
    q = CommandQueue(dev)
    seen = []
    ev = q._enqueue("probe", lambda: seen.append(ev.status), [])
    assert ev.status == EventStatus.QUEUED
    assert ev.queued_ns is not None and ev.submit_ns is None
    q.finish()
    assert seen == [EventStatus.RUNNING], \
        "the command must observe itself RUNNING"
    assert ev.status == EventStatus.COMPLETE and ev.succeeded
    p = ev.profile
    # profiling counters populated and monotone:
    # queued <= submit <= start <= end
    assert None not in p.values()
    assert p["queued_ns"] <= p["submit_ns"] <= p["start_ns"] <= p["end_ns"]
    assert ev.duration_us is not None and ev.duration_us >= 0


def test_profiling_counters_monotone_across_chain(plat):
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True, workers=4)
    evs = [q._enqueue(f"c{i}", lambda: time.sleep(0.002), []) for i in
           range(3)]
    chained = q._enqueue("tail", lambda: None, evs)
    q.finish()
    for ev in evs + [chained]:
        p = ev.profile
        assert p["queued_ns"] <= p["submit_ns"] <= p["start_ns"] \
            <= p["end_ns"]
    # the dependent command is submitted only after every dep completed
    assert chained.submit_ns >= max(e.end_ns for e in evs)


def test_error_propagates_to_waiters_and_dependents(plat):
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True, workers=2)

    def boom():
        raise ValueError("kaboom")

    ran = []
    e1 = q._enqueue("boom", boom, [])
    e2 = q._enqueue("after", lambda: ran.append(1), [e1])
    q.flush()
    with pytest.raises(CommandError):
        e1.wait()
    with pytest.raises(DependencyError):
        e2.wait()
    assert e1.status < 0 and e2.status < 0, \
        "failed commands get a negative status (OpenCL convention)"
    assert not ran, "dependents of a failed command must not run"
    with pytest.raises(CommandError):
        q.finish()


def test_user_event_gates_commands(plat):
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True, workers=2)
    gate = UserEvent("gate")
    ran = []
    ev = q._enqueue("gated", lambda: ran.append(1), [gate])
    q.flush()
    time.sleep(0.02)
    assert not ran and not ev.done, "command must wait for the user event"
    gate.complete()
    q.finish()
    assert ran == [1] and ev.succeeded


def test_finish_timeout_reports_stuck_commands(plat):
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True)
    gate = UserEvent("never")
    q._enqueue("stuck", lambda: None, [gate])
    with pytest.raises(RuntimeError, match="stuck"):
        q.finish(timeout=0.05)
    gate.complete()
    q.finish()


def test_requeued_command_cancelled_not_reported_stuck(plat):
    """Mesh-requeue race (docs/mesh.md §Failure ladder): a command whose
    request migrated to a sibling replica is cancelled on the losing
    queue — ``finish(timeout)`` must observe it as *failed typed*, fast,
    never time out naming it as stuck."""
    from repro.core.errors import DeviceLostError

    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True)
    gate = UserEvent("never-resolves")
    ran = []
    armed = q._enqueue("migrated:r7", lambda: ran.append(1), [gate])
    q.flush()                       # armed, gated on the dead device
    unflushed = q._enqueue("migrated:r8", lambda: ran.append(2), [])
    lost = DeviceLostError("replica 0 lost")
    victims = q.cancel_pending(lost)
    assert armed in victims and unflushed in victims
    assert armed.failed and armed.error is lost
    assert unflushed.failed and unflushed.error is lost
    t0 = time.perf_counter()
    with pytest.raises(CommandError):   # failed typed — not RuntimeError
        q.finish(timeout=30.0)
    assert time.perf_counter() - t0 < 5.0   # returned, did not time out
    assert ran == []                # cancelled commands never execute
    gate.complete()                 # late resolution must not resubmit
    q.finish()
    assert ran == []


def test_cancel_pending_spares_submitted_commands(plat):
    """Only commands that cannot have started are cancellable; work
    already on a worker runs to completion."""
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True)
    started = threading.Event()
    release = threading.Event()

    def running():
        started.set()
        release.wait(5.0)

    ev = q.enqueue_native(running, name="in-flight")
    q.flush()
    assert started.wait(5.0)
    assert q.cancel_pending() == []     # nothing cancellable
    release.set()
    q.finish()
    assert ev.succeeded


# --------------------------------------------------------------------------
# DAG ordering under out-of-order execution
# --------------------------------------------------------------------------

def test_dag_ordering_out_of_order_4_workers(plat):
    """A 3-chain x 4-stage lattice on a 4-worker out-of-order queue:
    every chain's stages run in order; chains interleave freely."""
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True, workers=4)
    order = []
    lock = threading.Lock()

    def mk(tag):
        def fn():
            time.sleep(0.003)
            with lock:
                order.append(tag)
        return fn

    tails = {}
    for chain in range(3):
        ev = None
        for stage in range(4):
            deps = [ev] if ev is not None else []
            ev = q._enqueue(f"{chain}:{stage}", mk((chain, stage)), deps)
        tails[chain] = ev
    q.finish()
    assert len(order) == 12
    for chain in range(3):
        stages = [s for c, s in order if c == chain]
        assert stages == sorted(stages), f"chain {chain} ran out of order"
    # with 4 workers the three independent chains must actually interleave
    first_six_chains = {c for c, _ in order[:6]}
    assert len(first_six_chains) > 1, "chains did not overlap"


def test_diamond_dependency_graph(plat):
    """A -> (B, C) -> D: B and C wait for A, D waits for both."""
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True, workers=4)
    order = []
    lock = threading.Lock()

    def mk(tag, dur=0.005):
        def fn():
            time.sleep(dur)
            with lock:
                order.append(tag)
        return fn

    a = q._enqueue("A", mk("A"), [])
    b = q._enqueue("B", mk("B"), [a])
    c = q._enqueue("C", mk("C"), [a])
    d = q._enqueue("D", mk("D"), [b, c])
    q.finish()
    assert order[0] == "A" and order[-1] == "D"
    assert set(order[1:3]) == {"B", "C"}
    assert d.submit_ns >= max(b.end_ns, c.end_ns)


def test_in_order_queue_preserves_explicit_wait_list(plat):
    """An in-order queue ADDS the implicit previous-command edge; it must
    never drop the explicit wait_for list (cross-queue deps rely on it)."""
    dev = plat.get_devices()[0]
    q_other = CommandQueue(dev, out_of_order=True)
    gate = UserEvent("xq")
    far = q_other._enqueue("far", lambda: None, [gate])
    q_other.flush()

    q = CommandQueue(dev)  # in-order
    ran = []
    q._enqueue("first", lambda: ran.append("first"), [])
    ev = q._enqueue("xdep", lambda: ran.append("xdep"), [far])
    q.flush()
    time.sleep(0.02)
    assert "xdep" not in ran, "explicit cross-queue wait_for was dropped"
    gate.complete()
    q.finish()
    q_other.finish()
    assert ran == ["first", "xdep"]
    assert far in [far]  # silence lint; far must be complete
    assert ev.succeeded


def test_marker_and_barrier(plat):
    dev = plat.get_devices()[0]
    q = CommandQueue(dev, out_of_order=True, workers=4)
    done = []
    for i in range(4):
        q._enqueue(f"w{i}", lambda i=i: (time.sleep(0.002),
                                         done.append(i)), [])
    m = q.enqueue_marker()
    bar = q.enqueue_barrier()
    after = q._enqueue("after", lambda: done.append("after"), [])
    q.finish()
    assert done[-1] == "after", "commands after a barrier wait for it"
    assert m.succeeded and bar.succeeded
    assert after.submit_ns >= bar.end_ns


# --------------------------------------------------------------------------
# kernel pipeline over the DAG (buffers + events)
# --------------------------------------------------------------------------

def test_event_ordered_kernel_pipeline(plat):
    dev = plat.get_devices()[0]
    n = 128
    k = dev.build_kernel(build_scale, (64,))
    q = CommandQueue(dev, out_of_order=True, workers=4)
    xb = create_buffer(dev, n, "float32")
    yb = create_buffer(dev, n, "float32")
    host = np.arange(n, dtype=np.float32)
    out = np.zeros(n, np.float32)
    e_w = q.enqueue_write_buffer(xb, host)
    e_k = q.enqueue_ndrange_kernel(k, (n,), {"x": xb, "y": yb},
                                   wait_for=[e_w])
    e_r = q.enqueue_read_buffer(yb, out, wait_for=[e_k])
    q.finish()
    np.testing.assert_array_equal(out, host * 2 + np.arange(n))
    assert e_w.succeeded and e_k.succeeded and e_r.succeeded
    xb.release()
    yb.release()


# --------------------------------------------------------------------------
# multi-device co-execution
# --------------------------------------------------------------------------

def test_split_groups_proportional():
    assert split_groups(8, [1, 1]) == [(0, 4), (4, 8)]
    assert split_groups(8, [3, 1]) == [(0, 6), (6, 8)]
    spans = split_groups(7, [1, 1, 1])
    assert spans[0][0] == 0 and spans[-1][1] == 7
    assert all(a <= b for a, b in spans)
    # spans tile the range contiguously
    for (_, e0), (s1, _) in zip(spans, spans[1:]):
        assert e0 == s1


def test_split_groups_rejects_degenerate_shares():
    """Zero-sum / negative / NaN / infinite / empty / non-numeric shares
    must raise a typed InvalidArgError, never emit overlapping spans."""
    from repro.runtime import InvalidArgError
    for bad in ([], [0.0, 0.0], [-1.0, 2.0], [float("nan"), 1.0],
                [float("inf"), 1.0], ["x", 1.0], [1.0, None]):
        with pytest.raises(InvalidArgError):
            split_groups(8, bad)
    with pytest.raises(InvalidArgError):
        split_groups(-1, [1.0])
    with pytest.raises(InvalidArgError):
        split_groups("eight", [1.0])


def test_split_groups_rounding_boundaries():
    """Shares that don't sum to 1, zero shares, fewer groups than
    devices, and 1-group splits: spans always partition [0, n)."""
    # shares need not sum to 1 — only ratios matter
    assert split_groups(8, [0.2, 0.2]) == split_groups(8, [1, 1])
    assert split_groups(10, [0.75]) == [(0, 10)]
    # a zero share yields an empty span, never an overlap
    assert split_groups(8, [0.0, 1.0]) == [(0, 0), (0, 8)]
    assert split_groups(8, [1.0, 0.0]) == [(0, 8), (8, 8)]
    # n_groups < n_devices: normalized — some spans empty, union exact
    for n, shares in [(1, [1, 1, 1]), (2, [1, 1, 1, 1, 1]),
                      (0, [1, 1]), (3, [5, 1, 1, 1])]:
        spans = split_groups(n, shares)
        assert len(spans) == len(shares)
        assert spans[0][0] == 0 and spans[-1][1] == n
        for (_, e0), (s1, _) in zip(spans, spans[1:]):
            assert e0 == s1                      # contiguous
        assert all(a <= b for a, b in spans)     # no negative spans
        assert sum(b - a for a, b in spans) == n  # exact partition
    # 1-group split lands the group on exactly one device
    spans = split_groups(1, [1, 3])
    assert sum(b - a for a, b in spans) == 1
    # extreme skew still covers the range
    spans = split_groups(100, [1e-9, 1.0])
    assert spans[-1][1] == 100 and spans[0] == (0, 0)


@pytest.mark.parametrize("mode", ["static", "steal"])
def test_multi_device_split_bitwise_identical(plat, mode):
    """An out-of-order multi-device run of the kernel must be *bitwise*
    identical to the single-device run (acceptance criterion)."""
    n = 512
    host = np.arange(n, dtype=np.float32)
    single_dev = plat.get_devices("vector")[0]
    k = single_dev.build_kernel(build_scale, (64,))
    single = k({"x": host, "y": np.zeros(n, np.float32)}, (n,))

    co = CoExecutor(plat.co_devices(2), chunks_per_device=3)
    merged = co.run(build_scale, (64,), (n,),
                    {"x": host, "y": np.zeros(n, np.float32)}, mode=mode)
    assert merged["y"].tobytes() == np.asarray(single["y"]).tobytes(), \
        "multi-device result differs bitwise from single-device"
    st = co.last_stats
    assert st.n_groups == n // 64
    assert sum(st.groups_per_device.values()) == st.n_groups, \
        "every work-group must be executed exactly once"
    if mode == "steal":
        assert sum(st.chunks_per_device.values()) >= 2
    co.finish()


def test_static_split_respects_weights(plat):
    n = 512
    host = np.arange(n, dtype=np.float32)
    co = CoExecutor(plat.co_devices(2))
    co.run(build_scale, (64,), (n,),
           {"x": host, "y": np.zeros(n, np.float32)},
           mode="static", weights=[3, 1])
    g = co.last_stats.groups_per_device
    names = sorted(g)
    assert g[names[0]] == 6 and g[names[1]] == 2
    co.finish()


def test_residency_copied_once_not_per_launch(plat):
    """8 chunk launches across 2 devices must migrate each buffer once
    per device; a second run on clean (read-only) buffers migrates
    nothing."""
    n = 512
    host = np.arange(n, dtype=np.float32)
    co = CoExecutor(plat.co_devices(2), chunks_per_device=4)
    xs = co.shared_buffer(host, "x")
    ys = co.shared_buffer(np.zeros(n, np.float32), "y")
    co.run(build_scale, (64,), (n,), {"x": xs, "y": ys}, mode="steal")
    st = co.last_stats
    assert sum(st.chunks_per_device.values()) == 8
    assert st.migrations == 4, \
        "each of 2 buffers copied once per device, not once per chunk"
    assert st.residency_hits > 0
    # x is read-only and y has converged -> second run may refresh y (it
    # was written) but must NOT recopy x
    co.run(build_scale, (64,), (n,), {"x": xs, "y": ys}, mode="steal")
    st2 = co.last_stats
    assert st2.migrations <= 2, "read-only buffer was re-migrated"
    co.finish()


def test_residency_tracker_contract():
    tr = ResidencyTracker()
    assert tr.acquire("b", "d0") is True      # first read: migrate
    assert tr.acquire("b", "d0") is False     # second read: resident
    assert tr.acquire("b", "d1") is True
    tr.wrote("b", "d1")                        # d1 wrote: d0 stale
    assert tr.acquire("b", "d0") is True
    assert tr.resident("b", "d1")
    tr.drop("b")
    assert not tr.resident("b", "d1")
    s = tr.stats()
    assert s["migrations"] == 3 and s["hits"] == 1


# --------------------------------------------------------------------------
# per-device autotuning keys
# --------------------------------------------------------------------------

def test_tuning_keys_are_per_device():
    from repro.core import TuningTable
    key_a = TuningTable.make_key("iriri", (8,), (32,), [], device="dev-a")
    key_b = TuningTable.make_key("iriri", (8,), (32,), [], device="dev-b")
    bare = TuningTable.make_key("iriri", (8,), (32,), [])
    assert key_a != key_b and key_a != bare, \
        "tuning decisions must be keyed per device"
    t = TuningTable()
    t.record(key_a, "vector", {"vector": 1.0})
    t.record(key_b, "loop", {"loop": 1.0})
    assert t.get(key_a) == "vector" and t.get(key_b) == "loop"


def test_autotuned_device_key_flows_from_runtime(plat):
    from repro.core import TuningTable, set_default_table
    table = TuningTable()
    set_default_table(table)
    try:
        dev = plat.get_devices("auto")[0]
        k = dev.build_kernel(build_scale, (64,))
        assert k.device_key == dev.info.name
        n = 128
        k({"x": np.arange(n, dtype=np.float32),
           "y": np.zeros(n, np.float32)}, (n,))
        assert len(table) == 1
        key = TuningTable.make_key(k._ir, (64,), (n,),
                                   sorted(k.options.items()),
                                   device=dev.info.name)
        assert table.get(key) is not None, \
            "the recorded winner must live under the device-scoped key"
    finally:
        set_default_table(None)
