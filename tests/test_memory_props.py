"""Stateful property-test harness for the hierarchical memory subsystem.

A hypothesis :class:`RuleBasedStateMachine` drives random interleavings
of the full op vocabulary — arena alloc/free, pooled alloc/free/trim,
sub-view creation, writes through aliased views, map/unmap publication,
whole-buffer writes, and span-granular migration — against the *real*
:class:`~repro.runtime.bufalloc.Bufalloc`,
:class:`~repro.runtime.memory.BufferPool` and
:class:`~repro.runtime.bufalloc.ResidencyTracker`, checking after every
step the structural invariants the paper's allocator design promises
(§3) and the residency contract the migration subsystem depends on
(docs/memory.md):

* chunks are contiguous, non-overlapping, in-region, aligned;
* the **sentinel is the last chunk**;
* no two adjacent free chunks survive (free-neighbour coalescing);
* pool chunks are real arena chunks of exactly one size class;
* **residency is never stale**: after ``acquire_spans`` + copying the
  returned spans, a device copy is byte-identical to the canonical
  contents, no matter which aliased views wrote what where.

The byte-level mirror model (plain numpy arrays per device) is the
oracle: the tracker only has to *report* enough staleness; the harness
fails the moment a reported-clean byte diverges.

The op/oracle logic lives in :class:`ModelDriver`, which needs no
hypothesis — a seeded random-walk test drives it on every install, and
the hypothesis state machine (run under the ``ci``/``dev`` profiles
registered in tests/conftest.py) adds minimized counterexamples and
bundle-based lifetime coverage where hypothesis is available.
"""

import random

import numpy as np
import pytest

from repro.runtime.bufalloc import (Bufalloc, OutOfMemory, ResidencyTracker,
                                    span_subtract, span_total, span_union)
from repro.runtime.memory import BufferPool

try:
    from hypothesis import given, strategies as st
    from hypothesis.stateful import (Bundle, RuleBasedStateMachine,
                                     consumes, initialize, invariant,
                                     multiple, rule)
    HAVE_HYPOTHESIS = True
except ImportError:               # plain tests below still run
    HAVE_HYPOTHESIS = False

ARENA_BYTES = 1 << 16
ALIGN = 32
TBUF_BYTES = 256                  # logical tracked-buffer size
DEVICES = ["d0", "d1", "d2", "host"]


class ModelDriver:
    """The machine body: real subsystems + byte-level oracle model.

    Every op method performs the real operation, updates the mirror
    model, and asserts the op-local contract; :meth:`check_invariants`
    asserts the global structural invariants.  Drivable by hypothesis
    rules or by a plain seeded random walk.
    """

    def __init__(self, greedy: bool):
        self.arena = Bufalloc(ARENA_BYTES, alignment=ALIGN, greedy=greedy)
        self.pool = BufferPool(self.arena, min_class=64,
                               max_free_per_class=3)
        self.tracker = ResidencyTracker()
        self.nbuf = 0
        self.stamp = 0
        # model: key -> {"canon": uint8[TBUF], "copies": {dev: uint8[TBUF]}}
        self.model = {}
        # mapped-but-not-unmapped writes: their spans are undefined for
        # everyone until unmap publishes them (OpenCL §5.4.3), so the
        # writer's copy is exempt from the staleness oracle there
        self.pending = []

    # -- helpers ---------------------------------------------------------------
    def _next_stamp(self) -> int:
        self.stamp = (self.stamp + 1) % 251 + 1   # never 0 (the init value)
        return self.stamp

    def _copy_of(self, m, dev) -> np.ndarray:
        if dev not in m["copies"]:
            m["copies"][dev] = np.zeros(TBUF_BYTES, np.uint8)
        return m["copies"][dev]

    # -- arena ops -------------------------------------------------------------
    def arena_alloc(self, size):
        try:
            c = self.arena.alloc(size)
        except OutOfMemory:
            return None
        assert c.start % ALIGN == 0, "alignment violated"
        assert c.size >= size
        assert c.start + c.size <= ARENA_BYTES, "chunk out of region"
        assert not c.free
        return c

    def arena_free(self, chunk):
        self.arena.free(chunk)

    # -- pool ops --------------------------------------------------------------
    def pool_alloc(self, size):
        try:
            c = self.pool.alloc(size)
        except OutOfMemory:
            return None
        assert c.size == self.pool.class_of(size) >= size
        assert not c.free, "pool handed out a chunk the arena thinks is free"
        return c

    def pool_free(self, chunk):
        self.pool.free(chunk)

    def pool_trim(self):
        before = self.arena.allocated_bytes()
        freed = self.pool.trim()
        assert self.arena.allocated_bytes() == before - freed

    # -- residency ops ----------------------------------------------------------
    def create_tracked_buffer(self):
        key = f"b{self.nbuf}"
        self.nbuf += 1
        self.model[key] = {"canon": np.zeros(TBUF_BYTES, np.uint8),
                           "copies": {}}
        return key

    def write_through_view(self, key, lo, hi, dev):
        """An aliased-view write on one device: canonical contents move
        forward, the writer's copy follows, and the tracker is told the
        exact span."""
        m = self.model.get(key)
        if m is None:
            return
        val = self._next_stamp()
        m["canon"][lo:hi] = val
        self._copy_of(m, dev)[lo:hi] = val
        self.tracker.wrote_span(key, dev, lo, hi)

    def map_view(self, key, lo, hi, dev):
        """Mapped-region lifecycle, part 1: the write lands in the
        writer's copy immediately (zero-copy view) but is *published* to
        the tracker only at unmap — exactly MappedRegion's contract."""
        m = self.model.get(key)
        if m is None:
            return None
        val = self._next_stamp()
        self._copy_of(m, dev)[lo:hi] = val
        mapped = (key, lo, hi, dev, val)
        self.pending.append(mapped)
        return mapped

    def unmap_view(self, mapped):
        if mapped in self.pending:
            self.pending.remove(mapped)
        key, lo, hi, dev, val = mapped
        m = self.model.get(key)
        if m is None:
            return
        m["canon"][lo:hi] = val             # unmap publishes the write
        self._copy_of(m, dev)[lo:hi] = val  # (map may have been re-written)
        self.tracker.wrote_span(key, dev, lo, hi)

    def write_whole(self, key, dev):
        m = self.model.get(key)
        if m is None:
            return
        val = self._next_stamp()
        m["canon"][:] = val
        m["copies"] = {dev: np.full(TBUF_BYTES, val, np.uint8)}
        self.tracker.wrote(key, dev)

    def migrate(self, key, dev):
        """THE core property: acquire_spans + copying exactly the
        returned spans must leave the device copy byte-identical to the
        canonical contents — residency is never stale, through any
        interleaving of aliased writes."""
        m = self.model.get(key)
        if m is None:
            return
        spans = self.tracker.acquire_spans(key, dev, TBUF_BYTES)
        prev = 0
        for lo, hi in spans:                # sorted, disjoint, in-range
            assert 0 <= lo < hi <= TBUF_BYTES
            assert lo >= prev, "spans must be sorted and disjoint"
            prev = hi
        copy = self._copy_of(m, dev)
        for lo, hi in spans:
            copy[lo:hi] = m["canon"][lo:hi]
        # bytes under this device's *pending* maps are undefined until
        # unmap publishes them; everything else must match canonical
        defined = np.ones(TBUF_BYTES, bool)
        for pkey, lo, hi, pdev, _ in self.pending:
            if pkey == key and pdev == dev:
                defined[lo:hi] = False
        assert np.array_equal(copy[defined], m["canon"][defined]), \
            f"device {dev} copy of {key} stale after migration: " \
            f"tracker under-reported staleness"

    def drop_tracked_buffer(self, key):
        self.tracker.drop(key)
        self.model.pop(key, None)
        self.pending = [p for p in self.pending if p[0] != key]

    # -- global invariants -------------------------------------------------------
    def check_invariants(self):
        # contiguity, sizes, prev/next links, sentinel-last, coalescing
        self.arena.check_invariants()
        a = self.arena
        assert a.allocated_bytes() + a.free_bytes() == ARENA_BYTES
        assert a.allocated_bytes() == sum(
            c.size for c in a.chunks() if not c.free)
        arena_chunks = {id(c) for c in a.chunks() if not c.free}
        for lst in self.pool._free.values():
            for c in lst:
                assert id(c) in arena_chunks, \
                    "pool free list holds a chunk the arena freed"


# ---------------------------------------------------------------------------
# Plain seeded random walk (runs even without hypothesis installed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_memory_model_random_walk(seed):
    rng = random.Random(seed)
    drv = ModelDriver(greedy=bool(seed % 2))
    chunks, pooled, tbufs, views, maps = [], [], [], [], []
    ops = ["arena_alloc", "arena_free", "pool_alloc", "pool_free",
           "pool_trim", "new_tbuf", "new_view", "write_view", "map",
           "unmap", "write_whole", "migrate", "drop"]
    for step in range(600):
        op = rng.choice(ops)
        if op == "arena_alloc":
            c = drv.arena_alloc(rng.randint(1, 2000))
            if c is not None:
                chunks.append(c)
        elif op == "arena_free" and chunks:
            drv.arena_free(chunks.pop(rng.randrange(len(chunks))))
        elif op == "pool_alloc":
            c = drv.pool_alloc(rng.randint(1, 1500))
            if c is not None:
                pooled.append(c)
        elif op == "pool_free" and pooled:
            drv.pool_free(pooled.pop(rng.randrange(len(pooled))))
        elif op == "pool_trim":
            drv.pool_trim()
        elif op == "new_tbuf" and len(tbufs) < 6:
            tbufs.append(drv.create_tracked_buffer())
        elif op == "new_view" and tbufs:
            lo = rng.randrange(TBUF_BYTES)
            hi = min(TBUF_BYTES, lo + rng.randint(1, TBUF_BYTES))
            views.append((rng.choice(tbufs), lo, hi))
        elif op == "write_view" and views:
            key, lo, hi = rng.choice(views)
            drv.write_through_view(key, lo, hi, rng.choice(DEVICES))
        elif op == "map" and views:
            key, lo, hi = rng.choice(views)
            mp = drv.map_view(key, lo, hi, rng.choice(DEVICES))
            if mp is not None:
                maps.append(mp)
        elif op == "unmap" and maps:
            drv.unmap_view(maps.pop(rng.randrange(len(maps))))
        elif op == "write_whole" and tbufs:
            drv.write_whole(rng.choice(tbufs), rng.choice(DEVICES))
        elif op == "migrate" and tbufs:
            drv.migrate(rng.choice(tbufs), rng.choice(DEVICES))
        elif op == "drop" and tbufs:
            key = tbufs.pop(rng.randrange(len(tbufs)))
            views = [v for v in views if v[0] != key]
            maps = [mp for mp in maps if mp[0] != key]
            drv.drop_tracked_buffer(key)
        drv.check_invariants()
    # drain: every tracked copy converges to canonical
    for key in tbufs:
        for dev in DEVICES:
            drv.migrate(key, dev)
    for c in chunks:
        drv.arena_free(c)
    for c in pooled:
        drv.pool_free(c)
    drv.pool_trim()
    drv.check_invariants()
    assert drv.arena.allocated_bytes() == 0
    assert drv.arena.largest_free() == ARENA_BYTES


# ---------------------------------------------------------------------------
# Span-algebra properties (plain checks + seeded driver)
# ---------------------------------------------------------------------------

def _bytes_of(spans):
    out = set()
    for lo, hi in spans:
        out.update(range(lo, hi))
    return out


def check_span_union(spans):
    acc = []
    for lo, hi in spans:
        acc = span_union(acc, lo, hi)
        for (a, b), (c, d) in zip(acc, acc[1:]):
            assert b < c, "overlapping/touching spans must merge"
    assert _bytes_of(acc) == _bytes_of(spans)
    assert span_total(acc) == len(_bytes_of(spans))
    return acc


def check_span_subtract(spans, cut):
    acc = check_span_union(spans)
    out = span_subtract(acc, *cut)
    assert _bytes_of(out) == _bytes_of(acc) - _bytes_of([cut])


def check_tracker_vs_bytewise_model(ops, size=128):
    """Random wrote_span/acquire_spans interleavings vs a brute-force
    per-byte validity model: the spans acquire_spans returns must cover
    *exactly* the stale bytes (under-reporting loses writes,
    over-reporting re-copies clean data)."""
    tr = ResidencyTracker()
    valid = {}                      # dev -> bool[size] (present = has copy)
    for op, dev, (lo, hi) in ops:
        if op == "w":
            tr.wrote_span("k", dev, lo, hi)
            for d, v in valid.items():
                if d != dev:
                    v[lo:hi] = False
            if dev not in valid:
                valid[dev] = np.zeros(size, bool)
            valid[dev][lo:hi] = True
        else:
            spans = tr.acquire_spans("k", dev, size)
            got = _bytes_of(spans)
            model_stale = set(np.flatnonzero(
                ~valid[dev]).tolist()) if dev in valid else set(range(size))
            assert got == model_stale, \
                f"acquire_spans reported {sorted(got)[:8]}..., model " \
                f"says {sorted(model_stale)[:8]}..."
            if dev not in valid:
                valid[dev] = np.zeros(size, bool)
            valid[dev][:] = True     # fully migrated


def _rand_span(rng, size=128):
    lo = rng.randrange(size)
    return (lo, min(size, lo + rng.randint(1, size // 2)))


@pytest.mark.parametrize("seed", range(8))
def test_span_algebra_random(seed):
    rng = random.Random(seed)
    spans = [_rand_span(rng) for _ in range(rng.randint(0, 12))]
    check_span_union(spans)
    check_span_subtract(spans, _rand_span(rng))


@pytest.mark.parametrize("seed", range(8))
def test_tracker_matches_bytewise_model_random(seed):
    rng = random.Random(100 + seed)
    ops = [(rng.choice("wr"), rng.choice(DEVICES), _rand_span(rng))
           for _ in range(rng.randint(1, 24))]
    check_tracker_vs_bytewise_model(ops)


# ---------------------------------------------------------------------------
# Hypothesis layer: the RuleBasedStateMachine + minimized span properties
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    class MemoryMachine(RuleBasedStateMachine):
        """Bundle-based lifetimes over :class:`ModelDriver`: hypothesis
        explores alloc/free/sub-buffer/map/write/migrate interleavings
        (with shrinking) that the seeded walk samples only sparsely."""

        chunks = Bundle("chunks")      # direct arena allocations
        pooled = Bundle("pooled")      # pool allocations
        tbufs = Bundle("tbufs")        # residency-tracked logical buffers
        views = Bundle("views")        # aliased sub-views of tracked buffers
        maps = Bundle("maps")          # pending (mapped, unpublished) writes

        @initialize(greedy=st.booleans())
        def init(self, greedy):
            self.drv = ModelDriver(greedy=greedy)

        @rule(target=chunks, size=st.integers(1, 2000))
        def arena_alloc(self, size):
            c = self.drv.arena_alloc(size)
            return c if c is not None else multiple()

        @rule(chunk=consumes(chunks))
        def arena_free(self, chunk):
            self.drv.arena_free(chunk)

        @rule(target=pooled, size=st.integers(1, 1500))
        def pool_alloc(self, size):
            c = self.drv.pool_alloc(size)
            return c if c is not None else multiple()

        @rule(chunk=consumes(pooled))
        def pool_free(self, chunk):
            self.drv.pool_free(chunk)

        @rule()
        def pool_trim(self):
            self.drv.pool_trim()

        @rule(target=tbufs)
        def create_tracked_buffer(self):
            return self.drv.create_tracked_buffer()

        @rule(target=views, key=tbufs,
              bounds=st.tuples(st.integers(0, TBUF_BYTES - 1),
                               st.integers(1, TBUF_BYTES)))
        def create_view(self, key, bounds):
            lo, length = bounds
            return (key, lo, min(TBUF_BYTES, lo + length))

        @rule(view=views, dev=st.sampled_from(DEVICES))
        def write_through_view(self, view, dev):
            self.drv.write_through_view(*view, dev)

        @rule(target=maps, view=views, dev=st.sampled_from(DEVICES))
        def map_view(self, view, dev):
            mp = self.drv.map_view(*view, dev)
            return mp if mp is not None else multiple()

        @rule(mapped=consumes(maps))
        def unmap_view(self, mapped):
            self.drv.unmap_view(mapped)

        @rule(key=tbufs, dev=st.sampled_from(DEVICES))
        def write_whole(self, key, dev):
            self.drv.write_whole(key, dev)

        @rule(key=tbufs, dev=st.sampled_from(DEVICES))
        def migrate(self, key, dev):
            self.drv.migrate(key, dev)

        @rule(key=consumes(tbufs))
        def drop_tracked_buffer(self, key):
            self.drv.drop_tracked_buffer(key)

        @invariant()
        def structurally_sound(self):
            self.drv.check_invariants()

    TestMemoryMachine = MemoryMachine.TestCase

    span_st = st.tuples(st.integers(0, 127), st.integers(1, 64)).map(
        lambda t: (t[0], min(128, t[0] + t[1])))

    @given(st.lists(span_st, max_size=12))
    def test_span_union_matches_set_semantics(spans):
        check_span_union(spans)

    @given(st.lists(span_st, max_size=8), span_st)
    def test_span_subtract_matches_set_semantics(spans, cut):
        check_span_subtract(spans, cut)

    @given(st.lists(st.tuples(st.sampled_from(["w", "r"]),
                              st.sampled_from(DEVICES), span_st),
                    min_size=1, max_size=24))
    def test_tracker_staleness_matches_bytewise_model(ops):
        check_tracker_vs_bytewise_model(ops)
