"""Chrome-trace export: schema validation + golden skeleton.

A deterministic recorded scenario — a 2-device co-execution plus a
fused-chain launch and a continuous-batching serving step, all under
one :class:`~repro.runtime.trace.ChromeTrace` — is exported and
checked two ways (docs/mesh.md §Observability):

* :func:`~repro.runtime.trace.validate_trace` enforces the Chrome Trace
  Event Format subset structurally: required ``ph``/``ts``/``pid``/
  ``tid`` fields per phase, non-negative monotone-consistent
  timestamps, ``ph:"s"``/``ph:"f"`` flow pairing, and every slice row
  named by ``M`` metadata;
* a **golden skeleton** (tests/golden/trace_schema.json) pins the
  normalized shape of what the exporter emits — the sorted distinct
  ``ph``/``cat``/name triples with digits collapsed — so an exporter
  change that silently drops slices, counters, or flow arrows fails
  loudly.  Regenerate intentionally with::

      REPRO_UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_trace.py
"""

import json
import os
import re

import numpy as np
import pytest

from repro.core import KernelBuilder
from repro.runtime import ChromeTrace, Platform, validate_trace
from repro.runtime.context import Context
from repro.serving import Request, ServingEngine, StubExecutor

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
N = 256
LSZ = (64,)


def build_scale():
    b = KernelBuilder("scale")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    y[g] = x[g] * 2.0 + g
    return b.finish()


def recorded_run():
    """The fixed scenario the golden pins: co-exec on 2 devices, a
    fused kernel chain, and a serving step, one trace."""
    from repro.core.examples import build_residual_add, build_rmsnorm_ew

    plat = Platform()
    ctx = Context(platform=plat)
    with ctx.trace() as tr:
        # -- fused chain on a context queue (fused_from provenance)
        prog = ctx.create_program(build_rmsnorm_ew, build_residual_add)
        bufs = {nm: ctx.create_buffer(N) for nm in "xwryz"}
        q = ctx.create_queue(ctx.devices[0], fusion="flush")
        q.enqueue_write_buffer(bufs["x"],
                               np.ones(N, np.float32))
        q.enqueue_write_buffer(bufs["w"],
                               np.ones(N, np.float32))
        q.enqueue_write_buffer(bufs["r"],
                               np.ones(N, np.float32))
        k1 = prog.create_kernel("rmsnorm_ew")
        k1.set_args(x=bufs["x"], w=bufs["w"], y=bufs["y"], inv_rms=0.5)
        k2 = prog.create_kernel("residual_add")
        k2.set_args(y=bufs["y"], r=bufs["r"], z=bufs["z"])
        q.enqueue_nd_range(k1, (N,), LSZ)
        q.enqueue_nd_range(k2, (N,), LSZ)
        q.finish()

        # -- 2-device co-execution; the co-executor owns its queues, so
        # they attach explicitly (one trace row per device queue)
        co = ctx.create_co_executor(plat.co_devices(2),
                                    chunks_per_device=2)
        for d, cq in co.queues.items():
            tr.attach_queue(cq, process=d.info.name)
        co.run(build_scale, LSZ, (N,),
               {"x": np.arange(N, dtype=np.float32),
                "y": np.zeros(N, np.float32)},
               mode="static", weights=[1.0, 1.0])
        co.finish()

        # -- one continuous-batching serving step (native DAG commands
        # through a context queue) + a counter sample
        eng = ServingEngine(None, None, None, batch_slots=2, max_seq=32,
                            executor=StubExecutor(batch_slots=2,
                                                  max_seq=32),
                            context=ctx)
        for i in range(3):
            eng.submit(Request(
                prompt=np.arange(2 + i, dtype=np.int32),
                max_new_tokens=3))
        eng.step()
        tr.counter("kv_pages_live", eng.kv_stats["pages_live"],
                   process="serving")
        eng.drain()
    return tr


def skeleton(events):
    """Normalized shape: sorted distinct (ph, cat, name) with digits
    collapsed — stable across timestamps, ids, and run speed."""
    out = set()
    for e in events:
        name = re.sub(r"\d+", "N", str(e.get("name", "")))
        cat = re.sub(r"\d+", "N", str(e.get("cat", "")))
        out.add((e["ph"], cat, name))
    return sorted(out)


# --------------------------------------------------------------------------
# structural validation
# --------------------------------------------------------------------------

def test_recorded_trace_validates():
    tr = recorded_run()
    events = tr.trace_events()
    counts = validate_trace(events)
    # every phase the exporter promises is present
    assert counts.get("M", 0) >= 4        # process + thread names
    assert counts.get("X", 0) >= 8        # slices: kernels + natives
    assert counts.get("C", 0) >= 2        # queue depth + kv counter
    assert counts.get("s", 0) >= 1        # DAG flow arrows
    assert counts.get("s") == counts.get("f")


def test_slices_carry_profiling_and_provenance():
    tr = recorded_run()
    events = tr.trace_events()
    slices = [e for e in events if e["ph"] == "X"]
    for e in slices:
        a = e["args"]
        assert a["end_ns"] >= a["start_ns"] >= a["queued_ns"]
        assert e["dur"] == pytest.approx(
            (a["end_ns"] - a["start_ns"]) / 1e3)
        assert a["kind"]
    fused = [e for e in slices if "fused_from" in e["args"]]
    assert fused, "fused super-command missing from the trace"
    assert "rmsnorm_ew" in " ".join(fused[0]["args"]["fused_from"])
    # exported ts are relative to the run start and sorted
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts) and ts[0] == 0


def test_trace_export_writes_chrome_json(tmp_path):
    tr = recorded_run()
    path = str(tmp_path / "out.json")
    doc = tr.export(path)
    with open(path) as f:
        loaded = json.load(f)
    assert loaded["displayTimeUnit"] == "ms"
    assert loaded["traceEvents"] == json.loads(
        json.dumps(doc["traceEvents"], default=float))
    validate_trace(loaded["traceEvents"])


def test_validate_trace_rejects_malformed():
    ok = [{"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
           "ts": 0, "args": {"name": "p"}},
          {"ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
           "ts": 0, "args": {"name": "t"}},
          {"ph": "X", "name": "k", "pid": 1, "tid": 1, "ts": 1.0,
           "dur": 2.0, "args": {}}]
    validate_trace(ok)
    with pytest.raises(ValueError, match="unknown ph"):
        validate_trace(ok + [{"ph": "Z", "name": "?", "ts": 0}])
    with pytest.raises(ValueError, match="missing"):
        validate_trace(ok + [{"ph": "X", "name": "k", "pid": 1,
                              "tid": 1, "ts": 3.0}])
    with pytest.raises(ValueError, match="negative ts"):
        validate_trace(ok + [{"ph": "i", "name": "k", "pid": 1,
                              "tid": 1, "ts": -1.0}])
    with pytest.raises(ValueError, match="no finish"):
        validate_trace(ok + [{"ph": "s", "name": "f", "id": 9,
                              "pid": 1, "tid": 1, "ts": 1.0}])
    with pytest.raises(ValueError, match="before it starts"):
        validate_trace(ok + [
            {"ph": "s", "name": "f", "id": 9, "pid": 1, "tid": 1,
             "ts": 5.0},
            {"ph": "f", "name": "f", "id": 9, "pid": 1, "tid": 1,
             "ts": 1.0}])
    with pytest.raises(ValueError, match="unnamed pid"):
        validate_trace(ok + [{"ph": "X", "name": "k", "pid": 7,
                              "tid": 1, "ts": 1.0, "dur": 0.0}])


def test_mesh_trace_shows_migration_flow():
    """The acceptance-criterion view: a killed replica's migration is a
    paired flow arrow between the two replicas' process rows."""
    from repro.serving import ServingMesh

    mesh = ServingMesh(
        n_replicas=2, batch_slots=2, max_seq=32,
        executor_factory=lambda i: StubExecutor(batch_slots=2,
                                                max_seq=32))
    tr = mesh.attach_trace()
    rng = np.random.default_rng(3)
    for _ in range(4):
        mesh.submit(Request(
            prompt=rng.integers(0, 99, 4).astype(np.int32),
            max_new_tokens=4))
    mesh.step()
    mesh.kill_replica(0)
    mesh.drain()
    events = tr.trace_events()
    validate_trace(events)
    flows = [e for e in events
             if e.get("cat") == "migration" and e["ph"] in ("s", "f")]
    assert flows, "migration left no flow arrows"
    starts = {e["id"]: e for e in flows if e["ph"] == "s"}
    for e in flows:
        if e["ph"] == "f":
            # the arrow crosses replicas: source row != destination row
            assert starts[e["id"]]["pid"] != e["pid"]


# --------------------------------------------------------------------------
# golden skeleton
# --------------------------------------------------------------------------

def test_golden_trace_schema():
    tr = recorded_run()
    got = skeleton(tr.trace_events())
    path = os.path.join(GOLDEN_DIR, "trace_schema.json")
    if os.environ.get("REPRO_UPDATE_GOLDEN"):
        with open(path, "w") as f:
            json.dump([list(t) for t in got], f, indent=1)
            f.write("\n")
        pytest.skip(f"golden updated: {path}")
    assert os.path.exists(path), \
        f"golden file missing; run with REPRO_UPDATE_GOLDEN=1 ({path})"
    with open(path) as f:
        want = sorted(tuple(t) for t in json.load(f))
    assert got == want, (
        "exported trace skeleton drifted; if the exporter change is "
        "intentional, regenerate with REPRO_UPDATE_GOLDEN=1")
