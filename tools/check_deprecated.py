"""CI guard: deprecated host entry points stay confined to shims/tests.

ruff's banned-api check (TID251, see ruff.toml) catches *imports* of
deprecated functions; the method-level entry points —
``Device.build_kernel``, ``CommandQueue.enqueue_kernel``,
``CoExecutor.run(build, ...)`` — are attribute calls ruff cannot ban, so
this script walks the AST of ``src/``, ``examples/`` and ``benchmarks/``
and fails if any call site survives outside the shim definitions and an
explicit per-file allowlist.  Tests are exempt: they prove the shims
keep working.  Benchmarks are scanned — the four compiler-layer
benchmarks that measure ``compile_kernel`` itself, and the sanctioned
fiber-baseline uses of ``run_ndrange``, are allowlisted by name so new
benchmark code cannot silently drift back onto deprecated entry points.

  python tools/check_deprecated.py        # exit 0 = clean
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# method/function name -> files allowed to reference it (the shim's own
# definition, its internal delegation, and explicitly sanctioned uses)
ALLOWED = {
    "build_kernel": {"src/repro/runtime/platform.py"},
    "enqueue_kernel": {"src/repro/runtime/queue.py"},
    "compile_kernel": {
        "src/repro/core/api.py",
        # these four measure the compiler layer itself (see ruff.toml)
        "benchmarks/bench_cache.py",
        "benchmarks/bench_compile.py",
        "benchmarks/bench_context.py",
        "benchmarks/bench_horizontal.py",
    },
    # the fiber interpreter stays available as the semantics oracle and
    # the Clover/Twin-Peaks baseline the paper argues against; calling
    # it anywhere else is a deprecated launch path.  WGProgram.run_ndrange
    # (the compiled programs' method of the same name) is internal to the
    # dispatch layer in api.py.
    "run_ndrange": {
        "src/repro/core/interp.py",
        "src/repro/core/api.py",
        "benchmarks/bench_kernel_suite.py",   # fiber baseline column
        "examples/quickstart.py",             # oracle demo
    },
}

SCAN_DIRS = ("src", "examples", "benchmarks")


def deprecated_calls(tree: ast.AST, rel: str):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = None
        if isinstance(fn, ast.Attribute):
            name = fn.attr
        elif isinstance(fn, ast.Name):
            name = fn.id
        if name in ("build_kernel", "enqueue_kernel", "compile_kernel",
                    "run_ndrange"):
            if rel not in ALLOWED[name]:
                yield node.lineno, f"{name}()"
        elif name == "run" and isinstance(fn, ast.Attribute):
            # CoExecutor.run(build, local_size, global_size, buffers,
            # scalars, mode=..., weights=...): flag 3+ positional args or
            # any of its distinctive keywords, so keyword-style calls
            # cannot evade the guard (other .run() calls in the tree take
            # <= 2 positional args and none of these keywords)
            kw = {k.arg for k in node.keywords}
            if (len(node.args) >= 3 or kw & {"buffers", "scalars",
                                             "mode", "weights"}) \
                    and rel != "src/repro/runtime/scheduler.py":
                yield node.lineno, "CoExecutor.run(build, ...)"


def main() -> int:
    problems = []
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            tree = ast.parse(path.read_text(), filename=rel)
            for lineno, what in deprecated_calls(tree, rel):
                problems.append(f"{rel}:{lineno}: deprecated host entry "
                                f"point {what}")
    if problems:
        print("deprecated host entry points used outside shim/test code "
              "(docs/host_api.md §Migration):")
        for p in problems:
            print("  " + p)
        return 1
    print(f"check_deprecated: clean ({', '.join(SCAN_DIRS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
