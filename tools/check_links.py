"""Markdown link checker for the repo docs (CI docs job).

Scans every tracked ``*.md`` file for inline links/images
(``[text](target)``) and verifies that each *relative* target resolves
to an existing file or directory (anchors are stripped; external
``http(s)``/``mailto`` targets are skipped).  Exits non-zero listing
every broken link.

  python tools/check_links.py [root]
"""

from __future__ import annotations

import os
import re
import sys

# inline markdown links/images; skips fenced code blocks below
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_DIRS = {".git", "__pycache__", "results", ".pytest_cache",
              "node_modules"}


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def links_in(path: str):
    """Yield (lineno, target) for every inline link outside code fences."""
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _LINK.finditer(line):
                yield i, m.group(1)


def check(root: str) -> int:
    broken = []
    n_links = 0
    for md in md_files(root):
        for lineno, target in links_in(md):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            n_links += 1
            rel = target.split("#", 1)[0]
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md), rel))
            if not os.path.exists(resolved):
                broken.append((os.path.relpath(md, root), lineno, target))
    for md, lineno, target in broken:
        print(f"BROKEN  {md}:{lineno}  -> {target}")
    print(f"checked {n_links} relative links in markdown files under "
          f"{os.path.abspath(root)}: {len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1 else "."))
