"""Regenerate the stage-by-stage IR dumps shown in docs/compiler.md.

Drives the compiler middle-end through the :class:`PassManager`
(``repro.core.passes``) with its per-pass dump hook — the pass list is
*enumerated from the manager*, so this tool stays correct when passes are
added or reordered.  For every CFG-mutating pass the canonical IR after
the pass is printed; analysis passes print their product (regions +
schedule, uniformity-informed metadata, context slots).  docs/compiler.md
embeds this output; re-run after compiler changes:

  PYTHONPATH=src python tools/dump_pipeline.py
"""

from repro.core import canonical_ir
from repro.core.examples import build_condbar, build_reduce2
from repro.core.passes import PassManager


def run_and_dump(fn, verbose_cfg: bool = True) -> None:
    """Run the default pipeline on ``fn``, printing after every pass."""
    last_ir = [canonical_ir(fn)]
    print("\n### input (KernelBuilder DSL lowering to SSA CFG)\n")
    print(last_ir[0])

    def on_pass(p, st) -> None:
        ref = f" ({p.paper})" if p.paper else ""
        if p.mutates_cfg:
            text = canonical_ir(st.fn)
            if text == last_ir[0]:
                print(f"\n### after {p.name}{ref}: no change\n")
                return
            last_ir[0] = text
            print(f"\n### after {p.name}{ref}\n")
            if verbose_cfg:
                print(text)
        else:
            print(f"\n### after {p.name}{ref}\n")
            if p.name == "form_regions":
                print(f"schedule (RPO, entry first): {st.wg.order}")
                print(f"linear chain: {st.wg.is_chain()}")
                for bar in st.wg.order:
                    r = st.wg.regions[bar]
                    print(f"region @{bar}: entry={r.entry} "
                          f"blocks={sorted(r.blocks) if r.blocks else []}")
            elif p.name == "context_planning":
                for s in st.ctx.slots:
                    print(f"slot {s.name}: {s.dtype} "
                          f"{'uniform (merged)' if s.uniform else 'per-WI'}")
                if not st.ctx.slots:
                    print("(no cross-region values: zero context slots)")
            elif p.name == "annotate_parallel_md":
                for bar in st.wg.order:
                    print(st.md[bar].describe())
            else:
                print("(analysis pass)")

    pm = PassManager(verify=True, on_pass=on_pass)
    print(f"\npipeline passes: {pm.pass_names()}")
    plan = pm.run(fn)
    print("\n### per-pass timings (ms)\n")
    for name, dt in plan.pass_times.items():
        print(f"  {name:22s} {dt * 1e3:7.3f}")


def main() -> None:
    print("=" * 72)
    print("tree-reduction kernel (b-loop, §4.5)")
    print("=" * 72)
    run_and_dump(build_reduce2())

    print("\n" + "=" * 72)
    print("conditional-barrier kernel (tail duplication, Alg. 2)")
    print("=" * 72)
    run_and_dump(build_condbar())


if __name__ == "__main__":
    main()
