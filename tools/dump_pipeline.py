"""Regenerate the stage-by-stage IR dumps shown in docs/compiler.md.

Runs the pocl pipeline one stage at a time on a small barrier kernel and
prints the canonical IR after each stage, plus the formed regions and
schedule.  docs/compiler.md embeds this output; re-run after compiler
changes:

  PYTHONPATH=src python tools/dump_pipeline.py
"""

from repro.core import KernelBuilder, canonical_ir
from repro.core.regions import (form_regions, inject_loop_barriers,
                                normalize, out_of_ssa, tail_duplicate)


def build_reduce2():
    """A 2-wide tree reduction: load to local, barrier, fold, barrier —
    small enough to read, big enough to exercise every stage."""
    b = KernelBuilder("reduce2")
    inp = b.arg_buffer("inp", "float32")
    out = b.arg_buffer("out", "float32")
    scratch = b.local_array("scratch", "float32", 2)
    lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
    scratch[lid] = inp[gid]
    b.barrier()
    s = b.var(b.const(1), name="s")
    with b.while_loop() as loop:
        loop.cond(s.get() > 0)
        with b.if_(lid < s.get()):
            scratch[lid] = scratch[lid] + scratch[lid + s.get()]
        b.barrier()
        s.set(s.get() / 2)
    with b.if_(lid == 0):
        out[grp] = scratch[0]
    return b.finish()


def build_condbar():
    """A loop-free conditional barrier (work-group-uniform condition):
    the Algorithm 2 tail-duplication case."""
    b = KernelBuilder("condbar")
    x = b.arg_buffer("x", "float32")
    n = b.arg_scalar("n", "int32")
    gid = b.global_id(0)
    zero = b.const(0)
    with b.if_(n > zero):
        b.barrier()
    x[gid] = x[gid] + 1.0
    return b.finish()


def stage(title: str, fn) -> None:
    print(f"\n### after {title}\n")
    print(canonical_ir(fn))


def main() -> None:
    fn = build_reduce2()
    stage("KernelBuilder (DSL lowering to SSA CFG)", fn)
    normalize(fn)
    stage("normalize (§4.3 Alg. 1: single exit, implicit entry/exit "
          "barriers, barrier isolation)", fn)
    inject_loop_barriers(fn)
    stage("inject_loop_barriers (§4.5 b-loop implicit barriers)", fn)
    out_of_ssa(fn)
    stage("out_of_ssa (§4.7 prep: phis -> virtual registers)", fn)
    tail_duplicate(fn)
    stage("tail_duplicate (§4.3 Alg. 2)", fn)
    wg = form_regions(fn)
    print("\n### form_regions (§4.3 Def. 1)\n")
    print(f"schedule (RPO, entry first): {wg.order}")
    print(f"linear chain: {wg.is_chain()}")
    for bar in wg.order:
        r = wg.regions[bar]
        print(f"region @{bar}: entry={r.entry} "
              f"blocks={sorted(r.blocks) if r.blocks else []}")

    print("\n" + "=" * 72)
    print("conditional-barrier kernel (tail duplication, Alg. 2)")
    print("=" * 72)
    fn2 = build_condbar()
    normalize(fn2)
    inject_loop_barriers(fn2)
    out_of_ssa(fn2)
    stage("normalize + out_of_ssa (condbar)", fn2)
    ndup = tail_duplicate(fn2)
    stage(f"tail_duplicate (condbar, {ndup} duplication(s))", fn2)
    wg2 = form_regions(fn2)
    print(f"\ncondbar schedule: {wg2.order}  chain={wg2.is_chain()}")


if __name__ == "__main__":
    main()
