"""Regenerate the stage-by-stage IR dumps shown in docs/compiler.md.

Drives the compiler middle-end through the :class:`PassManager`
(``repro.core.passes``) with its per-pass dump hook — the pass list is
*enumerated from the manager*, so this tool stays correct when passes are
added or reordered.  For every CFG-mutating pass the canonical IR after
the pass is printed; analysis passes print their product (regions +
schedule, uniformity-informed metadata, context slots).  docs/compiler.md
embeds this output; re-run after compiler changes:

  PYTHONPATH=src python tools/dump_pipeline.py
"""

from repro.core import canonical_ir
from repro.core.examples import (build_condbar, build_quantize,
                                 build_reduce2, build_residual_add,
                                 build_rmsnorm_ew)
from repro.core.fusion import ChainEdge, stitch_functions
from repro.core.passes import PassManager, kernel_fusibility


def run_and_dump(fn, verbose_cfg: bool = True) -> None:
    """Run the default pipeline on ``fn``, printing after every pass."""
    last_ir = [canonical_ir(fn)]
    print("\n### input (KernelBuilder DSL lowering to SSA CFG)\n")
    print(last_ir[0])

    def on_pass(p, st) -> None:
        ref = f" ({p.paper})" if p.paper else ""
        if p.mutates_cfg:
            text = canonical_ir(st.fn)
            if text == last_ir[0]:
                print(f"\n### after {p.name}{ref}: no change\n")
                return
            last_ir[0] = text
            print(f"\n### after {p.name}{ref}\n")
            if verbose_cfg:
                print(text)
        else:
            print(f"\n### after {p.name}{ref}\n")
            if p.name == "form_regions":
                print(f"schedule (RPO, entry first): {st.wg.order}")
                print(f"linear chain: {st.wg.is_chain()}")
                for bar in st.wg.order:
                    r = st.wg.regions[bar]
                    print(f"region @{bar}: entry={r.entry} "
                          f"blocks={sorted(r.blocks) if r.blocks else []}")
            elif p.name == "context_planning":
                for s in st.ctx.slots:
                    print(f"slot {s.name}: {s.dtype} "
                          f"{'uniform (merged)' if s.uniform else 'per-WI'}")
                if not st.ctx.slots:
                    print("(no cross-region values: zero context slots)")
            elif p.name == "annotate_parallel_md":
                for bar in st.wg.order:
                    print(st.md[bar].describe())
            else:
                print("(analysis pass)")

    pm = PassManager(verify=True, on_pass=on_pass)
    print(f"\npipeline passes: {pm.pass_names()}")
    plan = pm.run(fn)
    print("\n### per-pass timings (ms)\n")
    for name, dt in plan.pass_times.items():
        print(f"  {name:22s} {dt * 1e3:7.3f}")


def dump_fusion() -> None:
    """Stitch the rmsnorm→residual→quantize chain and print the fused
    IR embedded in docs/compiler.md §Fusion."""
    builders = [build_rmsnorm_ew, build_residual_add, build_quantize]
    fns = [b() for b in builders]
    for fn in fns:
        facts = kernel_fusibility(fn)
        fps = ", ".join(f"{fp.name}(loads={fp.loads},stores={fp.stores})"
                        for fp in facts.footprints)
        print(f"segment {fn.name}: elementwise={facts.elementwise} [{fps}]")
    edges = [ChainEdge(0, 1, "y", "y", True), ChainEdge(1, 2, "z", "z", True)]
    aliases = [[(0, "y"), (1, "y")], [(1, "z"), (2, "z")]]
    fused, bmap, smap = stitch_functions(fns, edges, aliases)
    print("\n### stitched chain (both intermediates elided)\n")
    print(canonical_ir(fused))
    print(f"buffer map: {sorted(bmap.items())}")
    print(f"scalar map: {sorted(smap.items())}")


def main() -> None:
    print("=" * 72)
    print("tree-reduction kernel (b-loop, §4.5)")
    print("=" * 72)
    run_and_dump(build_reduce2())

    print("\n" + "=" * 72)
    print("conditional-barrier kernel (tail duplication, Alg. 2)")
    print("=" * 72)
    run_and_dump(build_condbar())

    print("\n" + "=" * 72)
    print("DAG-fused elementwise chain (docs/compiler.md §Fusion)")
    print("=" * 72)
    dump_fusion()


if __name__ == "__main__":
    main()
