"""Paper Fig. 12/13/14 analogue: the kernel-suite benchmark.

The paper runs the AMD APP SDK suite on Intel/ARM/PPC and compares pocl's
statically parallelized work-groups against proprietary OpenCL stacks and
fiber-based implementations (FreeOCL/Clover).  The hardware-adapted
analogue here: the same OpenCL-style kernels authored in the repro.core
DSL, executed via

  fiber    — run_ndrange, real per-work-item fibers (the Clover/Twin-Peaks
             baseline the paper argues against)
  loop     — serial WI-loops, pocl's 'basic' driver analogue
  vector   — vectorized WI-loops over XLA (pocl's SIMD mapping; the MXU/
             VPU path on TPU)

Reported: wall-time per launch (median of N) + speedup over fiber.  The
paper's claim to reproduce: static parallel-region compilation beats fiber
context switching, and the vector mapping beats the serial loop.
"""

from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np

from repro.core import KernelBuilder, compile_kernel, run_ndrange


# ---------------------------------------------------------------------------
# the suite (AMD APP SDK-style kernels)
# ---------------------------------------------------------------------------

def build_vecadd():
    b = KernelBuilder("vecadd")
    A, B, C = (b.arg_buffer(n, "float32") for n in "ABC")
    g = b.global_id(0)
    C[g] = A[g] + B[g]
    return b.finish()


def build_saxpy():
    b = KernelBuilder("saxpy")
    X = b.arg_buffer("X", "float32")
    Y = b.arg_buffer("Y", "float32")
    a = b.arg_scalar("a", "float32")
    g = b.global_id(0)
    Y[g] = a * X[g] + Y[g]
    return b.finish()


def build_reduction():
    b = KernelBuilder("reduction")
    inp = b.arg_buffer("inp", "float32")
    out = b.arg_buffer("out", "float32")
    scratch = b.local_array("scratch", "float32", 64)
    lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
    scratch[lid] = inp[gid]
    b.barrier()
    s = b.var(b.const(32), name="s")
    with b.while_loop() as loop:
        loop.cond(s.get() > 0)
        with b.if_(lid < s.get()):
            scratch[lid] = scratch[lid] + scratch[lid + s.get()]
        b.barrier()
        s.set(s.get() / 2)
    with b.if_(lid == 0):
        out[grp] = scratch[0]
    return b.finish()


def build_dct():
    """Inner-loop kernel (paper Fig. 9)."""
    b = KernelBuilder("dct")
    inp = b.arg_buffer("inp", "float32")
    coef = b.arg_buffer("coef", "float32")
    out = b.arg_buffer("out", "float32")
    width = b.arg_scalar("width", "int32")
    lid = b.local_id(0)
    acc = b.var(0.0, name="acc")
    k = b.var(b.const(0), name="k")
    with b.while_loop() as loop:
        loop.cond(k.get() < width)
        acc.set(acc.get() + coef[k.get()] * inp[lid * width + k.get()])
        k.set(k.get() + 1)
    out[lid] = acc.get()
    return b.finish()


def build_blackscholes_lite():
    """Arithmetic-heavy, branch-free (BlackScholes stand-in)."""
    b = KernelBuilder("bs")
    S = b.arg_buffer("S", "float32")
    K = b.arg_buffer("K", "float32")
    out = b.arg_buffer("out", "float32")
    g = b.global_id(0)
    m = b.var(S[g] / K[g], name="m")
    # a few fused ops per element
    acc = b.var(m.get(), name="acc")
    i = b.var(b.const(0), name="i")
    with b.while_loop() as loop:
        loop.cond(i.get() < 8)
        acc.set(acc.get() * 0.9 + m.get() * 0.1)
        i.set(i.get() + 1)
    out[g] = acc.get()
    return b.finish()


def build_binarysearch():
    """Divergent control flow (the paper's worst case on pocl)."""
    b = KernelBuilder("bsearch")
    hay = b.arg_buffer("hay", "float32")
    needle = b.arg_buffer("needle", "float32")
    out = b.arg_buffer("out", "float32")
    n = b.arg_scalar("n", "int32")
    g = b.global_id(0)
    lo = b.var(b.const(0), name="lo")
    hi = b.var(n, name="hi")
    it = b.var(b.const(0), name="it")
    with b.while_loop() as loop:
        loop.cond(it.get() < 10)
        mid = b.var((lo.get() + hi.get()) / 2, name="mid")
        with b.if_(hay[mid.get()] < needle[g]):
            lo.set(mid.get())
        with b.if_(hay[mid.get()] >= needle[g]):
            hi.set(mid.get())
        it.set(it.get() + 1)
    out[g] = lo.get()
    return b.finish()


def build_matvec():
    b = KernelBuilder("matvec")
    M = b.arg_buffer("M", "float32")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    n = b.arg_scalar("n", "int32")
    g = b.global_id(0)
    acc = b.var(0.0, name="acc")
    j = b.var(b.const(0), name="j")
    with b.while_loop() as loop:
        loop.cond(j.get() < n)
        acc.set(acc.get() + M[g * n + j.get()] * x[j.get()])
        j.set(j.get() + 1)
    y[g] = acc.get()
    return b.finish()


def suite(n: int = 4096, lsz: int = 64):
    rng = np.random.default_rng(0)
    f32 = lambda *s: rng.standard_normal(s).astype(np.float32)
    hay = np.sort(f32(1024))
    return {
        "VecAdd": (build_vecadd, {"A": f32(n), "B": f32(n),
                                  "C": np.zeros(n, np.float32)},
                   (n,), (lsz,), None),
        "SAXPY": (build_saxpy, {"X": f32(n), "Y": f32(n)},
                  (n,), (lsz,), {"a": 1.5}),
        "Reduction": (build_reduction,
                      {"inp": f32(n), "out": np.zeros(n // lsz, np.float32)},
                      (n,), (lsz,), None),
        "DCT": (build_dct, {"inp": f32(lsz * 16), "coef": f32(16),
                            "out": np.zeros(lsz, np.float32)},
                (lsz,), (lsz,), {"width": 16}),
        "BlackScholes": (build_blackscholes_lite,
                         {"S": f32(n) + 10.0, "K": f32(n) + 10.0,
                          "out": np.zeros(n, np.float32)},
                         (n,), (lsz,), None),
        "BinarySearch": (build_binarysearch,
                         {"hay": hay, "needle": f32(n),
                          "out": np.zeros(n, np.float32)},
                         (n,), (lsz,), {"n": 1024}),
        "MatVec": (build_matvec, {"M": f32(256 * 256), "x": f32(256),
                                  "y": np.zeros(256, np.float32)},
                   (256,), (64,), {"n": 256}),
    }


def _time(fn: Callable[[], None], iters: int = 5) -> float:
    fn()                                   # warmup / compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(iters: int = 5, fiber_iters: int = 1) -> Dict[str, Dict[str, float]]:
    out = {}
    for name, (build, bufs, gsz, lsz, scalars) in suite().items():
        row = {}
        # fiber baseline (interpreted; 1 iter — it is orders slower)
        t0 = time.perf_counter()
        run_ndrange(build(), gsz, lsz,
                    {k: v.copy() for k, v in bufs.items()}, scalars)
        row["fiber"] = time.perf_counter() - t0
        for tgt in ("loop", "vector"):
            k = compile_kernel(build, lsz, target=tgt)
            row[tgt] = _time(
                lambda: k({key: v.copy() for key, v in bufs.items()},
                          gsz, scalars), iters)
        row["speedup_vector_vs_fiber"] = row["fiber"] / row["vector"]
        row["speedup_vector_vs_loop"] = row["loop"] / row["vector"]
        out[name] = row
    return out


def main():
    res = run()
    print(f"{'kernel':14s} {'fiber':>10s} {'loop':>10s} {'vector':>10s} "
          f"{'vec/fiber':>10s} {'vec/loop':>9s}")
    for name, r in res.items():
        print(f"{name:14s} {r['fiber']*1e3:9.2f}ms {r['loop']*1e3:9.2f}ms "
              f"{r['vector']*1e3:9.2f}ms "
              f"{r['speedup_vector_vs_fiber']:9.1f}x "
              f"{r['speedup_vector_vs_loop']:8.1f}x")
    return res


if __name__ == "__main__":
    main()
