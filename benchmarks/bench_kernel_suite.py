"""Paper Fig. 12/13/14 analogue: the kernel-suite benchmark.

The paper runs the AMD APP SDK suite on Intel/ARM/PPC and compares pocl's
statically parallelized work-groups against proprietary OpenCL stacks and
fiber-based implementations (FreeOCL/Clover).  The hardware-adapted
analogue: the :mod:`repro.suite` linear-algebra/irregular kernels (tiled
GEMM, CSR SpMV, stencils, work-group scan, privatized histogram — see
docs/scoreboard.md), executed via

  fiber    — run_ndrange, real per-work-item fibers (the Clover/Twin-Peaks
             baseline the paper argues against)
  loop     — serial WI-loops, pocl's 'basic' driver analogue
  vector   — vectorized WI-loops over XLA (pocl's SIMD mapping; the MXU/
             VPU path on TPU)

through the Context/Program/Kernel host API (loop/vector).  Reported:
wall-time per launch (median of N) + speedup over fiber.  The paper's
claim to reproduce: static parallel-region compilation beats fiber
context switching, and the vector mapping beats the serial loop.

Tuning-space sweeps and the full roofline matrix (pallas, co-execution,
autotuned columns) live in :mod:`benchmarks.bench_scoreboard`; this
benchmark keeps the historical fiber-vs-compiled comparison.

  PYTHONPATH=src python -m benchmarks.bench_kernel_suite
"""

from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np

# the fiber interpreter IS the baseline under measurement here — the one
# sanctioned use of the deprecated entry point outside tests
from repro.core.interp import run_ndrange  # noqa: TID251
from repro.runtime import Context
from repro.suite import suite_kernels


def _time(fn: Callable[[], None], iters: int = 5) -> float:
    fn()                                   # warmup / compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(iters: int = 5, shape_set: str = "ci"
        ) -> Dict[str, Dict[str, float]]:
    ctx = Context()
    out = {}
    for sk in suite_kernels():
        shape = sk.shapes.get(shape_set, sk.shapes["full"])
        params = sk.space(shape)[0]
        inputs = sk.make_inputs(shape, params)
        expected = sk.oracle(inputs, shape, params)
        gsz, lsz = sk.launch_dims(shape, params)
        row: Dict[str, float] = {}
        # fiber baseline (interpreted; 1 iter — it is orders slower)
        t0 = time.perf_counter()
        fiber_out = run_ndrange(sk.build(shape, params)(), gsz, lsz,
                                {k: v.copy() for k, v in inputs.items()})
        row["fiber"] = time.perf_counter() - t0
        outs = {}
        for tgt in ("loop", "vector"):
            kern = ctx.create_program(sk.build(shape, params)) \
                .create_kernel()
            kern.set_args(**inputs)
            row[tgt] = _time(
                lambda: ctx.launch(kern, gsz, lsz, target=tgt), iters)
            outs[tgt] = ctx.launch(kern, gsz, lsz, target=tgt)
        # all three execution strategies must agree bitwise with the
        # oracle — the portability claim, not just the speed claim
        row["bitwise_ok"] = float(all(
            np.asarray(o[name]).tobytes() == exp.tobytes()
            for o in (fiber_out, outs["loop"], outs["vector"])
            for name, exp in expected.items()))
        row["speedup_vector_vs_fiber"] = row["fiber"] / row["vector"]
        row["speedup_vector_vs_loop"] = row["loop"] / row["vector"]
        out[sk.name] = row
    return out


def main():
    res = run()
    print(f"{'kernel':12s} {'fiber':>10s} {'loop':>10s} {'vector':>10s} "
          f"{'vec/fiber':>10s} {'vec/loop':>9s} {'bitwise':>8s}")
    for name, r in res.items():
        print(f"{name:12s} {r['fiber']*1e3:9.2f}ms {r['loop']*1e3:9.2f}ms "
              f"{r['vector']*1e3:9.2f}ms "
              f"{r['speedup_vector_vs_fiber']:9.1f}x "
              f"{r['speedup_vector_vs_loop']:8.1f}x "
              f"{'ok' if r['bitwise_ok'] else 'FAIL':>8s}")
    return res


if __name__ == "__main__":
    main()
