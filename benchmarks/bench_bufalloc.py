"""Paper §3 Bufalloc: allocation throughput + fragmentation vs a naive
free-list, under the OpenCL buffer workload the allocator is tuned for
(large, long-lived, group-allocated buffers)."""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.runtime.bufalloc import Bufalloc, OutOfMemory


def workload(a: Bufalloc, rng, rounds=200, group=4):
    """Kernel-launch-like pattern: allocate a group of buffers, run,
    free the group; occasionally keep long-lived buffers."""
    live = []
    peak_frag = 0.0
    for i in range(rounds):
        sizes = [int(rng.integers(1 << 10, 1 << 16)) for _ in range(group)]
        try:
            chunks = a.alloc_group(sizes)
        except OutOfMemory:
            for c in live[:len(live) // 2]:
                a.free(c)
            live = live[len(live) // 2:]
            continue
        if i % 7 == 0:          # long-lived buffer
            live.append(chunks.pop())
        a.free_group(chunks)
        peak_frag = max(peak_frag, a.fragmentation())
    for c in live:
        a.free(c)
    return peak_frag


def run() -> Dict[str, float]:
    rng = np.random.default_rng(0)
    out = {}
    for greedy in (False, True):
        a = Bufalloc(64 << 20, alignment=64, greedy=greedy)
        t0 = time.perf_counter()
        frag = workload(a, np.random.default_rng(0))
        dt = time.perf_counter() - t0
        out[f"greedy={greedy}"] = {
            "seconds": dt, "peak_fragmentation": frag,
            "allocs_per_sec": 200 * 4 / dt,
        }
    return out


def main():
    res = run()
    for k, r in res.items():
        print(f"Bufalloc {k}: {r['allocs_per_sec']:.0f} allocs/s, "
              f"peak fragmentation {r['peak_fragmentation']:.3f}")
    return res


if __name__ == "__main__":
    main()
