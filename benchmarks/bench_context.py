"""Paper §4.7: context-array footprint with and without uniform-variable
merging, across the suite kernels and work-group sizes."""

from __future__ import annotations

from typing import Dict

from repro.core import compile_kernel
from .bench_kernel_suite import suite


def run(lsz: int = 64) -> Dict[str, Dict[str, int]]:
    out = {}
    for name, (build, _bufs, _gsz, _lsz, _scalars) in suite(lsz=lsz).items():
        k_merged = compile_kernel(build, (lsz,), merge_uniform=True)
        k_raw = compile_kernel(build, (lsz,), merge_uniform=False)
        m, r = k_merged.context_stats, k_raw.context_stats
        out[name] = {
            "slots": m["slots"],
            "uniform_merged": m["uniform_merged"],
            "bytes_merged": m["context_bytes"],
            "bytes_unmerged": r["context_bytes"],
            "saving": 1.0 - (m["context_bytes"] /
                             max(r["context_bytes"], 1)),
        }
    return out


def main():
    res = run()
    print(f"{'kernel':14s} {'slots':>6s} {'merged':>7s} "
          f"{'bytes(merged)':>14s} {'bytes(raw)':>11s} {'saving':>7s}")
    for name, r in res.items():
        print(f"{name:14s} {r['slots']:6d} {r['uniform_merged']:7d} "
              f"{r['bytes_merged']:14d} {r['bytes_unmerged']:11d} "
              f"{r['saving']*100:6.1f}%")
    return res


if __name__ == "__main__":
    main()
