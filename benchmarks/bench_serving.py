"""Continuous-batching serving benchmark: scheduler vs fixed-slot baseline.

A seeded Poisson traffic generator emits requests with mixed prompt and
output lengths (short interactive and long generative interleaved, the
mix that starves fixed groups) in *scheduler-step units*, so the trace —
and the latency/efficiency gates — are machine-independent.  The same
trace is served three ways by the same engine code:

* ``continuous`` — the request-level scheduler (per-step refill, paged
  KV, preemption);
* ``fixed``      — the refill-barrier baseline (slots refill only when
  all are empty: the old synchronized-group behaviour);
* ``serial``     — one slot, one request at a time: the oracle the
  per-request token streams must match bitwise.

Three gated measurements (docs/serving.md §Benchmarks):

* ``scheduler_trace`` — the Poisson trace on the deterministic
  :class:`~repro.serving.executor.StubExecutor` with a simulated device
  delay per batch call.  Gates: continuous beats fixed on tokens per
  decode call (batch efficiency), on wall tokens/s, and on p99 latency
  (in steps); every stream bitwise-equals the serial oracle.
* ``oom_preemption`` — the trace replayed under a KV budget tight
  enough to force preemption.  Gate: preemptions happened, **zero
  requests dropped**, streams still oracle-exact, zero leaked pages.
* ``model_trace`` — a short mixed trace on the real jitted model from
  the ``configs/`` zoo (smoke ``smollm-135m``).  Gates: continuous beats
  fixed on tokens/s and p99, and both produce streams bitwise-identical
  to the serial run.

  PYTHONPATH=src python -m benchmarks.bench_serving [--ci]
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving import Request, ServingEngine, StubExecutor

SLOTS = 4
MAX_SEQ = 128
PAGE_TOKENS = 8
ARRIVAL_RATE = 0.6        # Poisson mean arrivals per scheduler step
DELAY_S = 0.0015          # simulated device time per prefill/decode call


# ---------------------------------------------------------------------------
# seeded Poisson traffic
# ---------------------------------------------------------------------------

def gen_trace(n_requests: int, seed: int = 0,
              max_new_hi: int = 48) -> List[Tuple[int, np.ndarray, int]]:
    """``(arrival_step, prompt, max_new)`` triples: Poisson arrivals,
    bimodal output lengths (70% short interactive, 30% long generative),
    mixed prompt lengths."""
    rng = np.random.default_rng(seed)
    trace = []
    step = 0
    while len(trace) < n_requests:
        for _ in range(rng.poisson(ARRIVAL_RATE)):
            if len(trace) >= n_requests:
                break
            plen = int(rng.integers(4, 25))
            short = rng.random() < 0.7
            max_new = int(rng.integers(2, 9)) if short \
                else int(rng.integers(max_new_hi // 2, max_new_hi + 1))
            prompt = rng.integers(0, 500, plen).astype(np.int32)
            trace.append((step, prompt, max_new))
        step += 1
    return trace


# ---------------------------------------------------------------------------
# trace runner (engine-agnostic)
# ---------------------------------------------------------------------------

def run_trace(trace, make_engine, warmup: int = 0) -> Dict[str, object]:
    eng = make_engine()
    if warmup:
        # trace/compile the executor's shapes before the timed window
        warm = [Request(prompt=np.arange(4, dtype=np.int32) + 1,
                        max_new_tokens=2) for _ in range(warmup)]
        for w in warm:
            eng.submit(w)
        eng.drain()
    decode0 = eng.compile_stats["decode_steps"]
    reqs: List[Request] = []
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or eng.scheduler_stats["waiting"] or \
            eng.scheduler_stats["running"]:
        while i < len(trace) and trace[i][0] <= eng.current_step:
            _, prompt, max_new = trace[i]
            r = Request(prompt=prompt.copy(), max_new_tokens=max_new)
            eng.submit(r)
            reqs.append(r)
            i += 1
        eng.step()
    wall = time.perf_counter() - t0

    assert all(r.done for r in reqs), "benchmark dropped a request"
    lat = np.array([r.finish_step - r.submit_step for r in reqs], float)
    tokens = int(sum(len(r.out_tokens) for r in reqs))
    decode_calls = max(1, eng.compile_stats["decode_steps"] - decode0)
    sched = eng.scheduler_stats
    return {
        "requests": len(reqs),
        "tokens": tokens,
        "steps": sched["steps"],
        "decode_calls": decode_calls,
        "tokens_per_decode_call": tokens / decode_calls,
        "wall_s": wall,
        "tokens_per_s": tokens / max(wall, 1e-9),
        "p50_latency_steps": float(np.percentile(lat, 50)),
        "p99_latency_steps": float(np.percentile(lat, 99)),
        "preemptions": sched["preemptions"],
        "pages_leaked": eng.kv_stats["pages_live"],
        "streams": [tuple(r.out_tokens) for r in reqs],
    }


def _strip(res: Dict[str, object]) -> Dict[str, object]:
    return {k: v for k, v in res.items() if k != "streams"}


# ---------------------------------------------------------------------------
# Gate 1 + 2: the Poisson trace on the deterministic stub executor
# ---------------------------------------------------------------------------

def _stub_engine(scheduler: str, slots: int = SLOTS,
                 budget_pages: Optional[int] = None):
    def make():
        ex = StubExecutor(batch_slots=slots, max_seq=MAX_SEQ,
                          bytes_per_token=64, delay_s=DELAY_S)
        budget = None if budget_pages is None \
            else budget_pages * PAGE_TOKENS * 64
        return ServingEngine(None, None, None, batch_slots=slots,
                             max_seq=MAX_SEQ, executor=ex,
                             page_tokens=PAGE_TOKENS, scheduler=scheduler,
                             kv_budget_bytes=budget)
    return make


def bench_scheduler_trace(n_requests: int) -> Dict[str, object]:
    trace = gen_trace(n_requests, seed=0)
    cont = run_trace(trace, _stub_engine("continuous"))
    fixed = run_trace(trace, _stub_engine("fixed"))
    serial = run_trace(trace, _stub_engine("continuous", slots=1))
    identical = cont["streams"] == fixed["streams"] == serial["streams"]
    return {
        "trace_requests": n_requests,
        "continuous": _strip(cont),
        "fixed": _strip(fixed),
        "serial": _strip(serial),
        "batch_efficiency_gain":
            cont["tokens_per_decode_call"] / fixed["tokens_per_decode_call"],
        "throughput_gain": cont["tokens_per_s"] / fixed["tokens_per_s"],
        "p99_gain": fixed["p99_latency_steps"]
            / max(cont["p99_latency_steps"], 1e-9),
        "bitwise_identical_to_serial": identical,
    }


def bench_oom_preemption(n_requests: int) -> Dict[str, object]:
    # long-skewed trace under a KV budget (12 pages = 96 tokens) that
    # any single request fits in but two long residents cannot share:
    # the scheduler must preempt-and-requeue its way through
    rng = np.random.default_rng(1)
    trace = []
    for k in range(n_requests):
        plen = int(rng.integers(4, 13))
        max_new = int(rng.integers(40, 65))          # 12+64+1 <= 96
        trace.append((k, rng.integers(0, 500, plen).astype(np.int32),
                      max_new))
    res = run_trace(trace, _stub_engine("continuous", slots=2,
                                        budget_pages=12))
    serial = run_trace(trace, _stub_engine("continuous", slots=1))
    return {
        "requests": res["requests"],
        "preemptions": res["preemptions"],
        "completed": res["requests"],     # run_trace asserts all done
        "dropped": 0,
        "pages_leaked": res["pages_leaked"],
        "bitwise_identical_to_serial": res["streams"] == serial["streams"],
    }


# ---------------------------------------------------------------------------
# Gate 3: the real jitted model from the configs/ zoo
# ---------------------------------------------------------------------------

def bench_model_trace(n_requests: int) -> Dict[str, object]:
    import jax

    from repro import configs
    from repro.distributed.sharding import BASELINE_RULES
    from repro.models import init_params

    cfg = configs.get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    # mixed lengths, step-unit Poisson arrivals as above but shorter
    trace = []
    step = 0
    while len(trace) < n_requests:
        for _ in range(rng.poisson(0.8)):
            if len(trace) >= n_requests:
                break
            plen = int(rng.integers(4, 9))
            max_new = int(rng.integers(2, 5)) if rng.random() < 0.6 \
                else int(rng.integers(8, 15))
            trace.append((step, rng.integers(0, cfg.vocab, plen)
                          .astype(np.int32), max_new))
        step += 1

    def make_engine(scheduler, slots):
        def make():
            return ServingEngine(cfg, params, BASELINE_RULES,
                                 batch_slots=slots, max_seq=32,
                                 scheduler=scheduler)
        return make

    cont = run_trace(trace, make_engine("continuous", 2), warmup=1)
    fixed = run_trace(trace, make_engine("fixed", 2), warmup=1)
    serial = run_trace(trace, make_engine("continuous", 1), warmup=1)
    identical = cont["streams"] == fixed["streams"] == serial["streams"]
    return {
        "arch": "smollm-135m (smoke)",
        "continuous": _strip(cont),
        "fixed": _strip(fixed),
        "throughput_gain": cont["tokens_per_s"] / fixed["tokens_per_s"],
        "p99_gain": fixed["p99_latency_steps"]
            / max(cont["p99_latency_steps"], 1e-9),
        "bitwise_identical_to_serial": identical,
    }


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def run(ci: bool = False) -> Dict[str, object]:
    n = 24 if ci else 60
    return {"scheduler_trace": bench_scheduler_trace(n),
            "oom_preemption": bench_oom_preemption(8 if ci else 16),
            "model_trace": bench_model_trace(6 if ci else 12)}


def main(trajectory: bool = True, ci: bool = False):
    res = run(ci=ci)

    tr = res["scheduler_trace"]
    c, f = tr["continuous"], tr["fixed"]
    print(f"trace       : {tr['trace_requests']} reqs  "
          f"continuous {c['tokens_per_s']:7.0f} tok/s "
          f"p99 {c['p99_latency_steps']:5.1f} steps  |  "
          f"fixed {f['tokens_per_s']:7.0f} tok/s "
          f"p99 {f['p99_latency_steps']:5.1f} steps")
    print(f"  gains     : batch-eff {tr['batch_efficiency_gain']:.2f}x  "
          f"throughput {tr['throughput_gain']:.2f}x  "
          f"p99 {tr['p99_gain']:.2f}x  "
          f"bitwise={tr['bitwise_identical_to_serial']}")
    oo = res["oom_preemption"]
    print(f"oom         : {oo['requests']} reqs under tight KV budget  "
          f"{oo['preemptions']} preemptions  dropped={oo['dropped']}  "
          f"leaked={oo['pages_leaked']}  "
          f"bitwise={oo['bitwise_identical_to_serial']}")
    mt = res["model_trace"]
    mc, mf = mt["continuous"], mt["fixed"]
    print(f"model       : {mt['arch']}  "
          f"continuous {mc['tokens_per_s']:6.1f} tok/s "
          f"p99 {mc['p99_latency_steps']:5.1f}  |  "
          f"fixed {mf['tokens_per_s']:6.1f} tok/s "
          f"p99 {mf['p99_latency_steps']:5.1f}  "
          f"({mt['throughput_gain']:.2f}x, "
          f"bitwise={mt['bitwise_identical_to_serial']})")

    ok = (tr["batch_efficiency_gain"] > 1.0
          and tr["throughput_gain"] > 1.0
          and tr["p99_gain"] >= 1.0
          and tr["bitwise_identical_to_serial"]
          and oo["preemptions"] >= 1 and oo["dropped"] == 0
          and oo["pages_leaked"] == 0
          and oo["bitwise_identical_to_serial"]
          and mt["throughput_gain"] > 1.0
          and mt["p99_gain"] >= 1.0
          and mt["bitwise_identical_to_serial"])
    status = "OK" if ok else "BELOW TARGET"
    print(f"\nserving gates (continuous > fixed on tok/s + p99, bitwise "
          f"vs serial, zero drops under OOM): {status}")
    if trajectory:
        _append_trajectory(res)
    res["_gate_ok"] = ok
    return res


def _append_trajectory(res) -> None:
    """Append this run to BENCH_SERVING.json (one record per run, so the
    continuous-vs-fixed gains are tracked across PRs)."""
    path = os.path.join(os.path.dirname(__file__), "BENCH_SERVING.json")
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except Exception:
            hist = []
    hist.append({"timestamp": time.time(), "results": res})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, default=float)
    print(f"trajectory -> {path} ({len(hist)} runs)")


if __name__ == "__main__":
    import sys
    ci = "--ci" in sys.argv
    sys.exit(0 if main(ci=ci).get("_gate_ok") else 1)
