"""Cold-compile benchmark: stage-level plan sharing across an autotune
sweep (docs/caching.md §Stage-level plan caching).

The autotuner's cold path compiles one kernel for every candidate target
(``loop``, ``vector``, ``pallas``).  Before the pass-manager refactor each
target re-ran the whole target-independent prefix (normalize → b-loop
barriers → out-of-SSA → horizontal → tail duplication → region formation →
uniformity → context planning); now the prefix is computed once as a
:class:`~repro.core.passes.WorkGroupPlan` and shared, so each additional
target only pays its thin parallel-mapping layer.

Two arms, measured on identical fresh-built kernels:

  unshared — 3 targets x (build plan + lower): the pre-refactor cost,
             reproduced by constructing each WGProgram from a raw Function
  shared   — 1 x build plan + 3 x lower from the prebuilt plan: the cost
             the autotuner pays today

The acceptance gate is ``unshared/shared >= 1.5x`` on the 3-target sweep.
A second section reports the end-to-end ``compile_kernel(target="auto")``
cold dispatch and the plan/stage counters proving region formation ran
once.

  PYTHONPATH=src python -m benchmarks.bench_compile
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict


from repro.core import (CompilationCache, KernelBuilder, TuningTable,
                        compile_kernel, plan_count, set_default_table)
from repro.core.examples import build_dct
from repro.core.passes import build_plan
from repro.core.targets.loop import LoopWGProgram
from repro.core.targets.vector import WGProgram
from repro.core.targets.pallas_target import PallasWGProgram

LSZ = 16
REPEATS = 5
TARGET_CLASSES = {"loop": LoopWGProgram, "vector": WGProgram,
                  "pallas": PallasWGProgram}


def build_saxpy():
    b = KernelBuilder("saxpy")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    a = b.arg_scalar("a", "float32")
    gid = b.global_id(0)
    y[gid] = a * x[gid] + y[gid]
    return b.finish()


def build_reduce():
    b = KernelBuilder("wg_reduce")
    inp = b.arg_buffer("inp", "float32")
    out = b.arg_buffer("out", "float32")
    scratch = b.local_array("scratch", "float32", LSZ)
    lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
    scratch[lid] = inp[gid]
    b.barrier()
    s = b.var(b.const(LSZ // 2), name="s")
    with b.while_loop() as loop:
        loop.cond(s.get() > 0)
        with b.if_(lid < s.get()):
            scratch[lid] = scratch[lid] + scratch[lid + s.get()]
        b.barrier()
        s.set(s.get() / 2)
    with b.if_(lid == 0):
        out[grp] = scratch[0]
    return b.finish()


KERNELS = {"saxpy": build_saxpy, "wg_reduce": build_reduce, "dct": build_dct}


def _time_unshared(build) -> float:
    """Pre-refactor cost: every target builds its own plan from a raw
    Function (the WGProgram compatibility path runs the full pipeline)."""
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for cls in TARGET_CLASSES.values():
            cls(build(), (LSZ,))
        best = min(best, time.perf_counter() - t0)
    return best


def _time_shared(build) -> float:
    """Post-refactor cost: one plan, three thin target lowerings."""
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        plan = build_plan(build())
        for cls in TARGET_CLASSES.values():
            cls(plan, (LSZ,))
        best = min(best, time.perf_counter() - t0)
    return best


def _time_auto_cold(build) -> Dict[str, float]:
    """End-to-end compile_kernel(target='auto') cold sweep: compile every
    candidate through a fresh cache; report wall time + stage counters."""
    cache = CompilationCache()
    set_default_table(TuningTable())
    try:
        p0 = plan_count()
        t0 = time.perf_counter()
        k = compile_kernel(build, (LSZ,), target="auto", cache=cache)
        for tgt in ("loop", "vector", "pallas"):
            k.kernel_for(tgt)
        dt = time.perf_counter() - t0
        return {"auto_cold_ms": dt * 1e3,
                "plans_built": plan_count() - p0,
                "plan_hits": cache.stats.plan_hits}
    finally:
        set_default_table(None)


def run() -> Dict[str, Dict[str, float]]:
    results: Dict[str, Dict[str, float]] = {}
    for name, build in KERNELS.items():
        unshared = _time_unshared(build)
        shared = _time_shared(build)
        r = {"unshared_ms": unshared * 1e3,
             "shared_ms": shared * 1e3,
             "speedup": unshared / shared}
        r.update(_time_auto_cold(build))
        results[name] = r
    return results


def main(trajectory: bool = True, strict_timing: bool = True):
    """``strict_timing=False`` (the CI mode, ``--ci``) gates only on the
    deterministic stage counters — one plan per autotune sweep — and
    reports the wall-clock speedup as an advisory number, so a noisy
    shared runner cannot flake the build on a millisecond-scale timing
    ratio.  Local/benchmark runs keep the full >=1.5x timing gate."""
    res = run()
    print(f"{'kernel':12s} {'unshared':>10s} {'shared':>9s} {'speedup':>9s} "
          f"{'auto cold':>10s} {'plans':>6s}")
    for name, r in res.items():
        print(f"{name:12s} {r['unshared_ms']:8.2f}ms {r['shared_ms']:7.2f}ms"
              f" {r['speedup']:8.2f}x {r['auto_cold_ms']:8.2f}ms "
              f"{r['plans_built']:6d}")
    worst = min(r["speedup"] for r in res.values())
    plans_ok = all(r["plans_built"] == 1 for r in res.values())
    timing_ok = worst >= 1.5
    ok = plans_ok and (timing_ok or not strict_timing)
    status = "OK" if ok else "BELOW TARGET"
    if not timing_ok and not strict_timing and plans_ok:
        status += " (timing advisory only in --ci mode)"
    print(f"\nworst-case 3-target cold-compile speedup from plan sharing: "
          f"{worst:.2f}x (target >=1.5x); one plan per auto sweep: "
          f"{plans_ok}  {status}")
    if trajectory:
        _append_trajectory(res)
    res["_gate_ok"] = ok
    return res


def _append_trajectory(res) -> None:
    """Append this run to BENCH_COMPILE.json (one record per run, so the
    compile-time trajectory is tracked across PRs — see README.md)."""
    path = os.path.join(os.path.dirname(__file__), "BENCH_COMPILE.json")
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except Exception:
            hist = []
    hist.append({"timestamp": time.time(), "results": res})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, default=float)
    print(f"trajectory -> {path} ({len(hist)} runs)")


if __name__ == "__main__":
    import sys
    strict = "--ci" not in sys.argv[1:]
    sys.exit(0 if main(strict_timing=strict).get("_gate_ok") else 1)
