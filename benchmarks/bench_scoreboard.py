"""Performance-portability scoreboard (paper §4, Figs. 12-14; Rupp et al.).

Runs the :mod:`repro.suite` kernels through the Scoreboard — a tuning-
space sweep per (kernel, target) cell with bitwise oracle checks and a
measured-peak roofline — and emits:

  benchmarks/BENCH_SCOREBOARD.json   machine-readable matrix + gates
  benchmarks/SCOREBOARD.md           rendered markdown table

Exit status is the gate verdict (CI job ``scoreboard``):

  (a) every cell bitwise-equal to its NumPy oracle,
  (b) every swept cell's autotuned config at the minimum of its sweep,
  (c) every kernel's achieved-vs-roofline fraction on the vector target
      >= --min-fraction (env REPRO_SCOREBOARD_MIN_FRACTION).

The default fraction floor is deliberately conservative: CPU-hosted
targets (and pallas interpret mode) sit far from their calibrated peaks
on CI-sized problems; the floor catches order-of-magnitude regressions,
not absolute-performance claims.

  PYTHONPATH=src python -m benchmarks.bench_scoreboard [--ci]
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core.autotune import TuningTable
from repro.suite import Scoreboard, render_markdown
from repro.suite.scoreboard import check_gates

HERE = os.path.dirname(__file__)
DEFAULT_MIN_FRACTION = float(
    os.environ.get("REPRO_SCOREBOARD_MIN_FRACTION", "0.0005"))


def run(ci: bool = False, table_path: str | None = None,
        min_fraction: float = DEFAULT_MIN_FRACTION,
        kernels=None):
    table = TuningTable(table_path) if table_path else TuningTable()
    sb = Scoreboard(
        table=table,
        shape_set="ci" if ci else "full",
        warmup=1,
        repeats=2 if ci else 3,
        max_configs=2 if ci else None,
        calibration_n=1 << 12 if ci else 1 << 14,
    )
    report = sb.run(kernels=kernels)
    report["gates"] = check_gates(report, min_fraction=min_fraction,
                                  fraction_target="vector")
    return report


def main(argv=None, ci: bool = False):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true", default=ci,
                    help="reduced sweep: ci shapes, 2 configs per space")
    ap.add_argument("--out", default=os.path.join(HERE,
                                                  "BENCH_SCOREBOARD.json"))
    ap.add_argument("--md", default=os.path.join(HERE, "SCOREBOARD.md"))
    ap.add_argument("--table", default=None,
                    help="TuningTable path for persisted sweep winners "
                         "(a warm run re-measures only the winner)")
    ap.add_argument("--min-fraction", type=float,
                    default=DEFAULT_MIN_FRACTION,
                    help="achieved-vs-roofline floor on the vector target")
    ap.add_argument("--kernels", nargs="*", default=None,
                    help="subset of suite kernels (default: all)")
    args = ap.parse_args(argv)

    report = run(ci=args.ci, table_path=args.table,
                 min_fraction=args.min_fraction, kernels=args.kernels)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    md = render_markdown(report)
    with open(args.md, "w") as f:
        f.write(md)
    print(md)
    print(f"matrix -> {args.out}\ntable  -> {args.md}")

    gates = report["gates"]
    for k in ("bitwise_failures", "winner_failures", "fraction_failures"):
        for item in gates[k]:
            print(f"GATE FAIL [{k}]: {item}")
    status = "OK" if gates["ok"] else "BELOW TARGET"
    print(f"scoreboard gates (bitwise + winner<=worst + "
          f"fraction>={gates['min_fraction']}): {status}")
    return report


if __name__ == "__main__":
    import sys
    sys.exit(0 if main().get("gates", {}).get("ok") else 1)
