"""Benchmark harness entry point: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick]

  Fig. 12-14  -> bench_kernel_suite   (kernel suite across targets)
  §6.4        -> bench_horizontal     (DCT horizontal parallelization)
  Tables 3/4  -> bench_vml            (vecmathlib vs scalarized libm)
  §3          -> bench_bufalloc       (buffer allocator)
  §Roofline   -> roofline_report      (dry-run derived, if results exist)
  §4.1        -> bench_cache          (compile cache: cold vs hit dispatch)
  §3 runtime  -> bench_events         (event DAG overlap + co-execution)
  §4 pipeline -> bench_compile        (plan sharing across the target sweep)
  §3 memory   -> bench_memory         (map/unmap, pooling, ordered migration)
  §Serving    -> bench_serving        (continuous batching vs fixed-slot)
  §Fusion     -> bench_fusion         (DAG-fused chain vs per-kernel launches)
  §Scoreboard -> bench_scoreboard     (suite x target roofline matrix)
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/bench")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)
    summary = {}

    t0 = time.time()
    print("=" * 72)
    print("[1/15] Kernel suite across execution targets (paper Fig. 12-14)")
    print("=" * 72)
    from . import bench_kernel_suite
    res = bench_kernel_suite.main()
    summary["kernel_suite"] = {k: v for k, v in res.items()}

    print()
    print("=" * 72)
    print("[2/15] DCT horizontal inner-loop parallelization (paper §6.4)")
    print("=" * 72)
    from . import bench_horizontal
    summary["horizontal"] = bench_horizontal.main()

    print()
    print("=" * 72)
    print("[3/15] Vecmathlib vs scalarized libm (paper Tables 3/4)")
    print("=" * 72)
    from . import bench_vml
    res = bench_vml.main()
    summary["vml"] = {f"{k[0]}_{k[1]}": v for k, v in res.items()}

    print()
    print("=" * 72)
    print("[4/15] Bufalloc (paper §3)")
    print("=" * 72)
    from . import bench_bufalloc
    summary["bufalloc"] = bench_bufalloc.main()

    print()
    print("=" * 72)
    print("[5/15] Context-array uniform merging (paper §4.7)")
    print("=" * 72)
    from . import bench_context
    summary["context"] = bench_context.main()

    print()
    print("=" * 72)
    print("[6/15] Compilation cache: cold vs cache-hit dispatch (§4.1)")
    print("=" * 72)
    from . import bench_cache
    summary["cache"] = bench_cache.main()

    print()
    print("=" * 72)
    print("[7/15] Event-DAG runtime: overlap + multi-device co-execution (§3)")
    print("=" * 72)
    from . import bench_events
    summary["events"] = bench_events.main()

    print()
    print("=" * 72)
    print("[8/15] Pass-manager plan sharing: cold autotune compile (§4)")
    print("=" * 72)
    from . import bench_compile
    summary["compile"] = bench_compile.main()

    print()
    print("=" * 72)
    print("[9/15] Hierarchical memory: map/unmap, pool, migration (§3)")
    print("=" * 72)
    from . import bench_memory
    summary["memory"] = bench_memory.main()

    print()
    print("=" * 72)
    print("[10/15] Continuous-batching serving scheduler (vs fixed-slot)")
    print("=" * 72)
    from . import bench_serving
    summary["serving"] = bench_serving.main(ci=args.quick)

    print()
    print("=" * 72)
    print("[11/15] Adaptive N-device co-execution vs static (§Scheduler)")
    print("=" * 72)
    from . import bench_coexec
    summary["coexec"] = bench_coexec.main()

    print()
    print("=" * 72)
    print("[12/15] DAG-level kernel fusion vs per-kernel launches (§Fusion)")
    print("=" * 72)
    from . import bench_fusion
    summary["fusion"] = bench_fusion.main()

    print()
    print("=" * 72)
    print("[13/15] Replicated mesh: kill-one-of-three fault recovery")
    print("=" * 72)
    from . import bench_mesh
    summary["mesh"] = bench_mesh.main(ci=args.quick)

    print()
    print("=" * 72)
    print("[14/15] Performance-portability scoreboard (Figs. 12-14, Rupp)")
    print("=" * 72)
    from . import bench_scoreboard
    summary["scoreboard"] = bench_scoreboard.main(
        ["--ci"] if args.quick else [])["gates"]

    print()
    print("=" * 72)
    print("[15/15] Roofline report (dry-run derived)")
    print("=" * 72)
    from . import roofline_report
    roofline_report.main()

    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1, default=float)
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s; "
          f"summary -> {args.out}/summary.json")


if __name__ == "__main__":
    main()
