"""Adaptive N-device co-execution vs the best static split (§Scheduler).

The lopsided platform the adaptive scheduler exists for: two fast
devices plus one slow device (a :class:`ThrottledDevice` charging 8x
the per-group cost), where the slow device additionally *stalls* for
``STALL_S`` at the start of every timed launch — another tenant briefly
hogging it.  Any static split provably loses on this platform:

* give the slow device a fair share and the launch waits on
  ``stall + 8ms/group * share`` — the whole point of asymmetry;
* give it the minimal share (1 group) and the launch still waits out
  ``stall + 8ms``: a static plan cannot un-assign work once the stall
  materializes.

The adaptive mode's throughput model learns the 2:2:16 speed ratio
within a launch, the HGuided splitter sizes chunks to it, and — when
the stall hits — the fast devices finish the frontier and *steal* the
straggler's in-flight span, so the merge gate fires without waiting for
the stall.  Gates (CI-enforced):

* ``adaptive >= 1.5x`` the best static split over an all-positive
  weight sweep (static weights of 0 are device exclusion — a different
  platform, not a split policy);
* the adaptive merge is **bitwise identical** to a single-device launch;
* a fresh executor warm-started from the persisted
  :class:`~repro.core.autotune.TuningTable` converges within its first
  2 launches (slow-class share already lopsided, not the cold equal
  third).

Every executor warms the per-device jit trace with one untimed static
launch first: the one-shot trace cost would otherwise land inside the
first chunk's event window and poison the first throughput observation
(docs/runtime.md §Scheduler).

  PYTHONPATH=src python -m benchmarks.bench_coexec
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import KernelBuilder
from repro.core.autotune import TuningTable
from repro.runtime import Context, DeviceInfo, ThrottledDevice, device_class

N = 96 * 16
LSZ = 16
N_GROUPS = N // LSZ
FAST_S = 0.001          # seconds per work-group, fast devices
SLOW_S = 0.008          # slow device: 8x per-group cost
STALL_S = 0.25          # one-shot stall armed before every timed launch
REPEATS = 3
GATE_SPEEDUP = 1.5


def build_scale():
    b = KernelBuilder("scale")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    y[g] = x[g] * 2.0 + g
    return b.finish()


def make_device(i: int, seconds_per_group: float, cls: str) -> ThrottledDevice:
    return ThrottledDevice(DeviceInfo(
        name=f"bench-{cls}-{i}", driver="vector",
        global_mem_size=1 << 30, local_mem_size=1 << 20,
        max_work_group_size=1024, compute_units=1),
        seconds_per_group=seconds_per_group, coexec_class=cls)


def lopsided_platform() -> List[ThrottledDevice]:
    return [make_device(0, FAST_S, "fast"),
            make_device(1, FAST_S, "fast"),
            make_device(2, SLOW_S, "slow")]


def make_kernel(ctx: Context):
    prog = ctx.create_program(build_scale).build()
    k = prog.create_kernel("scale")
    k.set_args(x=np.arange(N, dtype=np.float32),
               y=np.zeros(N, np.float32))
    return k


def timed_launch(co, k, slow_dev, mode, weights=None):
    """One timed launch with the stall armed — the same adversity for
    every contender."""
    slow_dev.stall(STALL_S)
    t0 = time.perf_counter()
    out = co.launch(k, (N,), (LSZ,), mode=mode, weights=weights)
    return time.perf_counter() - t0, out


def bench_static(reference: bytes) -> Dict[str, object]:
    """Sweep all-positive static weight vectors, from fair to
    minimal-slow (1 group): every one waits out the stall."""
    devs = lopsided_platform()
    ctx = Context(devices=devs)
    k = make_kernel(ctx)
    co = ctx.create_co_executor(devs, tuning_table=TuningTable())
    co.launch(k, (N,), (LSZ,), mode="static")      # jit-trace warm-up
    sweep = {}
    for weights in [(1, 1, 1),                     # fair (speed-blind)
                    (4, 4, 1), (8, 8, 1),          # oracle-ish ratios
                    (16, 16, 1),
                    (47.5, 47.5, 1)]:              # minimal-slow: 1 group
        best = float("inf")
        for _ in range(REPEATS):
            wall, out = timed_launch(co, k, devs[2], "static",
                                     weights=list(weights))
            assert out["y"].tobytes() == reference, \
                f"static {weights} diverged bitwise"
            best = min(best, wall)
        sweep["/".join(str(w) for w in weights)] = best
    co.finish()
    best_key = min(sweep, key=sweep.get)
    return {"sweep_s": sweep, "best_weights": best_key,
            "best_s": sweep[best_key]}


def bench_adaptive(reference: bytes, table: TuningTable
                   ) -> Dict[str, object]:
    devs = lopsided_platform()
    ctx = Context(devices=devs)
    k = make_kernel(ctx)
    co = ctx.create_co_executor(devs, tuning_table=table)
    co.launch(k, (N,), (LSZ,), mode="static")      # jit-trace warm-up
    for _ in range(3):                             # stall-free convergence
        co.launch(k, (N,), (LSZ,), mode="adaptive")
    best, bitwise = float("inf"), True
    for _ in range(REPEATS):
        wall, out = timed_launch(co, k, devs[2], "adaptive")
        bitwise &= out["y"].tobytes() == reference
        best = min(best, wall)
    stats = co.last_stats
    co.finish()
    key = TuningTable.make_coexec_key(
        k.ir_hash, [device_class(d) for d in devs])
    return {"best_s": best, "bitwise_identical": bitwise,
            "weights": dict(stats.weights),
            "steals_per_device": dict(stats.steals_per_device),
            "groups_per_device": dict(stats.groups_per_device),
            "persisted": table.get_coexec(key)}


def bench_warm_convergence(table: TuningTable) -> Dict[str, object]:
    """A fresh executor over fresh devices, warm-started from the table
    persisted by :func:`bench_adaptive`: within 2 launches the slow
    class must already run a lopsided share."""
    devs = lopsided_platform()
    ctx = Context(devices=devs)
    k = make_kernel(ctx)
    co = ctx.create_co_executor(devs, tuning_table=table)
    co.launch(k, (N,), (LSZ,), mode="static")      # jit-trace warm-up
    per_launch = []
    for _ in range(2):
        co.launch(k, (N,), (LSZ,), mode="adaptive")
        per_launch.append(dict(co.last_stats.weights))
    co.finish()
    slow = devs[2].info.name
    slow_share = per_launch[-1][slow]
    slow_groups = co.last_stats.groups_per_device.get(slow, 0)
    return {"weights_per_launch": per_launch,
            "slow_share_after_2": slow_share,
            "slow_groups_last_launch": slow_groups,
            # converged: nowhere near the cold equal third
            "converged": slow_share < 0.2 and slow_groups < N_GROUPS / 3}


def run() -> Dict[str, object]:
    # bitwise reference: the same kernel on one unthrottled device
    ref_dev = make_device(9, 0.0, "ref")
    ref_ctx = Context(devices=[ref_dev])
    ref_out = ref_ctx.create_co_executor(
        [ref_dev], tuning_table=TuningTable()).launch(
            make_kernel(ref_ctx), (N,), (LSZ,), mode="static")
    reference = ref_out["y"].tobytes()

    table = TuningTable()
    static = bench_static(reference)
    adaptive = bench_adaptive(reference, table)
    warm = bench_warm_convergence(table)
    return {"platform": {"n_groups": N_GROUPS, "fast_s_per_group": FAST_S,
                         "slow_s_per_group": SLOW_S, "stall_s": STALL_S},
            "static": static, "adaptive": adaptive, "warm": warm,
            "speedup_vs_best_static":
                static["best_s"] / adaptive["best_s"]}


def main(trajectory: bool = True):
    res = run()
    st, ad, warm = res["static"], res["adaptive"], res["warm"]
    print(f"platform    : 2 fast ({FAST_S * 1e3:.0f}ms/group) + 1 slow "
          f"({SLOW_S * 1e3:.0f}ms/group), {N_GROUPS} groups, "
          f"{STALL_S * 1e3:.0f}ms stall each timed launch")
    for wkey, wall in st["sweep_s"].items():
        mark = " <- best" if wkey == st["best_weights"] else ""
        print(f"  static {wkey:14s}: {wall * 1e3:7.1f}ms{mark}")
    print(f"adaptive    : {ad['best_s'] * 1e3:7.1f}ms  "
          f"speedup {res['speedup_vs_best_static']:.2f}x vs best static  "
          f"bitwise_identical={ad['bitwise_identical']}")
    print(f"  weights   : { {k: round(v, 3) for k, v in ad['weights'].items()} }  "
          f"steals={ad['steals_per_device']}")
    print(f"  persisted : {ad['persisted']}")
    print(f"warm start  : slow share {warm['slow_share_after_2']:.3f} "
          f"after 2 launches ({warm['slow_groups_last_launch']} of "
          f"{N_GROUPS} groups)  converged={warm['converged']}")

    ok = (res["speedup_vs_best_static"] >= GATE_SPEEDUP
          and ad["bitwise_identical"] and warm["converged"])
    status = "OK" if ok else "BELOW TARGET"
    print(f"\nadaptive co-execution gate (>={GATE_SPEEDUP}x best static "
          f"+ bitwise + warm convergence): {status}")
    if trajectory:
        _append_trajectory(res)
    res["_gate_ok"] = ok
    return res


def _append_trajectory(res) -> None:
    """Append this run to BENCH_COEXEC.json (one record per run, so the
    adaptive-vs-static margin is tracked across PRs)."""
    path = os.path.join(os.path.dirname(__file__), "BENCH_COEXEC.json")
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except Exception:
            hist = []
    hist.append({"timestamp": time.time(), "results": res})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, default=float)
    print(f"trajectory -> {path} ({len(hist)} runs)")


if __name__ == "__main__":
    import sys
    sys.exit(0 if main().get("_gate_ok") else 1)
