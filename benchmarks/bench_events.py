"""Event-DAG runtime benchmark: overlap + multi-device co-execution.

Three measurements (docs/runtime.md):

* ``overlap``     — K independent write->kernel->read chains on an
                    in-order queue vs an out-of-order 4-worker queue.
                    The chains share no events, so the DAG scheduler may
                    run them concurrently; the in-order queue serializes
                    them by construction.  ``speedup = t_inorder / t_ooo``
                    is the acceptance gate (>= 1.1x on any multi-core
                    host; the theoretical ceiling is min(K, cores)).
* ``multidevice`` — one NDRange co-executed across 2 devices
                    (static split and work-stealing) vs the same kernel
                    on a single device, with a bitwise-identity check.
* ``profiling``   — per-command dispatch overhead of the event machinery
                    (enqueue + schedule + status/timestamp bookkeeping),
                    measured over no-op commands.

  PYTHONPATH=src python -m benchmarks.bench_events
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core import KernelBuilder
from repro.runtime import CommandQueue, Context, Platform, create_buffer

N = 8192
LSZ = 64
CHAINS = 4
REPEATS = 3


def build_heavy():
    """Compute-heavy kernel: a 100-iteration accumulation per work-item,
    so launch time dominates dispatch time and overlap is observable."""
    b = KernelBuilder("heavy")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    acc = b.var(0.0, name="acc")
    i = b.var(b.const(0), name="i")
    with b.while_loop() as loop:
        loop.cond(i.get() < 100)
        acc.set(acc.get() + (x[g] + i.get() * 0.5))
        i.set(i.get() + 1)
    y[g] = acc.get()
    return b.finish()


def bench_overlap(ctx: Context) -> Dict[str, float]:
    """Independent chains: in-order (serialized) vs out-of-order (DAG)."""
    dev = ctx.devices[0]
    kern = ctx.create_program(build_heavy).create_kernel()
    host = (np.arange(N, dtype=np.float32) / N)
    kern.set_args(x=host, y=np.zeros(N, np.float32))
    ctx.launch(kern, (N,), (LSZ,), device=dev)           # jit warm-up
    bufs = [(create_buffer(dev, N, "float32"),
             create_buffer(dev, N, "float32")) for _ in range(CHAINS)]
    outs = [np.zeros(N, np.float32) for _ in range(CHAINS)]

    def run(out_of_order: bool) -> float:
        best = float("inf")
        for _ in range(REPEATS):
            q = CommandQueue(dev, out_of_order=out_of_order, workers=4)
            t0 = time.perf_counter()
            for (xb, yb), out in zip(bufs, outs):
                e1 = q.enqueue_write_buffer(xb, host)
                kc = kern.clone().set_args(x=xb, y=yb)
                e2 = q.enqueue_nd_range(kc, (N,), (LSZ,), wait_for=[e1])
                q.enqueue_read_buffer(yb, out, wait_for=[e2])
            q.finish()
            best = min(best, time.perf_counter() - t0)
        return best

    t_in = run(False)
    t_ooo = run(True)
    expect = host * 100 + np.arange(100, dtype=np.float32).sum() * 0.5
    for out in outs:
        np.testing.assert_allclose(out, expect, rtol=1e-5)
    return {"chains": CHAINS, "inorder_s": t_in, "ooo_s": t_ooo,
            "overlap_speedup": t_in / t_ooo}


def bench_multidevice(ctx: Context) -> Dict[str, object]:
    """One NDRange split across 2 devices vs a single device."""
    dev = ctx.platform.get_devices("vector")[0]
    kern = ctx.create_program(build_heavy).create_kernel()
    host = (np.arange(N, dtype=np.float32) / N)
    zeros = np.zeros(N, np.float32)
    kern.set_args(x=host, y=zeros)
    single = ctx.launch(kern, (N,), (LSZ,), device=dev)   # warm + reference
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        single = ctx.launch(kern, (N,), (LSZ,), device=dev)
    t_single = (time.perf_counter() - t0) / REPEATS

    co = ctx.create_co_executor(ctx.platform.co_devices(2),
                                chunks_per_device=3)
    # warm every (device, chunk-range) pair: work-stealing assigns chunks
    # dynamically, so any chunk may land on any device; binding returns
    # the same compiled kernel co-execution uses, so its per-shape jit
    # cache warms here
    n_groups = N // LSZ
    n_chunks = co.chunks_per_device * len(co.devices)
    chunk = -(-n_groups // n_chunks)
    for d in co.devices:
        kd = kern.bind(d, (LSZ,))
        for lo in range(0, n_groups, chunk):
            kd({"x": host, "y": zeros}, (N,),
               group_range=(lo, min(lo + chunk, n_groups)))
    res: Dict[str, object] = {"single_s": t_single}
    for mode in ("static", "steal"):
        co.launch(kern, (N,), (LSZ,), mode=mode)  # warm the static spans
        t0 = time.perf_counter()
        for _ in range(REPEATS):
            merged = co.launch(kern, (N,), (LSZ,), mode=mode)
        t_co = (time.perf_counter() - t0) / REPEATS
        identical = merged["y"].tobytes() == \
            np.asarray(single["y"]).tobytes()
        res[mode] = {
            "co_s": t_co,
            "speedup_vs_single": t_single / t_co,
            "bitwise_identical": identical,
            "groups_per_device": co.last_stats.groups_per_device,
            "migrations": co.last_stats.migrations,
        }
    co.finish()
    return res


def bench_profiling(plat: Platform) -> Dict[str, float]:
    """Dispatch overhead of the event machinery on no-op commands."""
    dev = plat.get_devices()[0]
    n_cmds = 200
    best = float("inf")
    for _ in range(REPEATS):
        q = CommandQueue(dev, out_of_order=True, workers=2)
        t0 = time.perf_counter()
        ev = None
        for i in range(n_cmds):
            ev = q._enqueue(f"nop{i}", lambda: None,
                            [ev] if ev is not None else [])
        q.finish()
        best = min(best, time.perf_counter() - t0)
    return {"commands": n_cmds,
            "per_command_us": best / n_cmds * 1e6}


def run() -> Dict[str, object]:
    plat = Platform()
    ctx = Context(platform=plat)
    return {"overlap": bench_overlap(ctx),
            "multidevice": bench_multidevice(ctx),
            "profiling": bench_profiling(plat)}


def main(trajectory: bool = True):
    res = run()
    ov = res["overlap"]
    print(f"overlap     : {ov['chains']} chains  "
          f"in-order {ov['inorder_s'] * 1e3:7.1f}ms  "
          f"out-of-order {ov['ooo_s'] * 1e3:7.1f}ms  "
          f"speedup {ov['overlap_speedup']:.2f}x")
    md = res["multidevice"]
    print(f"multidevice : single {md['single_s'] * 1e3:7.1f}ms")
    for mode in ("static", "steal"):
        m = md[mode]
        print(f"  {mode:7s}: {m['co_s'] * 1e3:7.1f}ms  "
              f"speedup {m['speedup_vs_single']:.2f}x  "
              f"bitwise_identical={m['bitwise_identical']}  "
              f"groups={m['groups_per_device']}")
    pr = res["profiling"]
    print(f"profiling   : {pr['per_command_us']:.0f}us/command "
          f"({pr['commands']} chained no-ops)")

    ok = ov["overlap_speedup"] >= 1.1 and \
        all(md[m]["bitwise_identical"] for m in ("static", "steal"))
    status = "OK" if ok else "BELOW TARGET"
    print(f"\nDAG overlap gate (>=1.1x + bitwise-identical split): {status}")
    if trajectory:
        _append_trajectory(res)
    res["_gate_ok"] = ok
    return res


def _append_trajectory(res) -> None:
    """Append this run to BENCH_EVENTS.json (one record per run, so
    overlap and co-execution speedups are tracked across PRs)."""
    path = os.path.join(os.path.dirname(__file__), "BENCH_EVENTS.json")
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except Exception:
            hist = []
    hist.append({"timestamp": time.time(), "results": res})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, default=float)
    print(f"trajectory -> {path} ({len(hist)} runs)")


if __name__ == "__main__":
    import sys
    sys.exit(0 if main().get("_gate_ok") else 1)
