"""Render the §Roofline table from the dry-run result JSONs
(results/dryrun/<mesh>/<variant>/<arch>__<shape>.json)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load(mesh="pod16x16", variant="baseline", base="results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(base, mesh, variant, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | dominant | t_comp (ms) | t_mem (ms) | "
           "t_coll (ms) | mem/dev (GiB) | useful/HLO flops | roofline |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for d in sorted(rows, key=lambda d: (d["shape"], d["arch"])):
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['dominant']} "
            f"| {d['t_compute_eff'] * 1e3:.2f} | {d['t_memory'] * 1e3:.2f} "
            f"| {d['t_collective'] * 1e3:.2f} "
            f"| {d['bytes_per_device'] / 2**30:.2f} "
            f"| {d['useful_flop_ratio']:.2f} "
            f"| {d['roofline_fraction'] * 100:.1f}% |")
    return "\n".join(lines)


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = load(mesh)
        if not rows:
            continue
        print(f"\n### Roofline — {mesh} (baseline)\n")
        print(markdown_table(rows))
        worst = sorted((r for r in rows if r["shape"] != "long_500k"),
                       key=lambda d: d["roofline_fraction"])[:3]
        print("\nworst cells:",
              ", ".join(f"{w['arch']}x{w['shape']}"
                        f" ({w['roofline_fraction']*100:.1f}%)"
                        for w in worst))
    return 0


if __name__ == "__main__":
    main()
