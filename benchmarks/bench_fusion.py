"""DAG-level kernel fusion: fused chain vs per-kernel launches.

The rmsnorm→residual→quantize elementwise chain from
``repro.core.examples`` is enqueued on two queues over the same device:
``fusion="off"`` (three launches, two materialized intermediates) and
``fusion="flush"`` (one stitched launch, both intermediates elided —
docs/runtime.md §Kernel fusion).  Each size is timed as best-of-R
batches of enqueue×3 + ``finish()``, so the measured win is exactly what
fusion buys: two launch round-trips and two intermediate store/load
pairs per chain.  Gates (CI-enforced):

* best fused speedup across the size sweep ``>= 1.3x`` unfused;
* fused output **bitwise identical** to unfused at every size;
* ``plan_builds`` stable after the first fused launch (the stitched
  kernel is planned once, then every flush is a fused-tier hit);
* ``bytes_elided > 0`` and the pooled intermediates are *never
  materialized* by the fused queue.

  PYTHONPATH=src python -m benchmarks.bench_fusion
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core.examples import (build_quantize, build_residual_add,
                                 build_rmsnorm_ew)
from repro.runtime.context import Context

SIZES = (1024, 16384, 262144)
ITERS = 20
REPEATS = 5
GATE_SPEEDUP = 1.3


def _chain(ctx: Context, fusion: str, n: int):
    """A ready-to-run chain: (queue, kernels, buffers)."""
    prog = ctx.create_program(build_rmsnorm_ew, build_residual_add,
                              build_quantize)
    bufs = {nm: ctx.create_buffer(n) for nm in "xwryzq"}
    rng = np.random.default_rng(0)
    queue = ctx.create_queue(ctx.devices[0], fusion=fusion)
    for nm in "xwr":
        queue.enqueue_write_buffer(
            bufs[nm], rng.standard_normal(n).astype(np.float32))
    k1 = prog.create_kernel("rmsnorm_ew")
    k1.set_args(x=bufs["x"], w=bufs["w"], y=bufs["y"], inv_rms=0.5)
    k2 = prog.create_kernel("residual_add")
    k2.set_args(y=bufs["y"], r=bufs["r"], z=bufs["z"])
    k3 = prog.create_kernel("quantize")
    k3.set_args(z=bufs["z"], q=bufs["q"], scale=16.0)
    return queue, (k1, k2, k3), bufs


def bench_mode(ctx: Context, fusion: str, n: int) -> Dict[str, object]:
    lsz = (min(n, 256),)
    queue, kernels, bufs = _chain(ctx, fusion, n)
    for k in kernels:                              # jit/stitch warm-up
        queue.enqueue_nd_range(k, (n,), lsz)
    queue.finish()
    plans_after_warm = ctx.devices[0].compile_cache.stats.plan_builds
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for _ in range(ITERS):
            for k in kernels:
                queue.enqueue_nd_range(k, (n,), lsz)
            queue.finish()
        best = min(best, (time.perf_counter() - t0) / ITERS)
    stats = queue.dag_stats()
    return {"best_s": best,
            "q": np.array(bufs["q"].data),
            "dag_stats": stats,
            "launches": queue.stats["launches"],
            "intermediates_materialized":
                bufs["y"].materialized or bufs["z"].materialized,
            "plan_builds_stable":
                ctx.devices[0].compile_cache.stats.plan_builds
                == plans_after_warm}


def run() -> Dict[str, object]:
    per_size = {}
    for n in SIZES:
        off = bench_mode(Context(), "off", n)
        fused = bench_mode(Context(), "flush", n)
        per_size[n] = {
            "unfused_ms": off["best_s"] * 1e3,
            "fused_ms": fused["best_s"] * 1e3,
            "speedup": off["best_s"] / fused["best_s"],
            "bitwise_identical": bool(
                np.array_equal(off["q"], fused["q"])),
            "bytes_elided": fused["dag_stats"]["bytes_elided"],
            "fused_chains": fused["dag_stats"]["fused_chains"],
            "intermediates_materialized":
                fused["intermediates_materialized"],
            "plan_builds_stable": fused["plan_builds_stable"],
        }
    best_speedup = max(r["speedup"] for r in per_size.values())
    return {"sizes": per_size, "best_speedup": best_speedup}


def main(trajectory: bool = True):
    res = run()
    print(f"{'N':>8s} {'unfused':>10s} {'fused':>10s} {'speedup':>8s} "
          f"{'bitwise':>8s} {'elided':>10s}")
    for n, r in res["sizes"].items():
        print(f"{n:8d} {r['unfused_ms']:8.3f}ms {r['fused_ms']:8.3f}ms "
              f"{r['speedup']:7.2f}x {str(r['bitwise_identical']):>8s} "
              f"{r['bytes_elided']:>9d}B")

    rs = res["sizes"].values()
    ok = (res["best_speedup"] >= GATE_SPEEDUP
          and all(r["bitwise_identical"] for r in rs)
          and all(r["plan_builds_stable"] for r in rs)
          and all(r["bytes_elided"] > 0 for r in rs)
          and not any(r["intermediates_materialized"] for r in rs))
    status = "OK" if ok else "BELOW TARGET"
    print(f"\nfusion gate (>={GATE_SPEEDUP}x best, bitwise at every size, "
          f"plan_builds stable, intermediates elided): {status}")
    if trajectory:
        _append_trajectory(res)
    res["_gate_ok"] = ok
    return res


def _append_trajectory(res) -> None:
    """Append this run to BENCH_FUSION.json (one record per run, so the
    fusion margin is tracked across PRs)."""
    path = os.path.join(os.path.dirname(__file__), "BENCH_FUSION.json")
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except Exception:
            hist = []
    keep = {n: {k: v for k, v in r.items()}
            for n, r in res["sizes"].items()}
    hist.append({"timestamp": time.time(),
                 "results": {"sizes": keep,
                             "best_speedup": res["best_speedup"]}})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, default=float)
    print(f"trajectory -> {path} ({len(hist)} runs)")


if __name__ == "__main__":
    import sys
    sys.exit(0 if main().get("_gate_ok") else 1)
