"""Paper §6.4 analogue: horizontal inner-loop parallelization on the DCT
kernel.

On the paper's TTA the pass gave ~5.2x (53.5ms -> 10.2ms) because the
inner loop blocked static parallelization across work-items.  Here the
'static multi-issue datapath' is the CPU SIMD unit reached through the
vector target: with horizontal parallelization the work-item dimension
becomes the innermost vectorizable loop; without it, each work-item runs
its inner loop serially (loop target = the serial bound)."""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core import KernelBuilder, compile_kernel


def build_dct(width: int):
    def build():
        b = KernelBuilder("dct")
        inp = b.arg_buffer("inp", "float32")
        coef = b.arg_buffer("coef", "float32")
        out = b.arg_buffer("out", "float32")
        w = b.arg_scalar("width", "int32")
        lid = b.local_id(0)
        acc = b.var(0.0, name="acc")
        k = b.var(b.const(0), name="k")
        with b.while_loop() as loop:
            loop.cond(k.get() < w)
            acc.set(acc.get() + coef[k.get()] * inp[lid * w + k.get()])
            k.set(k.get() + 1)
        out[lid] = acc.get()
        return b.finish()
    return build


def _time(fn, iters=10):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(lsz: int = 256, width: int = 64) -> Dict[str, float]:
    rng = np.random.default_rng(0)
    bufs = {"inp": rng.standard_normal(lsz * width).astype(np.float32),
            "coef": rng.standard_normal(width).astype(np.float32),
            "out": np.zeros(lsz, np.float32)}
    scalars = {"width": width}
    build = build_dct(width)
    out = {}
    for hz in (False, True):
        k = compile_kernel(build, (lsz,), target="vector", horizontal=hz)
        out[f"vector_hz={hz}"] = _time(
            lambda: k({k2: v.copy() for k2, v in bufs.items()},
                      (lsz,), scalars))
    k = compile_kernel(build, (lsz,), target="loop")
    out["loop"] = _time(lambda: k({k2: v.copy() for k2, v in bufs.items()},
                                  (lsz,), scalars))
    # §6.4 mapping: the paper's 'no horizontal parallelization' case is a
    # target that executes each work-item's inner loop serially — our loop
    # target.  In the vector target the uniform inner loop is ALREADY
    # lockstep across lanes (the interchange falls out of the uniformity
    # analysis, see DESIGN.md), so the paper's speedup corresponds to
    # loop vs vector; the explicit hz pass only re-splits regions.
    out["speedup_serial_vs_horizontal"] = out["loop"] / out["vector_hz=True"]
    out["speedup_hz_pass_within_vector"] = \
        out["vector_hz=False"] / out["vector_hz=True"]
    return out


def main():
    r = run()
    print("DCT kernel (paper §6.4):")
    for k, v in r.items():
        if k == "speedup_serial_vs_horizontal":
            print(f"  {k}: {v:.1f}x   (paper's TTA: 5.2x; CPU-SIMD "
                  f"lane count >> TTA FPU count)")
        elif k.startswith("speedup"):
            print(f"  {k}: {v:.2f}x")
        else:
            print(f"  {k}: {v * 1e3:.3f} ms")
    return r


if __name__ == "__main__":
    main()
