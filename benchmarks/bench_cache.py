"""Compilation-cache microbenchmark: cold compile vs cache-hit dispatch.

Measures the per-launch dispatch overhead of ``compile_kernel`` + launch in
three regimes:

  cold     — empty cache: full normalize → regions → target-lowering
             pipeline on every dispatch (the seed behaviour)
  hit      — warm cache: canonical-IR hash + LRU lookup per dispatch
  autotune — warm tuning table + warm cache: table lookup + cache hit

Two views are reported:

* ``cold/hit``  — end-to-end per-dispatch wall time ratio.  The launch term
  is identical in both regimes, so this is a *lower bound* on the
  dispatch-overhead reduction and is robust to timing noise (no
  subtraction of nearly-equal quantities).  The >=10x acceptance gate is
  evaluated on this bound.
* ``*_compile_us`` — the compile_kernel step alone, measured directly:
  full pipeline when cold vs canonical-hash + LRU lookup on a hit.

  PYTHONPATH=src python -m benchmarks.bench_cache
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core import (CompilationCache, KernelBuilder, TuningTable,
                        compile_kernel, set_default_table)
from repro.launch.variants import kernel_variant

N = 4096
LSZ = 64
REPEATS = 10


def _policy(name: str, warm_cache: CompilationCache) -> dict:
    """Resolve a KERNEL_VARIANTS policy to compile_kernel kwargs, binding
    cache=True to this benchmark's warm cache instance."""
    kw = kernel_variant(name)
    kw["cache"] = warm_cache if kw["cache"] else False
    return kw


def build_saxpy():
    b = KernelBuilder("saxpy")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    a = b.arg_scalar("a", "float32")
    gid = b.global_id(0)
    y[gid] = a * x[gid] + y[gid]
    return b.finish()


def build_reduce():
    b = KernelBuilder("wg_reduce")
    inp = b.arg_buffer("inp", "float32")
    out = b.arg_buffer("out", "float32")
    scratch = b.local_array("scratch", "float32", LSZ)
    lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
    scratch[lid] = inp[gid]
    b.barrier()
    s = b.var(b.const(LSZ // 2), name="s")
    with b.while_loop() as loop:
        loop.cond(s.get() > 0)
        with b.if_(lid < s.get()):
            scratch[lid] = scratch[lid] + scratch[lid + s.get()]
        b.barrier()
        s.set(s.get() / 2)
    with b.if_(lid == 0):
        out[grp] = scratch[0]
    return b.finish()


KERNELS = {
    "saxpy": (build_saxpy,
              lambda: {"x": np.arange(N, dtype=np.float32),
                       "y": np.ones(N, np.float32)},
              {"a": np.float32(2.0)}),
    "wg_reduce": (build_reduce,
                  lambda: {"inp": np.arange(N, dtype=np.float32),
                           "out": np.zeros(N // LSZ, np.float32)},
                  None),
}


def _time_dispatch(build, bufs, scalars, policy, repeats=REPEATS) -> float:
    """Best-of-N seconds for one compile_kernel+launch dispatch under a
    KERNEL_VARIANTS policy (resolved compile_kernel kwargs)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        k = compile_kernel(build, (LSZ,), **policy)
        k(bufs, (N,), scalars)
        best = min(best, time.perf_counter() - t0)
    return best


def _time_compile_only(build, policy, repeats=REPEATS) -> float:
    """Best-of-N seconds for the dispatch (compile_kernel) step alone —
    measured directly rather than as a difference of two noisy
    end-to-end timings."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        compile_kernel(build, (LSZ,), **policy)
        best = min(best, time.perf_counter() - t0)
    return best


def _time_launch_only(k, bufs, scalars, repeats=REPEATS) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        k(bufs, (N,), scalars)
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> Dict[str, Dict[str, float]]:
    results: Dict[str, Dict[str, float]] = {}
    for name, (build, mk_bufs, scalars) in KERNELS.items():
        bufs = mk_bufs()

        # launch-only floor (shared by all regimes, jit-warm)
        warm = CompilationCache()
        cached = _policy("cached", warm)
        k = compile_kernel(build, (LSZ,), **cached)
        k(bufs, (N,), scalars)
        launch = _time_launch_only(k, bufs, scalars)

        # cold ("uncached" policy): full pipeline on every dispatch
        uncached = _policy("uncached", warm)
        cold = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            kc = compile_kernel(build, (LSZ,), **uncached)
            kc(bufs, (N,), scalars)
            cold = min(cold, time.perf_counter() - t0)

        # hit ("cached" policy): dispatch = hash + lookup + jit-warm launch
        hit = _time_dispatch(build, bufs, scalars, cached)

        # dispatch overhead, measured directly (compile step alone)
        cold_d = _time_compile_only(build, uncached, repeats=3)
        hit_d = _time_compile_only(build, cached)

        # "autotuned" policy steady state: warm table + warm cache
        autotuned = _policy("autotuned", warm)
        set_default_table(TuningTable())
        try:
            ka = compile_kernel(build, (LSZ,), **autotuned)
            ka(bufs, (N,), scalars)  # tunes + warms every candidate
            tuned = _time_dispatch(build, bufs, scalars, autotuned)
        finally:
            set_default_table(None)

        results[name] = {
            "launch_us": launch * 1e6,
            "cold_us": cold * 1e6,
            "hit_us": hit * 1e6,
            "autotuned_us": tuned * 1e6,
            "cold_compile_us": cold_d * 1e6,
            "hit_compile_us": hit_d * 1e6,
            # end-to-end ratio: a conservative lower bound on the
            # dispatch-overhead reduction (launch time is common to both)
            "dispatch_speedup": cold / hit,
        }
    return results


def main(trajectory: bool = True):
    res = run()
    print(f"{'kernel':12s} {'launch':>9s} {'cold':>11s} {'hit':>9s} "
          f"{'auto':>9s} {'dispatch x':>11s}")
    for name, r in res.items():
        print(f"{name:12s} {r['launch_us']:8.0f}u {r['cold_us']:10.0f}u "
              f"{r['hit_us']:8.0f}u {r['autotuned_us']:8.0f}u "
              f"{r['dispatch_speedup']:10.1f}x")
    worst = min(r["dispatch_speedup"] for r in res.values())
    ok = worst >= 10
    status = "OK (>=10x)" if ok else "BELOW TARGET"
    print(f"\nworst-case cache-hit dispatch speedup: {worst:.1f}x  {status}")
    if trajectory:
        _append_trajectory(res)
    res["_gate_ok"] = ok
    return res


def _append_trajectory(res) -> None:
    """Append this run to the BENCH_CACHE.json trajectory file (one record
    per run, so dispatch overhead is tracked across PRs — see README.md)."""
    path = os.path.join(os.path.dirname(__file__), "BENCH_CACHE.json")
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except Exception:
            hist = []
    hist.append({"timestamp": time.time(), "results": res})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, default=float)
    print(f"trajectory -> {path} ({len(hist)} runs)")


if __name__ == "__main__":
    import sys
    sys.exit(0 if main().get("_gate_ok") else 1)
