"""Paper Tables 3/4 analogue: Vecmathlib vs scalarized libm.

The paper compares vectorized elemental functions against scalarizing
each SIMD lane and calling libm.  The CPU/JAX analogue:

  scalarized — python-loop over elements calling numpy scalar math (the
               'disassemble the vector, call libm per lane' cost model)
  numpy      — numpy's vectorized libm (the proprietary-quality baseline)
  vml        — repro.vml polynomial/bit-twiddle implementations under jit
               (what the TPU VPU executes)

Reported: ns/element for exp, sin, sqrt at vector lengths 4 / 4096 /
1M, mirroring the paper's scalar-vs-vector sweep.
"""

from __future__ import annotations

import math
import time
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro import vml


def _time(fn, iters=20):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


FUNCS = {
    "exp": (vml.exp, np.exp, math.exp),
    "sin": (vml.sin, np.sin, math.sin),
    "sqrt": (vml.sqrt, np.sqrt, math.sqrt),
}


def run(sizes=(4, 4096, 1_048_576)) -> Dict:
    rng = np.random.default_rng(0)
    out = {}
    for name, (vml_fn, np_fn, scalar_fn) in FUNCS.items():
        for n in sizes:
            x = rng.uniform(0.1, 10.0, n).astype(np.float32)
            xj = jnp.asarray(x)
            jfn = jax.jit(vml_fn)
            jfn(xj).block_until_ready()
            t_vml = _time(lambda: jfn(xj).block_until_ready())
            t_np = _time(lambda: np_fn(x))
            if n <= 4096:   # the scalarized path is too slow at 1M
                t_scalar = _time(lambda: [scalar_fn(float(v)) for v in x],
                                 iters=3)
            else:
                t_scalar = float("nan")
            out[(name, n)] = {
                "vml_ns_per_elem": t_vml / n * 1e9,
                "numpy_ns_per_elem": t_np / n * 1e9,
                "scalarized_ns_per_elem": t_scalar / n * 1e9,
            }
    return out


def main():
    res = run()
    print(f"{'func':6s} {'n':>9s} {'scalarized':>12s} {'numpy':>10s} "
          f"{'vml(jit)':>10s}  (ns/elem)")
    for (name, n), r in res.items():
        print(f"{name:6s} {n:9d} {r['scalarized_ns_per_elem']:12.1f} "
              f"{r['numpy_ns_per_elem']:10.1f} {r['vml_ns_per_elem']:10.1f}")
    return res


if __name__ == "__main__":
    main()
