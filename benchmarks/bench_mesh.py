"""Replicated-mesh fault benchmark: kill one of three replicas mid-trace.

The headline acceptance run for docs/mesh.md: a seeded Poisson request
trace (the bench_serving generator) is served by a 3-replica
:class:`~repro.serving.ServingMesh` over deterministic
:class:`~repro.serving.executor.StubExecutor` replicas, with a Chrome
trace attached; one replica is killed mid-trace
(``inject_fault(stage="device")`` through the mesh's chaos hook).

Gates (ISSUE 9 acceptance criteria):

* every request finishes with a token stream **bitwise-identical to the
  serial oracle** (a 1-slot, 1-replica engine serving the same trace) —
  migration recompute changes nothing;
* **zero drops** — submitted == completed, no request failed;
* **zero KV page leaks** on live *and* dead replicas;
* **recovery <= 2 steps** — every migrated request is decoding (or
  done) on the sibling within two mesh steps of the kill;
* the exported Chrome trace **passes the schema validator** and
  contains the migration flow events.

Results append to BENCH_MESH.json (one record per run).

  PYTHONPATH=src python -m benchmarks.bench_mesh [--ci]
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.runtime.trace import validate_trace
from repro.serving import Request, ServingEngine, ServingMesh, StubExecutor

from .bench_serving import gen_trace

REPLICAS = 3
SLOTS = 4
MAX_SEQ = 128
PAGE_TOKENS = 8


def serial_oracle(trace) -> List[tuple]:
    """The same trace served by one slot, one request at a time."""
    eng = ServingEngine(None, None, None, batch_slots=1,
                        max_seq=MAX_SEQ, page_tokens=PAGE_TOKENS,
                        executor=StubExecutor(batch_slots=1,
                                              max_seq=MAX_SEQ))
    reqs = []
    for _, prompt, max_new in trace:
        r = Request(prompt=prompt.copy(), max_new_tokens=max_new)
        eng.submit(r)
        reqs.append(r)
    eng.drain()
    return [tuple(r.out_tokens) for r in reqs]


def bench_mesh_kill(n_requests: int) -> Dict[str, object]:
    trace = gen_trace(n_requests, seed=0)
    mesh = ServingMesh(
        n_replicas=REPLICAS, batch_slots=SLOTS, max_seq=MAX_SEQ,
        page_tokens=PAGE_TOKENS,
        executor_factory=lambda i: StubExecutor(batch_slots=SLOTS,
                                                max_seq=MAX_SEQ))
    tr = mesh.attach_trace()

    kill_at = max(2, trace[len(trace) // 2][0])   # mid-trace mesh step
    killed = False
    migrated = None               # requests moved off the dead replica
    recovery_steps = None         # steps until all decode again
    reqs: List[Request] = []
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or any(r.load for r in mesh.alive()):
        while i < len(trace) and trace[i][0] <= mesh.current_step:
            _, prompt, max_new = trace[i]
            r = Request(prompt=prompt.copy(), max_new_tokens=max_new)
            mesh.submit(r)
            reqs.append(r)
            i += 1
        if not killed and mesh.current_step >= kill_at:
            mesh.kill_replica(0)
            killed = True
        mesh.step()
        if migrated is None and mesh.last_migrated:
            migrated = list(mesh.last_migrated)
            steps_since = 0
        elif migrated is not None and recovery_steps is None:
            # recovery: mesh steps from the migration until every
            # migrated request emits tokens (or finishes) on the sibling
            steps_since += 1
            if all(r.done or r.out_tokens for r in migrated):
                recovery_steps = steps_since
    wall = time.perf_counter() - t0

    if migrated is not None and recovery_steps is None and \
            all(r.done or r.out_tokens for r in migrated):
        recovery_steps = steps_since      # recovered on the final step
    migrated_ids = {m["request"] for m in mesh.migrations}
    kill_step = min((m["step"] for m in mesh.migrations),
                    default=mesh.current_step)

    streams = [tuple(r.out_tokens) for r in reqs]
    oracle = serial_oracle(trace)
    events = tr.trace_events()
    schema_counts = validate_trace(events)
    migration_flows = sum(1 for e in events
                          if e.get("cat") == "migration"
                          and e["ph"] == "s")
    stats = mesh.mesh_stats
    return {
        "requests": len(reqs),
        "replicas": REPLICAS,
        "killed_replica": 0,
        "kill_step": kill_step,
        "completed": sum(1 for r in reqs if r.done),
        "dropped": sum(1 for r in reqs
                       if not r.done and r.error is None),
        "failed": sum(1 for r in reqs if r.error is not None),
        "migrated": stats["migrated"],
        "migrated_unique": len(migrated_ids),
        "recovery_steps": recovery_steps
        if recovery_steps is not None else -1,
        "wall_s": wall,
        "tokens": int(sum(len(r.out_tokens) for r in reqs)),
        "bitwise_identical_to_serial": streams == oracle,
        "pages_leaked": {
            r["key"]: r["pages_live"] for r in stats["replicas"]},
        "trace_events": sum(schema_counts.values()),
        "trace_schema_ok": True,      # validate_trace raised otherwise
        "migration_flows": migration_flows,
    }


def run(ci: bool = False) -> Dict[str, object]:
    return {"mesh_kill": bench_mesh_kill(18 if ci else 48)}


def main(trajectory: bool = True, ci: bool = False):
    res = run(ci=ci)
    mk = res["mesh_kill"]
    print(f"mesh        : {mk['requests']} reqs over {mk['replicas']} "
          f"replicas, replica {mk['killed_replica']} killed at step "
          f"{mk['kill_step']}")
    print(f"  outcome   : completed={mk['completed']} "
          f"dropped={mk['dropped']} failed={mk['failed']} "
          f"migrated={mk['migrated']} "
          f"recovery={mk['recovery_steps']} steps  "
          f"bitwise={mk['bitwise_identical_to_serial']}")
    print(f"  kv        : pages leaked per replica "
          f"{mk['pages_leaked']}")
    print(f"  trace     : {mk['trace_events']} events, schema ok, "
          f"{mk['migration_flows']} migration flows")

    ok = (mk["completed"] == mk["requests"]
          and mk["dropped"] == 0 and mk["failed"] == 0
          and mk["migrated"] >= 1
          and 0 <= mk["recovery_steps"] <= 2
          and mk["bitwise_identical_to_serial"]
          and all(v == 0 for v in mk["pages_leaked"].values())
          and mk["trace_schema_ok"]
          and mk["migration_flows"] >= 1)
    status = "OK" if ok else "BELOW TARGET"
    print(f"\nmesh gates (bitwise vs serial oracle, 0 drops, recovery "
          f"<= 2 steps, 0 leaks, trace schema + migration flows): "
          f"{status}")
    if trajectory:
        _append_trajectory(res)
    res["_gate_ok"] = ok
    return res


def _append_trajectory(res) -> None:
    """Append this run to BENCH_MESH.json (one record per run, so the
    fault-recovery trajectory is tracked across PRs)."""
    path = os.path.join(os.path.dirname(__file__), "BENCH_MESH.json")
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except Exception:
            hist = []
    hist.append({"timestamp": time.time(), "results": res})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, default=float)
    print(f"trajectory -> {path} ({len(hist)} runs)")


if __name__ == "__main__":
    import sys
    ci = "--ci" in sys.argv
    sys.exit(0 if main(ci=ci).get("_gate_ok") else 1)
