"""Hierarchical-memory benchmark: map/unmap, pooling, ordered migration.

Three measurements, each an acceptance gate (docs/memory.md):

* ``map_vs_copy``   — host touch of a device buffer through zero-copy
                      ``enqueue_map_buffer``/``enqueue_unmap_buffer``
                      vs the portable read-modify-write path
                      (``enqueue_read_buffer`` + ``enqueue_write_buffer``).
                      The copy path moves the full buffer twice per
                      touch; the map path moves nothing.
                      Gate: ``copy_per_touch / map_per_touch >= 5``.
* ``pool_vs_firstfit`` — serving-style KV block churn (cycled sizes,
                      bounded live set) on a fragmented arena: direct
                      first-fit ``Bufalloc`` alloc/free vs a size-class
                      :class:`~repro.runtime.memory.BufferPool` over an
                      identical arena.  Gate: ``pool_ops_per_s /
                      firstfit_ops_per_s >= 2``.
* ``migration``     — one NDRange co-executed on 2 devices with
                      event-ordered migration: results must stay
                      **bitwise identical** to the single-device launch,
                      repeat runs must re-migrate only the spans the
                      *other* device wrote (partial migrations), and the
                      transfer/compute overlap window is reported.

  PYTHONPATH=src python -m benchmarks.bench_memory
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core import KernelBuilder
from repro.runtime import (Bufalloc, BufferPool, CommandQueue, Context,
                           OutOfMemory, Platform, create_buffer)

N_MAP = 1 << 21          # floats mapped/copied per host touch (8 MiB)
TOUCHES = 8
REPEATS = 3

N_CO = 8192
LSZ = 64

POOL_OPS = 2000
POOL_LIVE = 32           # live KV blocks during churn
PIN_CHUNKS = 400         # pinned fragmentation in front of the arena


def build_heavy():
    """Compute-heavy kernel so migration has compute to hide behind."""
    b = KernelBuilder("heavy")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    acc = b.var(0.0, name="acc")
    i = b.var(b.const(0), name="i")
    with b.while_loop() as loop:
        loop.cond(i.get() < 100)
        acc.set(acc.get() + (x[g] + i.get() * 0.5))
        i.set(i.get() + 1)
    y[g] = acc.get()
    return b.finish()


# ---------------------------------------------------------------------------
# Gate 1: zero-copy map/unmap vs read-modify-write
# ---------------------------------------------------------------------------

def bench_map_vs_copy(plat: Platform) -> Dict[str, float]:
    dev = plat.get_devices("basic")[0]
    q = CommandQueue(dev)
    buf = create_buffer(dev, N_MAP, "float32")
    expect = np.zeros(N_MAP, np.float32)
    q.enqueue_write_buffer(buf, expect)
    q.finish()

    def touch_copy() -> None:
        host = np.empty(N_MAP, np.float32)
        q.enqueue_read_buffer(buf, host)
        q.finish()
        host[:64] += 1.0                       # the actual host work
        q.enqueue_write_buffer(buf, host)
        q.finish()

    def touch_map() -> None:
        region = q.enqueue_map_buffer(buf, "rw")
        arr = region.get()
        arr[:64] += 1.0                        # same host work, in place
        q.enqueue_unmap_buffer(region)
        q.finish()

    best_copy = best_map = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for _ in range(TOUCHES):
            touch_copy()
        best_copy = min(best_copy, (time.perf_counter() - t0) / TOUCHES)
        t0 = time.perf_counter()
        for _ in range(TOUCHES):
            touch_map()
        best_map = min(best_map, (time.perf_counter() - t0) / TOUCHES)
    expect[:64] += 2.0 * REPEATS * TOUCHES     # both paths touched it
    correct = np.array_equal(buf.data, expect)
    buf.release()
    return {"buffer_mb": N_MAP * 4 / 2**20,
            "copy_per_touch_ms": best_copy * 1e3,
            "map_per_touch_ms": best_map * 1e3,
            "speedup": best_copy / best_map,
            "correct": correct}


# ---------------------------------------------------------------------------
# Gate 2: size-class pool vs first-fit on a fragmented arena
# ---------------------------------------------------------------------------

def _fragmented_arena() -> Bufalloc:
    """An arena whose front is pocked with pinned small allocations —
    the long-lived state a serving process accretes — so every first-fit
    walk scans hundreds of chunks."""
    arena = Bufalloc(1 << 26, alignment=64, greedy=False)
    pins = [arena.alloc(1024) for _ in range(2 * PIN_CHUNKS)]
    for c in pins[::2]:
        arena.free(c)                          # alternating 1 KiB holes
    return arena


def _kv_sizes() -> list:
    # cycled "KV block" sizes: larger than any pinned hole, varied enough
    # to defeat trivial reuse, identical across the two contestants
    return [(12 << 10) + 640 * (i % 7) for i in range(POOL_OPS)]


def bench_pool_vs_firstfit() -> Dict[str, float]:
    sizes = _kv_sizes()

    def churn(alloc, free) -> float:
        live = []
        t0 = time.perf_counter()
        for i, s in enumerate(sizes):
            try:
                live.append(alloc(s))
            except OutOfMemory:                # pragma: no cover - sizing
                pass
            if len(live) >= POOL_LIVE:
                free(live.pop(0))
        dt = time.perf_counter() - t0
        for c in live:
            free(c)
        return dt

    best_ff = best_pool = float("inf")
    for _ in range(REPEATS):
        arena = _fragmented_arena()
        best_ff = min(best_ff, churn(arena.alloc, arena.free))
        arena.check_invariants()

        arena = _fragmented_arena()
        pool = BufferPool(arena, min_class=4096)
        warm = [pool.alloc(s) for s in sizes[:POOL_LIVE]]
        for c in warm:
            pool.free(c)                       # classes now on free lists
        best_pool = min(best_pool, churn(pool.alloc, pool.free))
        arena.check_invariants()
    stats = pool.stats()
    return {"ops": POOL_OPS,
            "firstfit_ops_per_s": POOL_OPS / best_ff,
            "pool_ops_per_s": POOL_OPS / best_pool,
            "speedup": best_ff / best_pool,
            "pool_hit_rate": stats["hits"] / max(1, stats["hits"]
                                                 + stats["misses"])}


# ---------------------------------------------------------------------------
# Gate 3: event-ordered migration stays bitwise-identical (and partial)
# ---------------------------------------------------------------------------

def bench_migration(plat: Platform) -> Dict[str, object]:
    ctx = Context(platform=plat)
    dev = plat.get_devices("vector")[0]
    kern = ctx.create_program(build_heavy).create_kernel()
    host = np.arange(N_CO, dtype=np.float32) / N_CO
    zeros = np.zeros(N_CO, np.float32)
    kern.set_args(x=host, y=zeros)
    single = ctx.launch(kern, (N_CO,), (LSZ,), device=dev)

    co = ctx.create_co_executor(plat.co_devices(2), chunks_per_device=3)
    xs = co.shared_buffer(host, "x")
    ys = co.shared_buffer(zeros, "y")
    kshared = kern.clone().set_args(x=xs, y=ys)
    merged = co.launch(kshared, (N_CO,), (LSZ,), mode="static")
    first = co.last_stats
    merged = co.launch(kshared, (N_CO,), (LSZ,), mode="static")
    second = co.last_stats
    identical = merged["y"].tobytes() == np.asarray(single["y"]).tobytes()
    co.finish()
    # what whole-buffer invalidation (the pre-fix behaviour) would move
    # on the repeat run: the written buffer y, full size, on each device
    whole_invalidate_bytes = 2 * N_CO * 4
    return {
        "bitwise_identical": identical,
        "first_run": {"migrations": first.migrations,
                      "bytes_migrated": first.bytes_migrated,
                      "transfer_commands": len(first.transfer_events),
                      "overlap_ms": first.migration_overlap_s() * 1e3},
        "second_run": {"migrations": second.migrations,
                       "partial_migrations": second.partial_migrations,
                       "bytes_migrated": second.bytes_migrated,
                       "whole_invalidate_bytes": whole_invalidate_bytes,
                       "overlap_ms": second.migration_overlap_s() * 1e3},
        "partial_ok": second.partial_migrations > 0
        and second.bytes_migrated < whole_invalidate_bytes,
    }


def run() -> Dict[str, object]:
    plat = Platform()
    return {"map_vs_copy": bench_map_vs_copy(plat),
            "pool_vs_firstfit": bench_pool_vs_firstfit(),
            "migration": bench_migration(plat)}


def main(trajectory: bool = True):
    res = run()
    mv = res["map_vs_copy"]
    print(f"map_vs_copy : {mv['buffer_mb']:.0f}MiB buffer  "
          f"copy {mv['copy_per_touch_ms']:7.2f}ms/touch  "
          f"map {mv['map_per_touch_ms']:7.2f}ms/touch  "
          f"speedup {mv['speedup']:.1f}x  correct={mv['correct']}")
    pf = res["pool_vs_firstfit"]
    print(f"pool        : first-fit {pf['firstfit_ops_per_s']:9.0f} ops/s  "
          f"pool {pf['pool_ops_per_s']:9.0f} ops/s  "
          f"speedup {pf['speedup']:.1f}x  "
          f"hit-rate {pf['pool_hit_rate']:.2f}")
    mg = res["migration"]
    print(f"migration   : bitwise_identical={mg['bitwise_identical']}  "
          f"run1 {mg['first_run']['bytes_migrated']}B "
          f"({mg['first_run']['transfer_commands']} transfers, "
          f"overlap {mg['first_run']['overlap_ms']:.2f}ms)  "
          f"run2 {mg['second_run']['bytes_migrated']}B vs "
          f"{mg['second_run']['whole_invalidate_bytes']}B whole-buffer "
          f"({mg['second_run']['partial_migrations']} partial)")

    ok = (mv["speedup"] >= 5.0 and mv["correct"]
          and pf["speedup"] >= 2.0
          and mg["bitwise_identical"] and mg["partial_ok"])
    status = "OK" if ok else "BELOW TARGET"
    print(f"\nmemory gates (map>=5x, pool>=2x, bitwise + partial "
          f"re-migration): {status}")
    if trajectory:
        _append_trajectory(res)
    res["_gate_ok"] = ok
    return res


def _append_trajectory(res) -> None:
    """Append this run to BENCH_MEMORY.json (one record per run, so the
    map/pool/migration ratios are tracked across PRs)."""
    path = os.path.join(os.path.dirname(__file__), "BENCH_MEMORY.json")
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
        except Exception:
            hist = []
    hist.append({"timestamp": time.time(), "results": res})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, default=float)
    print(f"trajectory -> {path} ({len(hist)} runs)")


if __name__ == "__main__":
    import sys
    sys.exit(0 if main().get("_gate_ok") else 1)
