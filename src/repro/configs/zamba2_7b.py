"""zamba2-7b — Mamba2 backbone + shared attention block [arXiv:2411.15242].

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
One SHARED attention+FFN block (parameters reused) is applied after every
6th mamba block — 13 applications over 81 layers, each with its own KV
cache instance.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, head_dim=112,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=64,
    attn_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=8, attn_every=2,
)
