"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, gated
cross-attention to image tokens every 5th layer.  The vision tower is a
STUB: input_specs() provides precomputed patch embeddings
(B, n_img_tokens=1600, d_model).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=128256,
    cross_attn_every=5, n_img_tokens=1600,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama-vision-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, cross_attn_every=2, n_img_tokens=16,
)
