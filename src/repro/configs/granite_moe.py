"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40 experts top-8.
40 experts do not divide the 16-way model axis, so the dry-run falls back
to per-expert tensor parallelism (see sharding.adapt_rules_for).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv=8, head_dim=64,
    d_ff=512, vocab=49155, n_experts=40, top_k=8,
)

SMOKE = dataclasses.replace(
    CONFIG, name="granite-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=32, vocab=512, n_experts=5, top_k=2, moe_group=16,
)
