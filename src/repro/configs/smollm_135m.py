"""smollm-135m — small llama-arch [hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.  9 heads / 3 KV heads
do not divide the 16-way model axis -> heads replicate, FFN/vocab still
shard (see sharding.adapt_rules_for).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, head_dim=64,
    d_ff=1536, vocab=49152, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="smollm-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
)
