"""granite-34b — llama-arch code model, MQA [arXiv:2405.04324; hf].

88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv=1, head_dim=128,
    d_ff=24576, vocab=49152,
)

SMOKE = dataclasses.replace(
    CONFIG, name="granite34-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=1, head_dim=16,
    d_ff=128, vocab=512,
)
