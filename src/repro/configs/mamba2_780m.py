"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L d_model=1536, attention-free, vocab=50280, ssm_state=128.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv=1, d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=64,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke",
    n_layers=2, d_model=64, vocab=512,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
)
