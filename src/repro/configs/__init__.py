"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the exact published config; ``get_smoke(arch)``
returns a reduced same-family config for CPU smoke tests (the FULL configs
are only ever lowered abstractly via the dry-run).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, ShapeConfig, ALL_SHAPES, \
    shapes_for

_ARCHS = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "granite-moe-3b-a800m": "granite_moe",
    "mamba2-780m": "mamba2_780m",
    "whisper-small": "whisper_small",
    "internlm2-20b": "internlm2_20b",
    "granite-34b": "granite_34b",
    "smollm-135m": "smollm_135m",
    "starcoder2-7b": "starcoder2_7b",
    "llama-3.2-vision-11b": "llama32_vision",
    "zamba2-7b": "zamba2_7b",
}

ARCH_IDS: List[str] = list(_ARCHS)


def _module(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch]}")


def get_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    cfg.validate()
    return cfg


def get_smoke(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).SMOKE
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    cfg.validate()
    return cfg


def all_cells():
    """Every (arch, shape) dry-run cell — 40 total per the assignment."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            out.append((arch, shape))
    return out
