"""whisper-small — enc-dec, conv frontend stubbed [arXiv:2212.04356].

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865.  The audio conv
frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, enc_seq=1500, d_model).  LayerNorm + (non-gated) GELU, learned
positional embeddings (no RoPE).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, head_dim=64,
    d_ff=3072, vocab=51865,
    enc_layers=12, enc_seq=1500, act="gelu", norm="layernorm",
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512, enc_layers=2, enc_seq=32,
)
