"""starcoder2-7b — dense GQA + RoPE [arXiv:2402.19173; hf].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv=4, head_dim=128,
    d_ff=18432, vocab=49152,
)

SMOKE = dataclasses.replace(
    CONFIG, name="starcoder2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
)
