from .sharding import (ShardingRules, BASELINE_RULES, DECODE_RULES,
                       logical_to_sharding, constrain, adapt_rules_for,
                       divisible)
