"""Hierarchical cross-pod gradient reduction.

On a multi-pod mesh the data-parallel axis factors as (pod, data).  The
naive all-reduce moves every gradient byte across the (slow, few-link)
pod interconnect once per participant.  The hierarchical schedule
  1. reduce-scatter inside each pod      (fast ICI, 1/data of the bytes)
  2. all-reduce the scattered shards across pods (DCN, bytes/data)
  3. all-gather inside each pod          (fast ICI)
moves only 1/data of the gradient bytes over the pod axis.  Expressed as
a shard_map wrapper so it composes with the pjit step; XLA can find this
schedule itself in common cases, but pinning it makes the cross-pod
traffic explicit and predictable at 1000+ node scale.
"""

from __future__ import annotations


import jax
from jax.sharding import Mesh, PartitionSpec as P


def hierarchical_psum(tree, mesh: Mesh):
    """psum over ('pod','data') done hierarchically; call inside
    shard_map.  Falls back to a flat psum when there is no pod axis."""
    if "pod" not in mesh.axis_names:
        return jax.tree.map(lambda g: jax.lax.psum(g, "data"), tree)

    def one(g):
        # 1. reduce_scatter in-pod over 'data'
        scat = jax.lax.psum_scatter(g, "data", scatter_dimension=0,
                                    tiled=True)
        # 2. all-reduce across pods (small shards)
        scat = jax.lax.psum(scat, "pod")
        # 3. all-gather in-pod
        return jax.lax.all_gather(scat, "data", axis=0, tiled=True)

    return jax.tree.map(one, tree)


def hierarchical_grad_reduce(grad_fn, mesh: Mesh, batch_spec):
    """Wrap a per-shard grad function so its output grads are reduced
    hierarchically.  grad_fn(params, batch) -> grads (unreduced, local).
    Params replicated; batch sharded by batch_spec along ('pod','data')."""
    from jax.experimental.shard_map import shard_map

    axes = [a for a in ("pod", "data") if a in mesh.axis_names]

    def inner(params, batch):
        grads = grad_fn(params, batch)
        return hierarchical_psum(grads, mesh)

    return shard_map(inner, mesh=mesh,
                     in_specs=(P(), batch_spec),
                     out_specs=P(),
                     check_rep=False)
