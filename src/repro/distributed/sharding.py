"""Logical-axis sharding rules for the (pod, data, model) production mesh.

This is the framework analogue of pocl's split between *target-independent
parallel region formation* and *target-specific mapping*: the model stack
annotates every tensor with **logical axis names** (batch/seq/heads/mlp/...)
and this module owns the single table that maps logical names onto physical
mesh axes.  Changing the parallel mapping (the §Perf hillclimb) edits the
rule table only — the model definition is untouched, exactly like retargeting
a pocl work-group function from SIMD lanes to VLIW slots.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-name -> physical mesh axis (or tuple of axes) mapping."""

    batch: Axis = ("pod", "data")       # global batch dimension
    seq: Axis = None                    # sequence (attention/mixer-internal)
    act_seq: Axis = "model"             # residual-stream sequence dim:
    # sharding the saved per-layer residuals over the model axis is
    # Megatron-style sequence parallelism — without it the remat-scan
    # carries alone exceed HBM at 4k x 256-batch scale.
    heads: Axis = "model"               # attention query heads
    kv_heads: Axis = None               # GQA KV heads (often < model size)
    head_dim: Axis = None
    d_model: Axis = None                # residual stream (activations)
    embed_fsdp: Axis = "data"           # the d_model dim OF PARAMS: FSDP /
    # ZeRO-style sharding over the data axis; XLA all-gathers weights just
    # before use and reduce-scatters grads.  Off for serving (latency).
    mlp: Axis = "model"                 # FFN hidden
    vocab: Axis = "model"               # embedding / logits vocab dim
    experts: Axis = "model"             # MoE expert dimension (EP)
    expert_mlp: Axis = None             # MoE per-expert FFN hidden (TP)
    moe_capacity: Axis = None           # dispatch capacity dim (token-
    # parallel MoE: shard C over model, replicate experts — no sharded
    # contraction in the expert-FFN backward)
    cache_seq: Axis = None              # KV-cache sequence dim (decode)
    ssm_heads: Axis = "model"           # Mamba2 SSD heads
    ssm_state: Axis = None
    conv_dim: Axis = "model"            # Mamba conv channels

    def spec(self, *logical: Optional[str]) -> P:
        """PartitionSpec for a tensor whose dims carry these logical names."""
        out = []
        for name in logical:
            if name is None:
                out.append(None)
            else:
                out.append(getattr(self, name))
        return P(*out)

    def replace(self, **kw) -> "ShardingRules":
        return dataclasses.replace(self, **kw)


# Paper-faithful baseline: plain 2D data x tensor parallelism, experts on the
# model axis when divisible.  Beyond-paper variants are built from this via
# ``replace`` (see launch/dryrun.py --opt).
BASELINE_RULES = ShardingRules()

# Prefill: weights stay fully materialized per model-rank (no FSDP
# regather per layer) — serving batches are small and latency-bound.
PREFILL_RULES = BASELINE_RULES.replace(embed_fsdp=None)

# Decode: KV caches shard along the cache sequence dimension so 32k-token
# caches fit in HBM; under pjit the softmax over the sharded S decomposes
# into partial max/sum + small all-reduces = flash-decoding.  Params keep
# their tensor-parallel sharding (heads on "model").  S=1 steps cannot
# shard the token dim, so act_seq is off.
DECODE_RULES = BASELINE_RULES.replace(cache_seq="model", act_seq=None,
                                      embed_fsdp=None)

# Long-context single-sequence decode (batch=1): no data parallelism is
# possible, so the cache sequence shards over BOTH mesh axes.
LONG_DECODE_RULES = BASELINE_RULES.replace(
    batch=None, cache_seq=("data", "model"), act_seq=None, embed_fsdp=None)


def logical_to_sharding(mesh: Mesh, rules: ShardingRules,
                        logical: Sequence[Optional[str]]) -> NamedSharding:
    spec = rules.spec(*logical)
    # drop mesh axes that do not exist (e.g. "pod" on the single-pod mesh)
    cleaned = []
    for entry in spec:
        if entry is None:
            cleaned.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in mesh.axis_names)
            cleaned.append(kept if kept else None)
        else:
            cleaned.append(entry if entry in mesh.axis_names else None)
    return NamedSharding(mesh, P(*cleaned))


def constrain(x, rules: ShardingRules, *logical: Optional[str]):
    """with_sharding_constraint by logical names; no-op outside jit/mesh."""
    spec = rules.spec(*logical)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def prune_to_mesh(rules: ShardingRules, mesh: Mesh) -> ShardingRules:
    """Drop mesh axes the target mesh does not have (e.g. 'pod' on the
    single-pod mesh) from every rule entry."""
    kw = {}
    for f in dataclasses.fields(rules):
        v = getattr(rules, f.name)
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in mesh.axis_names)
            kw[f.name] = kept if kept else None
        elif isinstance(v, str):
            kw[f.name] = v if v in mesh.axis_names else None
        else:
            kw[f.name] = v
    return ShardingRules(**kw)


def divisible(n: int, mesh: Mesh, axis: Axis) -> bool:
    """Whether dim of size n divides evenly over the mesh axes in ``axis``."""
    if axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else axis
    total = 1
    for a in axes:
        if a in mesh.axis_names:
            total *= mesh.shape[a]
    return n % total == 0


def adapt_rules_for(rules: ShardingRules, mesh: Mesh, *,
                    n_kv: int = 0, n_experts: int = 0,
                    n_heads: int = 0, d_ff: int = 0,
                    vocab: int = 0) -> ShardingRules:
    """Fix up rules whose dims don't divide the mesh (pocl's 'local size not
    a multiple of the vector width' fallback, applied to mesh axes).

    - KV heads that don't divide the model axis are replicated (GQA).
    - An expert count that doesn't divide the model axis falls back to
      TOKEN-PARALLEL MoE (capacity dim on the model axis) — measured 2.3x
      better than per-expert tensor parallelism on granite-moe train_4k
      (EXPERIMENTS.md §Perf H2); the TP fallback remains available as the
      'moe_tp_fallback' variant.
    """
    out = rules
    if n_kv and not divisible(n_kv, mesh, rules.kv_heads):
        out = out.replace(kv_heads=None)
    if n_heads and not divisible(n_heads, mesh, rules.heads):
        out = out.replace(heads=None)
    if n_experts and not divisible(n_experts, mesh, rules.experts):
        out = out.replace(experts=None, expert_mlp=None,
                          moe_capacity="model")
    if vocab and not divisible(vocab, mesh, rules.vocab):
        out = out.replace(vocab=None)
    return out
