"""The DSL linear-algebra/irregular kernel suite (docs/scoreboard.md).

Six kernels spanning the workload classes of the paper's evaluation
(§4, Figs. 12-14) and the Rupp-et-al. linear-algebra portability study:

========== ==================================================== ==========
name       pattern                                              tuning axes
========== ==================================================== ==========
gemm       tiled matmul, local-memory A/B tiles + barriers      ts, unroll
spmv       CSR sparse matvec, predicated ragged-row loop        lsz, unroll
stencil1d  3-point stencil, optional local-memory staging       lsz, use_local
stencil2d  5-point stencil, 2-D NDRange                         tx, ty
scan       work-group Hillis-Steele inclusive prefix sum        unroll
hist       privatized histogram + tree reduction (no atomics)   lsz, ipt
========== ==================================================== ==========

Every kernel is authored once in the :class:`~repro.core.KernelBuilder`
DSL and runs unchanged on the loop / vector / pallas targets and under
multi-device co-execution — tuning parameters are *build-time* constants
(tile sizes shape local arrays, unroll factors shape the CFG), so each
swept configuration is a distinct program.

Ragged-edge convention: the runtime requires ``global_size`` to be a
multiple of ``local_size`` (pocl's uniform work-group model), so
:func:`SuiteKernel.launch_dims` pads the global size up and the kernels
guard stores with ``if gid < n`` and clamp *every* potentially
out-of-range load index — ``select`` evaluates both arms, and a clamped
load is safe on all targets (the fiber interpreter would raise on a raw
out-of-bounds index; vector/pallas would silently wrap or clamp).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import KernelBuilder

from typing import Callable, Dict, Mapping, Tuple

from . import oracles
from .oracles import ceil_to

Shape = Mapping[str, int]
Params = Mapping[str, int]


def param_key(params: Params) -> str:
    """Canonical string for one tuning-space point: ``"ts=8,unroll=8"``.
    Sorted so the same dict always names the same sweep column."""
    return ",".join(f"{k}={params[k]}" for k in sorted(params))


@dataclasses.dataclass(frozen=True)
class SuiteKernel:
    """One suite entry: DSL builder + tuning space + bitwise oracle.

    ``build(shape, params)`` returns a zero-arg IR builder suitable for
    ``Context.create_program``; ``make_inputs``/``oracle`` share the
    conventions documented in :mod:`repro.suite.oracles`; ``flops`` /
    ``bytes_moved`` are the *analytic useful* work of one launch (the
    roofline numerator — independent of tuning parameters, which only
    change how the same work is scheduled)."""
    name: str
    description: str
    shapes: Mapping[str, Shape]          # "full" and "ci" problem sizes
    space: Callable[[Shape], Tuple[Params, ...]]
    build: Callable[[Shape, Params], Callable]
    make_inputs: Callable[[Shape, Params], Dict[str, np.ndarray]]
    oracle: Callable[[Dict[str, np.ndarray], Shape, Params],
                     Dict[str, np.ndarray]]
    launch_dims: Callable[[Shape, Params],
                          Tuple[Tuple[int, ...], Tuple[int, ...]]]
    flops: Callable[[Shape], float]
    bytes_moved: Callable[[Shape], float]
    outputs: Tuple[str, ...]


# -- tiled GEMM ---------------------------------------------------------------

def _build_gemm(shape: Shape, params: Params):
    m, n, k = shape["m"], shape["n"], shape["k"]
    ts, unroll = params["ts"], params["unroll"]

    def build():
        b = KernelBuilder(f"suite_gemm_ts{ts}_u{unroll}", ndim=2)
        a_ = b.arg_buffer("A", "float32")
        b_ = b.arg_buffer("B", "float32")
        c_ = b.arg_buffer("C", "float32")
        asub = b.local_tile("As", "float32", (ts, ts))
        bsub = b.local_tile("Bs", "float32", (ts, ts))
        av, bv, cv = b.strided(a_, k), b.strided(b_, n), b.strided(c_, n)
        col, row = b.global_id(0), b.global_id(1)
        lx, ly = b.local_id(0), b.local_id(1)
        acc = b.var(0.0, name="acc")
        with b.for_range(0, ceil_to(k, ts) // ts) as t:
            ka = t * ts + lx
            asub[ly, lx] = b.select(
                (row < m) & (ka < k),
                av[b.minimum(row, m - 1), b.minimum(ka, k - 1)], 0.0)
            kb = t * ts + ly
            bsub[ly, lx] = b.select(
                (kb < k) & (col < n),
                bv[b.minimum(kb, k - 1), b.minimum(col, n - 1)], 0.0)
            b.barrier()
            for kk in b.range_unrolled(ts, unroll):
                acc.set(acc.get() + asub[ly, kk] * bsub[kk, lx])
            b.barrier()
        with b.if_((row < m) & (col < n)):
            cv[b.minimum(row, m - 1), b.minimum(col, n - 1)] = acc.get()
        return b.finish()
    return build


def _gemm_space(shape: Shape):
    return tuple({"ts": ts, "unroll": u}
                 for ts in (4, 8) for u in (1, ts))


def _gemm_dims(shape: Shape, params: Params):
    ts = params["ts"]
    return ((ceil_to(shape["n"], ts), ceil_to(shape["m"], ts)), (ts, ts))


# -- SpMV over CSR ------------------------------------------------------------

def _spmv_nnz(shape: Shape) -> int:
    return int(((np.arange(shape["m"]) % shape["max_nnz"]) + 1).sum())


def _build_spmv(shape: Shape, params: Params):
    m, n, max_nnz = shape["m"], shape["n"], shape["max_nnz"]
    unroll = params["unroll"]
    nnz_total = _spmv_nnz(shape)

    def build():
        b = KernelBuilder(f"suite_spmv_l{params['lsz']}_u{unroll}")
        rowptr = b.arg_buffer("rowptr", "int32")
        cols = b.arg_buffer("cols", "int32")
        vals = b.arg_buffer("vals", "float32")
        x = b.arg_buffer("x", "float32")
        y = b.arg_buffer("y", "float32")
        r = b.global_id(0)
        rc = b.minimum(r, m - 1)
        start, end = rowptr[rc], rowptr[rc + 1]
        acc = b.var(0.0, name="acc")
        # uniform trip count over the max row length with predication:
        # ragged rows cost a select, not a divergent loop
        for j in b.range_unrolled(max_nnz, unroll):
            idx = b.minimum(start + j, nnz_total - 1)
            contrib = vals[idx] * x[b.minimum(cols[idx], n - 1)]
            acc.set(acc.get() + b.select(start + j < end, contrib, 0.0))
        with b.if_(r < m):
            y[rc] = acc.get()
        return b.finish()
    return build


def _spmv_space(shape: Shape):
    return tuple({"lsz": lsz, "unroll": u}
                 for lsz in (32, 64) for u in (1, shape["max_nnz"]))


def _spmv_dims(shape: Shape, params: Params):
    return ((ceil_to(shape["m"], params["lsz"]),), (params["lsz"],))


# -- 1-D three-point stencil --------------------------------------------------

def _build_stencil1d(shape: Shape, params: Params):
    n = shape["n"]
    lsz, use_local = params["lsz"], params["use_local"]

    def build():
        b = KernelBuilder(f"suite_stencil1d_l{lsz}_s{use_local}")
        x = b.arg_buffer("x", "float32")
        y = b.arg_buffer("y", "float32")
        gid = b.global_id(0)
        if use_local:
            tile = b.local_array("tile", "float32", lsz + 2)
            lid = b.local_id(0)
            tile[lid + 1] = x[b.minimum(gid, n - 1)]
            with b.if_(lid < 1):
                tile[0] = x[b.minimum(b.maximum(gid - 1, 0), n - 1)]
            with b.if_(lid >= lsz - 1):
                tile[lsz + 1] = x[b.minimum(gid + 1, n - 1)]
            b.barrier()
            left, center, right = tile[lid], tile[lid + 1], tile[lid + 2]
        else:
            center = x[b.minimum(gid, n - 1)]
            left = x[b.minimum(b.maximum(gid - 1, 0), n - 1)]
            right = x[b.minimum(gid + 1, n - 1)]
        with b.if_(gid < n):
            y[b.minimum(gid, n - 1)] = \
                (0.25 * left + 0.5 * center) + 0.25 * right
        return b.finish()
    return build


def _stencil1d_space(shape: Shape):
    return tuple({"lsz": lsz, "use_local": s}
                 for lsz in (32, 64) for s in (0, 1))


def _stencil1d_dims(shape: Shape, params: Params):
    return ((ceil_to(shape["n"], params["lsz"]),), (params["lsz"],))


# -- 2-D five-point stencil ---------------------------------------------------

def _build_stencil2d(shape: Shape, params: Params):
    h, w = shape["h"], shape["w"]
    tx, ty = params["tx"], params["ty"]

    def build():
        b = KernelBuilder(f"suite_stencil2d_t{tx}x{ty}", ndim=2)
        x = b.arg_buffer("x", "float32")
        y = b.arg_buffer("y", "float32")
        xv, yv = b.strided(x, w), b.strided(y, w)
        gx, gy = b.global_id(0), b.global_id(1)
        cx, cy = b.minimum(gx, w - 1), b.minimum(gy, h - 1)
        left = xv[cy, b.minimum(b.maximum(gx - 1, 0), w - 1)]
        right = xv[cy, b.minimum(gx + 1, w - 1)]
        up = xv[b.minimum(b.maximum(gy - 1, 0), h - 1), cx]
        down = xv[b.minimum(gy + 1, h - 1), cx]
        center = xv[cy, cx]
        with b.if_((gx < w) & (gy < h)):
            yv[cy, cx] = 0.5 * center + \
                0.125 * ((left + right) + (up + down))
        return b.finish()
    return build


def _stencil2d_space(shape: Shape):
    return ({"tx": 8, "ty": 8}, {"tx": 16, "ty": 4}, {"tx": 4, "ty": 16})


def _stencil2d_dims(shape: Shape, params: Params):
    tx, ty = params["tx"], params["ty"]
    return ((ceil_to(shape["w"], tx), ceil_to(shape["h"], ty)), (tx, ty))


# -- work-group inclusive prefix scan -----------------------------------------

def _build_scan(shape: Shape, params: Params):
    seg = shape["seg"]
    unroll = params["unroll"]

    def build():
        b = KernelBuilder(f"suite_scan_s{seg}_u{unroll}")
        x = b.arg_buffer("x", "float32")
        y = b.arg_buffer("y", "float32")
        # ping-pong halves of one local buffer: Hillis-Steele reads the
        # previous round while writing the next, no second barrier needed
        buf = b.local_array("buf", "float32", 2 * seg)
        lid, gid = b.local_id(0), b.global_id(0)
        buf[lid] = x[gid]
        b.barrier()
        if unroll:
            pin, pout, off = 0, 1, 1
            while off < seg:
                with b.if_(lid >= off):
                    buf[pout * seg + lid] = \
                        buf[pin * seg + lid] + buf[pin * seg + lid - off]
                with b.if_(lid < off):
                    buf[pout * seg + lid] = buf[pin * seg + lid]
                b.barrier()
                pin, pout = pout, pin
                off *= 2
            y[gid] = buf[pin * seg + lid]
        else:
            pin = b.var(b.const(0), name="pin")
            off = b.var(b.const(1), name="off")
            with b.while_loop() as loop:
                loop.cond(off.get() < seg)
                pout = 1 - pin.get()
                with b.if_(lid >= off.get()):
                    buf[pout * seg + lid] = buf[pin.get() * seg + lid] + \
                        buf[pin.get() * seg + lid - off.get()]
                with b.if_(lid < off.get()):
                    buf[pout * seg + lid] = buf[pin.get() * seg + lid]
                b.barrier()
                pin.set(pout)
                off.set(off.get() * 2)
            y[gid] = buf[pin.get() * seg + lid]
        return b.finish()
    return build


def _scan_space(shape: Shape):
    return ({"unroll": 0}, {"unroll": 1})


def _scan_dims(shape: Shape, params: Params):
    return ((shape["n"],), (shape["seg"],))


# -- histogram ----------------------------------------------------------------

def _build_hist(shape: Shape, params: Params):
    n, bins = shape["n"], shape["bins"]
    lsz, ipt = params["lsz"], params["ipt"]

    def build():
        b = KernelBuilder(f"suite_hist_l{lsz}_i{ipt}")
        x = b.arg_buffer("x", "float32")
        out = b.arg_buffer("out", "int32")
        # per-work-item privatized counts: no atomics on any target
        priv = b.local_array("priv", "int32", lsz * bins)
        lid, grp = b.local_id(0), b.group_id(0)
        for t in range(bins):
            priv[lid * bins + t] = 0
        base = grp * (lsz * ipt)
        for t in range(ipt):
            i = base + t * lsz + lid
            with b.if_(i < n):
                v = x[b.minimum(i, n - 1)]
                slot = b.maximum(
                    b.minimum((v * float(bins)).astype("int32"), bins - 1),
                    0)
                priv[lid * bins + slot] = priv[lid * bins + slot] + 1
        b.barrier()
        s = b.var(b.const(lsz // 2), name="s")
        with b.while_loop() as loop:
            loop.cond(s.get() > 0)
            with b.if_(lid < s.get()):
                for t in range(bins):
                    priv[lid * bins + t] = priv[lid * bins + t] + \
                        priv[(lid + s.get()) * bins + t]
            b.barrier()
            s.set(s.get() / 2)
        with b.if_(lid < bins):
            out[grp * bins + lid] = priv[lid]
        return b.finish()
    return build


def _hist_space(shape: Shape):
    # lsz must be a power of two (tree reduction) and >= bins (store)
    return tuple({"lsz": lsz, "ipt": ipt}
                 for lsz in (16, 32) for ipt in (1, 4))


def _hist_dims(shape: Shape, params: Params):
    return ((oracles.hist_groups(shape, params) * params["lsz"],),
            (params["lsz"],))


# -- registry -----------------------------------------------------------------

SUITE: Dict[str, SuiteKernel] = {k.name: k for k in (
    SuiteKernel(
        name="gemm",
        description="tiled dense matmul, local-memory tiles + barriers",
        shapes={"full": {"m": 45, "n": 48, "k": 40},
                "ci": {"m": 13, "n": 17, "k": 9}},
        space=_gemm_space, build=_build_gemm,
        make_inputs=oracles.gemm_inputs, oracle=oracles.gemm_oracle,
        launch_dims=_gemm_dims,
        flops=lambda s: 2.0 * s["m"] * s["n"] * s["k"],
        bytes_moved=lambda s: 4.0 * (s["m"] * s["k"] + s["k"] * s["n"]
                                     + s["m"] * s["n"]),
        outputs=("C",)),
    SuiteKernel(
        name="spmv",
        description="CSR sparse matvec, predicated ragged-row loop",
        shapes={"full": {"m": 300, "n": 256, "max_nnz": 8},
                "ci": {"m": 70, "n": 64, "max_nnz": 4}},
        space=_spmv_space, build=_build_spmv,
        make_inputs=oracles.spmv_inputs, oracle=oracles.spmv_oracle,
        launch_dims=_spmv_dims,
        flops=lambda s: 2.0 * _spmv_nnz(s),
        bytes_moved=lambda s: 4.0 * (2 * _spmv_nnz(s) + s["m"] + 1
                                     + s["n"] + s["m"]),
        outputs=("y",)),
    SuiteKernel(
        name="stencil1d",
        description="3-point stencil, optional local-memory staging",
        shapes={"full": {"n": 4000}, "ci": {"n": 150}},
        space=_stencil1d_space, build=_build_stencil1d,
        make_inputs=oracles.stencil1d_inputs,
        oracle=oracles.stencil1d_oracle,
        launch_dims=_stencil1d_dims,
        flops=lambda s: 5.0 * s["n"],
        bytes_moved=lambda s: 8.0 * s["n"],
        outputs=("y",)),
    SuiteKernel(
        name="stencil2d",
        description="5-point stencil over a 2-D NDRange",
        shapes={"full": {"h": 60, "w": 76}, "ci": {"h": 13, "w": 19}},
        space=_stencil2d_space, build=_build_stencil2d,
        make_inputs=oracles.stencil2d_inputs,
        oracle=oracles.stencil2d_oracle,
        launch_dims=_stencil2d_dims,
        flops=lambda s: 6.0 * s["h"] * s["w"],
        bytes_moved=lambda s: 8.0 * s["h"] * s["w"],
        outputs=("y",)),
    SuiteKernel(
        name="scan",
        description="work-group Hillis-Steele inclusive prefix sum",
        shapes={"full": {"n": 4096, "seg": 64},
                "ci": {"n": 256, "seg": 32}},
        space=_scan_space, build=_build_scan,
        make_inputs=oracles.scan_inputs, oracle=oracles.scan_oracle,
        launch_dims=_scan_dims,
        flops=lambda s: float(s["n"]) * max(math.log2(s["seg"]), 1.0),
        bytes_moved=lambda s: 8.0 * s["n"],
        outputs=("y",)),
    SuiteKernel(
        name="hist",
        description="privatized histogram + tree reduction, no atomics",
        shapes={"full": {"n": 5000, "bins": 16},
                "ci": {"n": 300, "bins": 8}},
        space=_hist_space, build=_build_hist,
        make_inputs=oracles.hist_inputs, oracle=oracles.hist_oracle,
        launch_dims=_hist_dims,
        flops=lambda s: 4.0 * s["n"],
        bytes_moved=lambda s: 4.0 * s["n"] + 4.0 * s["bins"],
        outputs=("out",)),
)}


def suite_kernels() -> Tuple[SuiteKernel, ...]:
    """All suite kernels in registry order."""
    return tuple(SUITE.values())
