"""Per-target autotuning sweeps + roofline-validated reporting.

The :class:`Scoreboard` runs every suite kernel through the host API
(``Context`` -> ``Program`` -> ``Kernel`` -> ``launch``) on each compiled
target, sweeps the kernel's tuning space, checks every configuration's
output *bitwise* against the NumPy oracle, persists the winning
parameters in the :class:`~repro.core.autotune.TuningTable` (``sweeps``
section — a warm run re-measures only the winner), and prices the winner
against a **measured** roofline: per-target peak FLOP/s and bandwidth are
calibrated by DSL microkernels (an ILP'd FMA chain and a streaming copy)
run through the very same compiler/runtime stack, so the reported
achieved-vs-roofline fraction compares like with like — the Rupp-et-al.
methodology, applied to the paper's three code-generation strategies
(§4.4: loop serialization, §4.5: SIMD lanes, and the Pallas path).

Extra columns beyond the fixed targets:

* ``coexec2`` — the vector winner co-executed over 2 homogeneous devices
  (:meth:`~repro.runtime.platform.Platform.co_devices`), priced against
  2x the vector peaks;
* ``auto`` — the ``repro-auto`` device, whose per-kernel target choice
  comes from the same tuning table the sweeps persist into.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import KernelBuilder
from repro.core.autotune import TuningTable, set_default_table
from repro.launch.roofline import kernel_report
from repro.runtime import Context

from typing import Dict, Optional, Sequence

from .kernels import SUITE, SuiteKernel, param_key

SCHEMA = "bench_scoreboard/v1"

# FMA-chain calibration: independent accumulator chains give the
# compiler ILP so the measured peak is a throughput, not a latency
_CAL_CHAINS = 4
_CAL_OPS = 32


def _build_cal_flops():
    b = KernelBuilder("suite_cal_flops")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    accs = [b.var(x[g] * (0.5 + 0.25 * c), name=f"acc{c}")
            for c in range(_CAL_CHAINS)]
    for _ in range(_CAL_OPS):
        for a in accs:
            a.set(a.get() * 1.0009765625 + 0.0009765625)
    total = accs[0].get()
    for a in accs[1:]:
        total = total + a.get()
    y[g] = total
    return b.finish()


def _build_cal_copy():
    b = KernelBuilder("suite_cal_copy")
    x = b.arg_buffer("x", "float32")
    y = b.arg_buffer("y", "float32")
    g = b.global_id(0)
    y[g] = x[g] + 1.0
    return b.finish()


def _time(fn, warmup: int, repeats: int) -> float:
    """Best-of-N wall time of ``fn()`` after ``warmup`` calls (first call
    pays jit compilation)."""
    for _ in range(max(warmup, 1)):
        fn()
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate(ctx: Context, target: str, n: int = 1 << 14,
              lsz: int = 64, warmup: int = 1, repeats: int = 3
              ) -> Dict[str, float]:
    """Measured per-target peaks: ``peak_flops`` (FLOP/s, FMA chains) and
    ``peak_bw`` (B/s, streaming copy), through the same Context/launch
    path the suite kernels use.  ``n`` must be a multiple of ``lsz``."""
    n = -(-n // lsz) * lsz
    x = np.linspace(0.5, 1.5, n).astype(np.float32)
    peaks: Dict[str, float] = {}
    for name, build, work in (
            ("peak_flops", _build_cal_flops,
             float(n) * (2 * _CAL_OPS * _CAL_CHAINS + _CAL_CHAINS)),
            ("peak_bw", _build_cal_copy, 8.0 * n)):
        kern = ctx.create_program(build).create_kernel()
        kern.set_args(x=x, y=np.zeros(n, np.float32))
        t = _time(lambda: ctx.launch(kern, (n,), (lsz,), target=target),
                  warmup, repeats)
        peaks[name] = work / max(t, 1e-12)
    return peaks


def _subsample(space, max_configs: Optional[int]):
    """Evenly-spaced sub-space keeping the endpoints; never fewer than 2
    configurations (the beats-worst gate needs a sweep, not a point)."""
    if max_configs is None or max_configs >= len(space) or len(space) <= 2:
        return tuple(space)
    m = max(int(max_configs), 2)
    idx = np.linspace(0, len(space) - 1, m).round().astype(int)
    return tuple(space[i] for i in sorted(set(idx.tolist())))


class Scoreboard:
    """Sweep + verify + price the suite on every compiled target.

    ``table`` persists sweep winners: pass a path-backed
    :class:`TuningTable` and a later Scoreboard over the same table
    re-measures only each cell's winning configuration (``sweep_cached``
    in the cell marks this).  ``max_configs`` trims each tuning space
    (evenly, endpoints kept) for CI-sized runs."""

    def __init__(self, ctx: Optional[Context] = None,
                 table: Optional[TuningTable] = None,
                 targets: Sequence[str] = ("loop", "vector", "pallas"),
                 shape_set: str = "full",
                 warmup: int = 1, repeats: int = 3,
                 max_configs: Optional[int] = None,
                 include_coexec: bool = True,
                 include_auto: bool = True,
                 coexec_mode: str = "static",
                 calibration_n: int = 1 << 14):
        self.ctx = ctx if ctx is not None else Context()
        self.table = table if table is not None else TuningTable()
        self.targets = tuple(targets)
        self.shape_set = shape_set
        self.warmup = int(warmup)
        self.repeats = int(repeats)
        self.max_configs = max_configs
        self.include_coexec = include_coexec
        self.include_auto = include_auto
        self.coexec_mode = coexec_mode
        self.calibration_n = int(calibration_n)
        self._co = None          # lazy: created once, devices are appended
        self.peaks: Dict[str, Dict[str, float]] = {}

    # -- internals ----------------------------------------------------------

    def _kernel_obj(self, sk: SuiteKernel, shape, params, inputs):
        prog = self.ctx.create_program(sk.build(shape, params))
        kern = prog.create_kernel()
        kern.set_args(**inputs)
        return kern

    def _bitwise(self, out, expected) -> bool:
        return all(np.asarray(out[name]).tobytes() == exp.tobytes()
                   for name, exp in expected.items())

    def _measure(self, sk: SuiteKernel, shape, params, *,
                 target: Optional[str] = None, device=None, co=None):
        """One configuration: build, launch, time, bitwise-check."""
        inputs = sk.make_inputs(shape, params)
        expected = sk.oracle(inputs, shape, params)
        kern = self._kernel_obj(sk, shape, params, inputs)
        gsz, lsz = sk.launch_dims(shape, params)
        if co is not None:
            run = lambda: co.launch(kern, gsz, lsz, mode=self.coexec_mode)
        else:
            run = lambda: self.ctx.launch(kern, gsz, lsz, device=device,
                                          target=target)
        t = _time(run, self.warmup, self.repeats)
        ok = self._bitwise(run(), expected)
        return t, ok, kern

    def _roofline(self, sk: SuiteKernel, shape, target: str, time_s: float,
                  peaks: Dict[str, float]):
        return kernel_report(
            kernel=sk.name, target=target,
            flops=sk.flops(shape), bytes_moved=sk.bytes_moved(shape),
            time_s=max(time_s, 1e-12),
            peak_flops=peaks["peak_flops"],
            peak_bw=peaks["peak_bw"]).to_dict()

    def _sweep_cell(self, sk: SuiteKernel, shape, space, target: str):
        """Full sweep (or warm re-measure of the persisted winner) for
        one (kernel, target) cell."""
        key = TuningTable.make_sweep_key(sk.name, target, param_key(shape))
        space_keys = {param_key(p): p for p in space}
        cached = self.table.get_sweep(key)
        use_cache = (cached is not None
                     and param_key(cached["params"]) in space_keys
                     and set(cached["timings_us"]) == set(space_keys))
        if use_cache:
            params = space_keys[param_key(cached["params"])]
            timings = dict(cached["timings_us"])
            t, ok, _ = self._measure(sk, shape, params, target=target)
            bitwise = ok
        else:
            timings, results = {}, {}
            bitwise = True
            for params in space:
                t, ok, _ = self._measure(sk, shape, params, target=target)
                timings[param_key(params)] = t * 1e6
                results[param_key(params)] = (t, params)
                bitwise = bitwise and ok
            best_key = min(timings, key=timings.get)
            t, params = results[best_key]
            self.table.record_sweep(key, params, timings)
        worst_us = max(timings.values())
        best_us = min(timings.values())
        cell = {
            "target": target,
            "params": dict(params),
            "config": param_key(params),
            "time_us": t * 1e6,
            "timings_us": timings,
            "best_us": best_us,
            "worst_us": worst_us,
            "speedup_vs_worst": worst_us / max(best_us, 1e-9),
            "bitwise": bool(bitwise),
            "sweep_cached": bool(use_cache),
            "roofline": self._roofline(sk, shape, target, t,
                                       self.peaks[target]),
        }
        return cell

    def _coexec_cell(self, sk: SuiteKernel, shape, vector_cell):
        if self._co is None:
            devs = self.ctx.platform.co_devices(2)
            self._co = self.ctx.create_co_executor(devs)
        params = vector_cell["params"]
        t, ok, _ = self._measure(sk, shape, params, co=self._co)
        base = self.peaks.get("vector") or next(iter(self.peaks.values()))
        peaks2 = {k: 2.0 * v for k, v in base.items()}
        return {
            "target": "coexec2",
            "params": dict(params),
            "config": param_key(params),
            "time_us": t * 1e6,
            "bitwise": bool(ok),
            "speedup_vs_vector": vector_cell["time_us"] / max(t * 1e6,
                                                              1e-9),
            "roofline": self._roofline(sk, shape, "coexec2", t, peaks2),
        }

    def _auto_cell(self, sk: SuiteKernel, shape, space):
        autos = self.ctx.platform.get_devices("auto")
        if not autos:
            return None
        params = space[0]
        set_default_table(self.table)
        try:
            t, ok, kern = self._measure(sk, shape, params,
                                        device=autos[0])
        finally:
            set_default_table(None)
        chosen = None
        try:    # diagnostic only: scan the table for this kernel's winner
            for k, ent in getattr(self.table, "_winners", {}).items():
                if k.startswith(kern.ir_hash):
                    chosen = ent.get("target")
                    break
        except Exception:
            chosen = None
        base = self.peaks.get("vector") or next(iter(self.peaks.values()))
        return {
            "target": "auto",
            "params": dict(params),
            "config": param_key(params),
            "time_us": t * 1e6,
            "bitwise": bool(ok),
            "chosen_target": chosen,
            "roofline": self._roofline(sk, shape, "auto", t, base),
        }

    # -- entry point --------------------------------------------------------

    def run(self, kernels: Optional[Sequence[str]] = None) -> Dict:
        names = list(kernels) if kernels else list(SUITE)
        for tgt in self.targets:
            self.peaks[tgt] = calibrate(
                self.ctx, tgt, n=self.calibration_n,
                warmup=self.warmup, repeats=self.repeats)
        report = {
            "schema": SCHEMA,
            "shape_set": self.shape_set,
            "repeats": self.repeats,
            "targets": list(self.targets),
            "peaks": {t: {"peak_flops": p["peak_flops"],
                          "peak_bw": p["peak_bw"],
                          "gflops": p["peak_flops"] / 1e9,
                          "gbs": p["peak_bw"] / 1e9}
                      for t, p in self.peaks.items()},
            "kernels": {},
        }
        for name in names:
            sk = SUITE[name]
            shape = sk.shapes.get(self.shape_set, sk.shapes["full"])
            space = _subsample(sk.space(shape), self.max_configs)
            cells = {}
            for tgt in self.targets:
                cells[tgt] = self._sweep_cell(sk, shape, space, tgt)
            if self.include_coexec and "vector" in cells:
                cells["coexec2"] = self._coexec_cell(sk, shape,
                                                     cells["vector"])
            if self.include_auto:
                auto = self._auto_cell(sk, shape, space)
                if auto is not None:
                    cells["auto"] = auto
            report["kernels"][name] = {
                "shape": dict(shape),
                "space_size": len(space),
                "flops": sk.flops(shape),
                "bytes": sk.bytes_moved(shape),
                "cells": cells,
            }
        report["gates"] = check_gates(report)
        return report


def check_gates(report: Dict, min_fraction: float = 0.0,
                fraction_target: str = "vector") -> Dict:
    """The scoreboard's pass/fail verdicts.

    * ``bitwise`` — every cell's winner reproduced the NumPy oracle
      bitwise (conformance; always enforced);
    * ``winner_beats_worst`` — in every swept cell the autotuned
      configuration's time is the minimum of its sweep, strictly below
      the worst when the sweep measured more than one configuration;
    * ``min_fraction`` — every kernel's achieved-vs-roofline fraction on
      ``fraction_target`` reaches ``min_fraction`` (0 disables).
    """
    bitwise_bad, beats_bad, frac_bad = [], [], []
    for name, ent in report.get("kernels", {}).items():
        for tgt, cell in ent["cells"].items():
            if not cell.get("bitwise", False):
                bitwise_bad.append(f"{name}/{tgt}")
            timings = cell.get("timings_us")
            if timings:
                best = min(timings.values())
                worst = max(timings.values())
                if cell["best_us"] != best or \
                        (len(timings) > 1 and not best <= worst):
                    beats_bad.append(f"{name}/{tgt}")
        cell = ent["cells"].get(fraction_target)
        if min_fraction > 0 and cell is not None:
            frac = cell["roofline"]["fraction"]
            if not frac >= min_fraction:
                frac_bad.append(f"{name}: {frac:.4f} < {min_fraction}")
    return {
        "bitwise": not bitwise_bad,
        "bitwise_failures": bitwise_bad,
        "winner_beats_worst": not beats_bad,
        "winner_failures": beats_bad,
        "min_fraction": min_fraction,
        "fraction_target": fraction_target,
        "fraction_ok": not frac_bad,
        "fraction_failures": frac_bad,
        "ok": not (bitwise_bad or beats_bad or frac_bad),
    }


def render_markdown(report: Dict) -> str:
    """The (kernel x target) matrix as a GitHub-flavored markdown table:
    one row per kernel, one column per target, each cell showing the
    achieved-vs-roofline fraction, the winning time and configuration."""
    targets = list(report.get("targets", []))
    extras = []
    for ent in report.get("kernels", {}).values():
        for tgt in ent["cells"]:
            if tgt not in targets and tgt not in extras:
                extras.append(tgt)
    cols = targets + extras
    lines = [
        "# Performance-portability scoreboard",
        "",
        f"Shape set `{report.get('shape_set')}`; cells show "
        "achieved-vs-roofline fraction, winner time, winning config "
        "(docs/scoreboard.md).",
        "",
        "Calibrated peaks: " + "; ".join(
            f"{t} {p['gflops']:.2f} GFLOP/s / {p['gbs']:.2f} GB/s"
            for t, p in report.get("peaks", {}).items()),
        "",
        "| kernel | " + " | ".join(cols) + " |",
        "|---" * (len(cols) + 1) + "|",
    ]
    for name, ent in report.get("kernels", {}).items():
        row = [name]
        for tgt in cols:
            cell = ent["cells"].get(tgt)
            if cell is None:
                row.append("—")
                continue
            frac = cell["roofline"]["fraction"]
            mark = "" if cell.get("bitwise") else " ✗oracle"
            row.append(f"{frac:.3f} · {cell['time_us']:.0f}µs · "
                       f"`{cell['config']}`{mark}")
        lines.append("| " + " | ".join(row) + " |")
    gates = report.get("gates", {})
    lines += ["", f"Gates: bitwise={gates.get('bitwise')} "
                  f"winner_beats_worst={gates.get('winner_beats_worst')} "
                  f"fraction_ok={gates.get('fraction_ok')}"]
    return "\n".join(lines) + "\n"
