"""Input generators and NumPy oracles for the suite kernels.

Every oracle is *bitwise* — the scoreboard and the conformance tests
compare ``tobytes()``, not allclose.  Two conventions make that
well-defined across the loop/vector/pallas targets:

* **FMA-safe data.**  OpenCL (and XLA) may contract ``a*b + c`` into a
  fused multiply-add, which rounds once where NumPy's mul-then-add
  rounds twice.  Rather than forbid the contraction (and measure a
  de-optimized kernel), every multiply-accumulate kernel gets small
  *integer-valued* float32 inputs and dyadic stencil weights, so every
  intermediate is exactly representable and FMA vs mul+add cannot
  differ.  Add-only kernels (scan) keep real-valued data — addition
  order is fixed by the algorithm and reproduced by the oracle.
* **Matched association.**  Each oracle reproduces the kernel's exact
  accumulation order (padded-K tile loop for GEMM, ascending-slot
  predicated loop for SpMV, doubling steps for the scan), not the
  mathematically-equal NumPy one-liner.

Inputs are deterministic per (kernel, shape, params): generators seed
from a stable hash so every sweep configuration of one kernel sees the
same operand values (outputs whose *shape* depends on params, e.g. the
histogram's per-group partials, still differ where they must).
"""

from __future__ import annotations

import zlib

import numpy as np

from typing import Dict, Mapping


def ceil_to(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return -(-int(x) // int(m)) * int(m)


def _rng(name: str, shape: Mapping[str, int]) -> np.random.Generator:
    desc = name + "|" + ",".join(f"{k}={v}" for k, v in sorted(shape.items()))
    return np.random.default_rng(zlib.crc32(desc.encode()))


def _int_f32(rng: np.random.Generator, n: int, lo: int = -4,
             hi: int = 5) -> np.ndarray:
    """Small integer-valued float32 data: exact under FMA contraction."""
    return rng.integers(lo, hi, size=n).astype(np.float32)


# -- tiled GEMM ---------------------------------------------------------------

def gemm_inputs(shape, params) -> Dict[str, np.ndarray]:
    m, n, k = shape["m"], shape["n"], shape["k"]
    rng = _rng("gemm", shape)
    return {"A": _int_f32(rng, m * k), "B": _int_f32(rng, k * n),
            "C": np.zeros(m * n, np.float32)}


def gemm_oracle(inputs, shape, params) -> Dict[str, np.ndarray]:
    """Padded-K accumulation in ascending-k order — the tile loop's exact
    association (each tile contributes its k slots in order; zero-padding
    the ragged last tile adds exact zeros, as the kernel's guarded loads
    do)."""
    m, n, k = shape["m"], shape["n"], shape["k"]
    ts = params["ts"]
    kp = ceil_to(k, ts)
    ap = np.zeros((m, kp), np.float32)
    ap[:, :k] = inputs["A"].reshape(m, k)
    bp = np.zeros((kp, n), np.float32)
    bp[:k, :] = inputs["B"].reshape(k, n)
    acc = np.zeros((m, n), np.float32)
    for kk in range(kp):
        acc = acc + ap[:, kk:kk + 1] * bp[kk:kk + 1, :]
    return {"C": acc.reshape(-1)}


# -- SpMV over CSR ------------------------------------------------------------

def spmv_structure(shape):
    """Deterministic CSR structure: row ``r`` holds ``(r % max_nnz) + 1``
    entries at columns ``(r*3 + j*7) % n`` — ragged rows (every nnz count
    from 1 to max_nnz occurs) without a data-dependent build step."""
    m, n, max_nnz = shape["m"], shape["n"], shape["max_nnz"]
    counts = (np.arange(m) % max_nnz) + 1
    rowptr = np.zeros(m + 1, np.int32)
    rowptr[1:] = np.cumsum(counts)
    cols = np.concatenate(
        [(r * 3 + np.arange(c) * 7) % n for r, c in enumerate(counts)]
    ).astype(np.int32) if m else np.zeros(0, np.int32)
    return rowptr, cols


def spmv_inputs(shape, params) -> Dict[str, np.ndarray]:
    rowptr, cols = spmv_structure(shape)
    rng = _rng("spmv", shape)
    return {"rowptr": rowptr, "cols": cols,
            "vals": _int_f32(rng, len(cols)),
            "x": _int_f32(rng, shape["n"]),
            "y": np.zeros(shape["m"], np.float32)}


def spmv_oracle(inputs, shape, params) -> Dict[str, np.ndarray]:
    """Ascending-slot accumulation with the kernel's clamped-index
    predication: slot j of every row in order, rows vectorized."""
    m, max_nnz = shape["m"], shape["max_nnz"]
    rowptr, cols, vals, x = (inputs["rowptr"], inputs["cols"],
                             inputs["vals"], inputs["x"])
    nnz = np.diff(rowptr)
    last = max(len(vals) - 1, 0)
    acc = np.zeros(m, np.float32)
    for j in range(max_nnz):
        idx = np.minimum(rowptr[:-1] + j, last)
        contrib = vals[idx] * x[cols[idx]]
        acc = np.where(j < nnz, acc + contrib, acc).astype(np.float32)
    return {"y": acc}


# -- 1-D three-point stencil --------------------------------------------------

def stencil1d_inputs(shape, params) -> Dict[str, np.ndarray]:
    rng = _rng("stencil1d", shape)
    n = shape["n"]
    return {"x": _int_f32(rng, n), "y": np.zeros(n, np.float32)}


def stencil1d_oracle(inputs, shape, params) -> Dict[str, np.ndarray]:
    x = inputs["x"]
    left = np.concatenate([x[:1], x[:-1]])
    right = np.concatenate([x[1:], x[-1:]])
    q, h = np.float32(0.25), np.float32(0.5)
    return {"y": ((q * left + h * x) + q * right).astype(np.float32)}


# -- 2-D five-point stencil ---------------------------------------------------

def stencil2d_inputs(shape, params) -> Dict[str, np.ndarray]:
    rng = _rng("stencil2d", shape)
    h, w = shape["h"], shape["w"]
    return {"x": _int_f32(rng, h * w), "y": np.zeros(h * w, np.float32)}


def stencil2d_oracle(inputs, shape, params) -> Dict[str, np.ndarray]:
    h, w = shape["h"], shape["w"]
    a = inputs["x"].reshape(h, w)
    p = np.pad(a, 1, mode="edge")
    left, right = p[1:-1, :-2], p[1:-1, 2:]
    up, down = p[:-2, 1:-1], p[2:, 1:-1]
    res = np.float32(0.5) * a + \
        np.float32(0.125) * ((left + right) + (up + down))
    return {"y": res.astype(np.float32).reshape(-1)}


# -- work-group inclusive prefix scan -----------------------------------------

def scan_inputs(shape, params) -> Dict[str, np.ndarray]:
    rng = _rng("scan", shape)
    n = shape["n"]
    x = rng.standard_normal(n).astype(np.float32)
    return {"x": x, "y": np.zeros(n, np.float32)}


def scan_oracle(inputs, shape, params) -> Dict[str, np.ndarray]:
    """Hillis-Steele doubling steps per segment — NOT cumsum, whose
    left-to-right association differs in float32."""
    seg = shape["seg"]
    a = inputs["x"].reshape(-1, seg).copy()
    off = 1
    while off < seg:
        nxt = a.copy()
        nxt[:, off:] = a[:, off:] + a[:, :-off]
        a = nxt
        off *= 2
    return {"y": a.reshape(-1)}


# -- histogram (privatized, atomics-free) -------------------------------------

def hist_groups(shape, params) -> int:
    return -(-shape["n"] // (params["lsz"] * params["ipt"]))


def hist_inputs(shape, params) -> Dict[str, np.ndarray]:
    rng = _rng("hist", shape)
    n, bins = shape["n"], shape["bins"]
    x = rng.random(n).astype(np.float32)
    return {"x": x,
            "out": np.zeros(hist_groups(shape, params) * bins, np.int32)}


def hist_oracle(inputs, shape, params) -> Dict[str, np.ndarray]:
    """Per-work-group partial histograms (one group's block of
    ``lsz*ipt`` items -> ``bins`` counts); the host sums partials."""
    n, bins = shape["n"], shape["bins"]
    block = params["lsz"] * params["ipt"]
    x = inputs["x"]
    ngrp = hist_groups(shape, params)
    out = np.zeros(ngrp * bins, np.int32)
    for g in range(ngrp):
        blk = x[g * block: min((g + 1) * block, n)]
        b = np.clip((blk * bins).astype(np.int32), 0, bins - 1)
        out[g * bins: (g + 1) * bins] = np.bincount(b, minlength=bins)[:bins]
    return {"out": out}
