"""Performance-portability kernel suite + scoreboard (docs/scoreboard.md).

A linear-algebra/irregular kernel suite authored in the repro.core DSL
(tiled GEMM, CSR SpMV, 1-D/2-D stencils, work-group prefix scan,
privatized histogram), each with a parameterized tuning space and a
bitwise NumPy oracle, plus the :class:`Scoreboard` layer that sweeps the
spaces per compiled target and reports achieved-vs-roofline fractions —
the Rupp-et-al. quantification of the paper's performance-portability
claim (§4, Figs. 12-14).
"""

from .kernels import SuiteKernel, SUITE, suite_kernels, ceil_to, param_key
from .scoreboard import Scoreboard, calibrate, render_markdown

__all__ = [
    "SUITE",
    "Scoreboard",
    "SuiteKernel",
    "calibrate",
    "ceil_to",
    "param_key",
    "render_markdown",
    "suite_kernels",
]
