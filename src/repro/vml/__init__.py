"""Vecmathlib (paper §5): vectorized, fusible elemental math for the kernel
compiler's built-in library and the LM stack's activations."""

from .core import (cos, copysign, erf, exp, fabs, gelu_tanh, log, reciprocal,
                   rsqrt, sigmoid, signbit, silu, sin, sqrt, tanh)
from . import ref

__all__ = ["exp", "log", "sin", "cos", "tanh", "erf", "sqrt", "rsqrt",
           "fabs", "copysign", "signbit", "reciprocal", "sigmoid",
           "gelu_tanh", "silu", "ref"]
