"""Oracles for Vecmathlib: the jnp/XLA "libm" the paper compares against."""

import jax.numpy as jnp
import jax.scipy.special as jsp
from jax import lax

exp = jnp.exp
log = jnp.log
sin = jnp.sin
cos = jnp.cos
tanh = jnp.tanh
erf = jsp.erf
sqrt = jnp.sqrt
rsqrt = lax.rsqrt
fabs = jnp.abs
sigmoid = lambda x: jnp.where(x >= 0, 1 / (1 + jnp.exp(-jnp.abs(x))),
                              1 - 1 / (1 + jnp.exp(-jnp.abs(x))))


def reciprocal(x):
    return 1.0 / x


def gelu_tanh(x):
    import numpy as np
    c = np.float32(0.7978845608028654)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def silu(x):
    return x * sigmoid(x)
