"""Polynomial kernels and coefficient tables for Vecmathlib (paper §5.1).

Most functions are computed via *range reduction followed by a polynomial
expansion* (the paper's recipe).  Coefficients are minimax fits (cephes /
fdlibm heritage) on the reduced ranges, accurate to float32 round-off.
"""

from __future__ import annotations

import jax.numpy as jnp

LN2 = 0.6931471805599453
INV_LN2 = 1.4426950408889634
# Cody–Waite split of ln2 for accurate exp range reduction
LN2_HI = 0.693359375
LN2_LO = -2.12194440e-4

PI = 3.141592653589793
PI_2 = 1.5707963267948966
INV_PI_2 = 0.6366197723675814
# Cody–Waite split of pi/2
PIO2_HI = 1.5707855224609375
PIO2_MID = 1.0804334124e-5
PIO2_LO = 6.0770943833e-11


def horner(x, coeffs):
    """Evaluate sum(c_i * x^(n-i)) with Horner's rule; coeffs highest-first."""
    acc = jnp.full_like(x, coeffs[0])
    for c in coeffs[1:]:
        acc = acc * x + c
    return acc


# e^r = 1 + r + r^2 * P(r) on [-ln2/2, ln2/2] (cephes expf minimax)
EXP_COEFFS = (
    1.9875691500e-4,
    1.3981999507e-3,
    8.3334519073e-3,
    4.1665795894e-2,
    1.6666665459e-1,
    5.0000001201e-1,
)

# sin(r) = r + r^3 * P(r^2) on [-pi/4, pi/4]
SIN_COEFFS = (
    -1.9515295891e-4,
    8.3321608736e-3,
    -1.6666654611e-1,
)

# cos(r) = 1 - r^2/2 + r^4 * P(r^2) on [-pi/4, pi/4]
COS_COEFFS = (
    2.443315711809948e-5,
    -1.388731625493765e-3,
    4.166664568298827e-2,
)

# log(1+f) = 2 * s * P(s^2), s = f/(2+f)  (atanh series, |s| <= 0.172)
LOG_COEFFS = (
    1.0 / 9.0,
    1.0 / 7.0,
    1.0 / 5.0,
    1.0 / 3.0,
    1.0,
)

# erf rational approximation (Abramowitz & Stegun 7.1.26), |err| <= 1.5e-7
ERF_A = (1.061405429, -1.453152027, 1.421413741, -0.284496736, 0.254829592)
ERF_P = 0.3275911
