"""Vecmathlib (paper §5): vectorized elemental functions in pure jnp.

Design rules carried over from the paper:

* **Bit manipulation** for the low-level pieces (sign/exponent surgery for
  ``fabs``/``copysign``/exponent scaling, §5.1 first paragraph), assuming
  IEEE-754 layout.
* **Newton's method** for functions with cheap inverses: ``sqrt`` divides the
  exponent by two via an integer shift for the initial guess, then iterates
  :math:`r_{n+1} = (r_n + x/r_n)/2`; ``rsqrt`` iterates
  :math:`y_{n+1} = y_n (1.5 - 0.5 x y_n^2)` (§5.1 second paragraph).
* **Range reduction + polynomial expansion** for the transcendental
  functions (§5.1 third paragraph): ``exp`` reduces by powers of two with a
  Cody–Waite split, ``sin``/``cos`` reduce modulo :math:`\\pi/2` with
  quadrant selection, ``log`` reduces to the mantissa and uses the atanh
  series.

Everything is elementwise jnp, so these functions *fuse with surrounding
code* (the paper's core argument against scalarizing to libm) — inside
Pallas kernel bodies they lower to straight VPU vector ops.

All routines compute in float32 (upcasting half/bfloat16 inputs) and
preserve the input dtype on return; float64 inputs are computed in float64
by falling back to the same algorithms with the f32 coefficient tables —
accuracy is float32-grade, which is what the OpenCL built-ins profile
requires.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import poly
from .poly import horner

_F32 = jnp.float32
_I32 = jnp.int32


def _prep(x):
    x = jnp.asarray(x)
    orig = x.dtype
    if x.dtype in (jnp.bfloat16, jnp.float16):
        x = x.astype(_F32)
    elif x.dtype not in (jnp.float32, jnp.float64):
        x = x.astype(_F32)
    return x, orig


def _fin(y, orig):
    return y.astype(orig) if y.dtype != orig else y


# ---------------------------------------------------------------------------
# bit-manipulation primitives (§5.1)
# ---------------------------------------------------------------------------

@jax.custom_jvp
def _fabs_f32(x):
    bits = x.view(_I32) & np.int32(0x7FFFFFFF)
    return bits.view(_F32)


@_fabs_f32.defjvp
def _fabs_f32_jvp(primals, tangents):
    # bitcast int ops have no autodiff rule (silent zero gradient!), so
    # the bit-manipulation primitives carry explicit JVPs
    (x,), (dx,) = primals, tangents
    y = _fabs_f32(x)
    return y, jnp.where(x < 0, -dx, dx)


def fabs(x):
    """Clear the sign bit."""
    x, orig = _prep(x)
    if x.dtype == jnp.float32:
        return _fin(_fabs_f32(x), orig)
    return _fin(jnp.abs(x), orig)


@jax.custom_jvp
def _copysign_f32(x, s):
    m = np.int32(np.uint32(0x80000000).view(np.int32))
    bits = (x.view(_I32) & np.int32(0x7FFFFFFF)) | (s.view(_I32) & m)
    return bits.view(_F32)


@_copysign_f32.defjvp
def _copysign_f32_jvp(primals, tangents):
    (x, s), (dx, _) = primals, tangents
    y = _copysign_f32(x, s)
    flip = (x < 0) != (s < 0)
    return y, jnp.where(flip, -dx, dx)


def copysign(x, s):
    x, orig = _prep(x)
    s = jnp.asarray(s, x.dtype)
    if x.dtype == jnp.float32:
        return _fin(_copysign_f32(x, s), orig)
    return _fin(jnp.copysign(x, s), orig)


def signbit(x):
    x, _ = _prep(x)
    if x.dtype == jnp.float32:
        return (x.view(_I32) >> 31) != 0
    return jnp.signbit(x)


def _ldexp_f32(x, k):
    """x * 2^k via exponent-field addition (k int32, result float32)."""
    # split into two steps to stay in the normal range
    k1 = k // 2
    k2 = k - k1
    f1 = ((k1 + 127) << 23).view(_F32)
    f2 = ((k2 + 127) << 23).view(_F32)
    return x * f1 * f2


def _frexp_f32(x):
    """Return (mantissa in [sqrt(2)/2, sqrt(2)), exponent) for positive x."""
    bits = x.view(_I32)
    e = ((bits >> 23) & 0xFF) - 127
    m_bits = (bits & np.int32(0x007FFFFF)) | np.int32(0x3F800000)
    m = m_bits.view(_F32)  # in [1, 2)
    # shift mantissa to [sqrt(2)/2, sqrt(2)) for symmetric log reduction
    big = m > 1.4142135623730951
    m = jnp.where(big, m * 0.5, m)
    e = e + big.astype(_I32)
    return m, e


# ---------------------------------------------------------------------------
# Newton-iteration functions (§5.1)
# ---------------------------------------------------------------------------

def sqrt(x):
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        return _fin(jnp.sqrt(x), orig)
    # initial guess: halve the exponent with an integer shift
    bits = x.view(_I32)
    guess_bits = (bits >> 1) + np.int32(0x1FC00000)
    r = guess_bits.view(_F32)
    # Newton: r <- (r + x/r) / 2 ; three iterations double the digits each
    for _ in range(3):
        r = 0.5 * (r + x / r)
    r = jnp.where(x > 0, r, jnp.where(x == 0, 0.0, jnp.nan))
    r = jnp.where(jnp.isinf(x) & (x > 0), jnp.inf, r)
    return _fin(r.astype(_F32), orig)


def rsqrt(x):
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        return _fin(1.0 / jnp.sqrt(x), orig)
    bits = x.view(_I32)
    y = (np.int32(0x5F3759DF) - (bits >> 1)).view(_F32)  # magic initial guess
    for _ in range(3):
        y = y * (1.5 - 0.5 * x * y * y)
    y = jnp.where(x > 0, y, jnp.where(x == 0, jnp.inf, jnp.nan))
    y = jnp.where(jnp.isinf(x) & (x > 0), 0.0, y)
    return _fin(y.astype(_F32), orig)


def reciprocal(x):
    """1/x via Newton on f(y)=1/y - x: y <- y*(2 - x*y)."""
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        return _fin(1.0 / x, orig)
    bits = x.view(_I32)
    y = (np.int32(0x7EF311C3) - bits).view(_F32)
    for _ in range(3):
        y = y * (2.0 - x * y)
    y = jnp.where(x == 0, jnp.inf * jnp.sign(1.0 / jnp.where(x == 0, 1.0, x)),
                  y)
    y = jnp.where(jnp.isinf(x), 0.0, y)
    return _fin(y, orig)


# ---------------------------------------------------------------------------
# range reduction + polynomial (§5.1)
# ---------------------------------------------------------------------------

def exp(x):
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        return _fin(jnp.exp(x), orig)
    xc = jnp.clip(x, -87.3, 88.72)
    k = jnp.round(xc * poly.INV_LN2)
    ki = k.astype(_I32)
    # Cody–Waite: r = x - k*ln2 computed in two pieces for accuracy
    r = xc - k * poly.LN2_HI
    r = r - k * poly.LN2_LO
    p = horner(r, poly.EXP_COEFFS)
    er = 1.0 + r + r * r * p
    y = _ldexp_f32(er, ki)
    # saturate outside the clamp range (incl. +/-inf inputs)
    y = jnp.where(x >= 88.72, jnp.inf, y)
    y = jnp.where(x <= -87.3, 0.0, y)
    y = jnp.where(jnp.isnan(x), jnp.nan, y)
    return _fin(y, orig)


def log(x):
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        return _fin(jnp.log(x), orig)
    m, e = _frexp_f32(jnp.maximum(x, 1e-45))
    f = m - 1.0
    s = f / (2.0 + f)
    z = s * s
    r = 2.0 * s * horner(z, poly.LOG_COEFFS)
    y = r + e.astype(_F32) * np.float32(poly.LN2)
    y = jnp.where(x > 0, y, jnp.where(x == 0, -jnp.inf, jnp.nan))
    y = jnp.where(jnp.isinf(x) & (x > 0), jnp.inf, y)
    return _fin(y, orig)


def _sincos_reduce(x):
    """Reduce to r in [-pi/4, pi/4] and quadrant q (mod 4)."""
    q = jnp.round(x * poly.INV_PI_2)
    qi = q.astype(_I32)
    r = x - q * poly.PIO2_HI
    r = r - q * poly.PIO2_MID
    r = r - q * poly.PIO2_LO
    return r.astype(_F32), qi


def _sin_core(r):
    z = r * r
    return r + r * z * horner(z, poly.SIN_COEFFS)


def _cos_core(r):
    z = r * r
    return 1.0 - 0.5 * z + z * z * horner(z, poly.COS_COEFFS)


def sin(x):
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        return _fin(jnp.sin(x), orig)
    r, q = _sincos_reduce(x)
    sc = jnp.where(q % 2 == 0, _sin_core(r), _cos_core(r))
    sign = jnp.where((q % 4) >= 2, -1.0, 1.0)
    return _fin(sign * sc, orig)


def cos(x):
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        return _fin(jnp.cos(x), orig)
    r, q = _sincos_reduce(x)
    sc = jnp.where(q % 2 == 0, _cos_core(r), _sin_core(r))
    sign = jnp.where(((q + 1) % 4) >= 2, -1.0, 1.0)
    return _fin(sign * sc, orig)


def tanh(x):
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        return _fin(jnp.tanh(x), orig)
    # tanh(x) = 1 - 2/(e^{2x}+1), clamped: |x|>9 saturates in f32
    xa = jnp.clip(x, -9.0, 9.0)
    e2 = exp(2.0 * xa)
    y = (e2 - 1.0) / (e2 + 1.0)
    return _fin(y, orig)


def erf(x):
    x, orig = _prep(x)
    if x.dtype != jnp.float32:
        import jax.scipy.special as jsp
        return _fin(jsp.erf(x), orig)
    a = fabs(x)
    t = 1.0 / (1.0 + poly.ERF_P * a)
    y = 1.0 - horner(t, poly.ERF_A) * t * exp(-a * a)
    return _fin(copysign(y, x), orig)


def sigmoid(x):
    x, orig = _prep(x)
    e = exp(-fabs(x).astype(x.dtype))
    pos = 1.0 / (1.0 + e)
    y = jnp.where(x >= 0, pos, 1.0 - pos)
    return _fin(y, orig)


def gelu_tanh(x):
    """GELU with the tanh approximation — the LM-stack consumer of vml."""
    x, orig = _prep(x)
    c = np.float32(0.7978845608028654)  # sqrt(2/pi)
    y = 0.5 * x * (1.0 + tanh(c * (x + 0.044715 * x * x * x)))
    return _fin(y, orig)


def silu(x):
    x, orig = _prep(x)
    return _fin(x * sigmoid(x), orig)
