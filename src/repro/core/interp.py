"""Fiber-based reference executor for SPMD kernels.

This implements OpenCL work-group semantics the way Clover / Twin Peaks do
(paper §7): one light-weight thread ("fiber" = Python generator) per
work-item, yielding at every ``barrier`` and resuming in rounds.  It executes
the *original, untransformed* kernel CFG, so it serves as the ground-truth
oracle against which the pocl-style compiled targets (region-formed,
vectorized) are validated — mirroring how the paper contrasts the fiber
approach with static work-group compilation.

Pure numpy; intentionally slow and simple.
"""

from __future__ import annotations

import math
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

from . import ir
from .ir import CondBranch, Function, Instr, Jump, Return, Value


def _trunc_div(a, b):
    if np.issubdtype(np.asarray(a).dtype, np.integer):
        q = np.floor_divide(a, b)
        r = a - q * b
        # adjust toward-zero for mixed signs (C semantics)
        adj = (r != 0) & ((r < 0) != (b < 0))
        return (q + adj).astype(np.asarray(a).dtype)
    return a / b


def _trunc_rem(a, b):
    if np.issubdtype(np.asarray(a).dtype, np.integer):
        return (a - _trunc_div(a, b) * b).astype(np.asarray(a).dtype)
    return np.fmod(a, b)


_BIN = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": _trunc_div,
    "rem": _trunc_rem,
    "min": np.minimum,
    "max": np.maximum,
    "pow": np.power,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
    "shl": lambda a, b: a << b,
    "shr": lambda a, b: a >> b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
}

_UN = {
    "neg": lambda a: -a,
    "not": lambda a: ~a if np.issubdtype(np.asarray(a).dtype, np.integer)
    else np.logical_not(a),
    "abs": np.abs,
    "exp": np.exp,
    "log": np.log,
    "sin": np.sin,
    "cos": np.cos,
    "tanh": np.tanh,
    "erf": np.vectorize(math.erf),
    "sqrt": np.sqrt,
    "rsqrt": lambda a: 1.0 / np.sqrt(a),
    "floor": np.floor,
    "ceil": np.ceil,
    "rint": np.rint,
}


class _Fiber:
    """Executes one work-item; yields at barriers."""

    def __init__(self, fn: Function, buffers: Dict[str, np.ndarray],
                 scalars: Dict[str, object], ids: Dict[str, Tuple[int, ...]]):
        self.fn = fn
        self.buffers = buffers
        self.scalars = scalars
        self.ids = ids
        self.env: Dict[int, object] = {}
        for nm, v in fn.arg_values.items():
            self.env[v.id] = np.dtype(v.dtype).type(scalars[nm])

    def _val(self, o):
        if isinstance(o, Value):
            return self.env[o.id]
        return o

    def run(self) -> Iterator[None]:
        fn = self.fn
        cur = fn.entry
        prev: Optional[str] = None
        while True:
            blk = fn.blocks[cur]
            # phis evaluate simultaneously on entry
            if blk.phis:
                vals = [self._val(phi.incomings[prev]) for phi in blk.phis]
                for phi, v in zip(blk.phis, vals):
                    self.env[phi.result.id] = np.dtype(phi.result.dtype).type(v)
            for ins in blk.instrs:
                if ins.op == "barrier":
                    yield
                    continue
                self._exec(ins)
            term = blk.terminator
            if isinstance(term, Return):
                return
            if isinstance(term, Jump):
                prev, cur = cur, term.target
            else:
                assert isinstance(term, CondBranch)
                c = bool(self._val(term.cond))
                prev, cur = cur, (term.if_true if c else term.if_false)

    def _exec(self, ins: Instr) -> None:
        op = ins.op
        if op == "const":
            r = np.dtype(ins.result.dtype).type(ins.attrs["value"])
        elif op == "convert":
            r = np.dtype(ins.result.dtype).type(self._val(ins.operands[0]))
        elif op in _BIN:
            a, b = (self._val(o) for o in ins.operands)
            r = _BIN[op](a, b)
            r = np.dtype(ins.result.dtype).type(r)
        elif op in _UN:
            r = _UN[op](self._val(ins.operands[0]))
            r = np.dtype(ins.result.dtype).type(r)
        elif op == "select":
            c, a, b = (self._val(o) for o in ins.operands)
            r = a if bool(c) else b
        elif op in ir.ID_OPS:
            r = np.int32(self.ids[op][ins.attrs["dim"]])
        elif op == "load":
            buf = self.buffers[ins.attrs["buffer"]]
            idx = int(self._val(ins.operands[0]))
            r = buf[idx]
        elif op == "store":
            buf = self.buffers[ins.attrs["buffer"]]
            idx = int(self._val(ins.operands[0]))
            buf[idx] = self._val(ins.operands[1])
            return
        else:
            raise NotImplementedError(f"interp: op {op}")
        if ins.result is not None:
            self.env[ins.result.id] = r


def run_ndrange(fn: Function, global_size: Sequence[int],
                local_size: Sequence[int],
                buffers: Dict[str, np.ndarray],
                scalars: Optional[Dict[str, object]] = None) -> Dict[str, np.ndarray]:
    """Execute an NDRange with fiber semantics.  Returns the buffers dict
    (global buffers mutated in place on copies)."""
    scalars = scalars or {}
    gsz = tuple(global_size) + (1,) * (3 - len(global_size))
    lsz = tuple(local_size) + (1,) * (3 - len(local_size))
    for g, l in zip(gsz, lsz):
        assert g % l == 0, "global size must be divisible by local size"
    ngrp = tuple(g // l for g, l in zip(gsz, lsz))

    out = {k: np.array(v, copy=True) for k, v in buffers.items()}
    local_defs = [a for a in fn.buffer_args if a.space == ir.LOCAL]

    for gz in range(ngrp[2]):
        for gy in range(ngrp[1]):
            for gx in range(ngrp[0]):
                grp = (gx, gy, gz)
                bufs = dict(out)
                for la in local_defs:
                    if la.name not in buffers:
                        bufs[la.name] = np.zeros(la.size, dtype=la.dtype)
                fibers = []
                for lz in range(lsz[2]):
                    for ly in range(lsz[1]):
                        for lx in range(lsz[0]):
                            lid = (lx, ly, lz)
                            ids = {
                                "local_id": lid,
                                "group_id": grp,
                                "global_id": tuple(
                                    g * l + i for g, l, i in zip(grp, lsz, lid)),
                                "local_size": lsz,
                                "num_groups": ngrp,
                                "global_size": gsz,
                            }
                            fibers.append(
                                _Fiber(fn, bufs, scalars, ids).run())
                # round-robin between barriers
                live = list(fibers)
                while live:
                    nxt = []
                    for f in live:
                        try:
                            next(f)
                            nxt.append(f)
                        except StopIteration:
                            pass
                    live = nxt
                for k in out:
                    out[k] = bufs[k]
    return out
