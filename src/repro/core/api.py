"""Kernel-compiler entry point (the layer under the host object model).

``_compile_kernel(build, local_size, target=...)`` runs the full
pocl-style pipeline at *enqueue* time (the paper specializes the
work-group function per local size, §4.1) and returns a callable
compiled kernel.  Host code reaches it through
:class:`~repro.core.program.Program` /
:class:`~repro.runtime.context.Context` (docs/host_api.md); the public
``compile_kernel`` wrapper survives as a deprecated shim.

Targets:
  ``vector``  — work-items on lanes, if-converted divergence (SIMD mapping)
  ``loop``    — serial work-item loops ('basic' driver analogue)
  ``pallas``  — vector mapping wrapped in a ``pl.pallas_call`` (TPU path,
                validated with interpret=True on CPU)
  ``auto``    — target chosen per kernel shape by the autotuner
                (:mod:`repro.core.autotune`)

``build`` is a zero-argument function returning a fresh
:class:`repro.core.ir.Function` (the pipeline mutates the CFG, and one
work-group function is generated per local size).  Compilation is memoized
in a content-addressed :class:`repro.core.cache.CompilationCache` keyed by
the canonical IR hash + specialization parameters, so re-enqueueing an
identical kernel is a hash lookup, not a pipeline re-run (docs/caching.md).
Pass ``cache=False`` to force a fresh compile, or a ``CompilationCache``
instance to use a private cache (each runtime ``Device`` owns one).
"""

from __future__ import annotations

import threading
import warnings
from typing import Callable, Dict, Optional, Sequence, Union

import jax
import numpy as np

from .cache import CacheKey, CompilationCache, PlanKey, default_cache, ir_hash
from .errors import InvalidArgError
from .ir import Function
from .passes import WorkGroupPlan, build_plan
from .targets.loop import LoopWGProgram
from .targets.vector import WGProgram

# running count of actual pipeline executions (cache misses); tests and
# bench_cache use it to prove steady-state launches do zero compile work.
# Guarded: compiles run concurrently on CommandQueue worker threads.
_compiles_done = 0
_compiles_lock = threading.Lock()


def compile_count() -> int:
    with _compiles_lock:
        return _compiles_done


class CompiledKernel:
    def __init__(self, prog: WGProgram, name: str):
        self.prog = prog
        self.name = name
        # cached kernels are shared across queue worker threads; guard the
        # per-shape jit cache's check-then-insert
        self._jit_cache: Dict[tuple, Callable] = {}
        self._jit_lock = threading.Lock()

    # the per-shape jit cache holds live jax callables; drop it (and the
    # lock) when the compilation cache pickles us to the disk tier
    def __getstate__(self):
        state = dict(self.__dict__)
        state["_jit_cache"] = {}
        state.pop("_jit_lock", None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._jit_lock = threading.Lock()

    def __call__(self, buffers: Dict[str, np.ndarray],
                 global_size: Sequence[int],
                 scalars: Optional[Dict[str, object]] = None,
                 jit: bool = True,
                 group_range: Optional[Sequence[int]] = None
                 ) -> Dict[str, np.ndarray]:
        """Launch over ``global_size``.  ``group_range=(lo, hi)`` executes
        only that contiguous range of linearized work-groups of the full
        NDRange (the multi-device co-execution unit, runtime/scheduler.py);
        group-id decoding is unchanged, so results over the sub-range are
        identical to the same groups of a full launch."""
        gsz = tuple(global_size)
        grange = None if group_range is None \
            else (int(group_range[0]), int(group_range[1]))
        scalars = scalars or {}
        # the pallas target needs scalar args as jaxpr literals (pallas
        # rejects captured device constants), so launch it un-jitted —
        # pallas_call compiles the kernel itself
        if type(self.prog).__name__ == "PallasWGProgram":
            jit = False
        if not jit:
            out = self.prog.run_ndrange(buffers, scalars, gsz,
                                        group_range=grange)
            return {k: np.asarray(v) for k, v in out.items()}
        key = (gsz, grange, tuple(sorted((k, v.shape, str(v.dtype))
                                         for k, v in buffers.items())))
        with self._jit_lock:
            fn = self._jit_cache.get(key)
            if fn is None:
                def launch(bufs, scals):
                    return self.prog.run_ndrange(bufs, scals, gsz,
                                                 group_range=grange)
                fn = jax.jit(launch)
                self._jit_cache[key] = fn
        out = fn(buffers, {k: np.asarray(v) for k, v in scalars.items()})
        return {k: np.asarray(v) for k, v in out.items()}

    # compiler introspection (used by tests/benchmarks)
    @property
    def num_regions(self) -> int:
        return len(self.prog.wg.regions)

    @property
    def context_stats(self) -> Dict[str, int]:
        return self.prog.plan.stats(self.prog.L)

    @property
    def work_group_plan(self) -> WorkGroupPlan:
        """The shared target-independent plan this kernel was built from."""
        return self.prog.wgplan

    @property
    def region_md(self) -> Dict[str, object]:
        """Per-region :class:`~repro.core.passes.ParallelRegionMD`."""
        return self.prog.md


def _run_pipeline(fn: Function, local_size: Sequence[int], target: str,
                  horizontal: bool, merge_uniform: bool,
                  use_vml: bool,
                  plan_cache: Optional[CompilationCache] = None,
                  _ir: Optional[str] = None) -> CompiledKernel:
    """One compilation = the (cacheable) target-independent prefix + the
    target-specific parallel mapping.  With a ``plan_cache``, the prefix —
    the pass-manager pipeline producing the :class:`WorkGroupPlan` — is
    looked up by :class:`PlanKey` and shared across targets and local
    sizes of the same kernel; only the thin mapping layer runs per
    target."""
    global _compiles_done
    with _compiles_lock:
        _compiles_done += 1
    name = fn.name
    if plan_cache is not None:
        pkey = PlanKey.make(_ir if _ir is not None else ir_hash(fn),
                            horizontal=horizontal,
                            merge_uniform=merge_uniform)
        plan = plan_cache.get_or_build_plan(
            pkey, lambda: build_plan(fn, horizontal=horizontal,
                                     merge_uniform=merge_uniform))
    else:
        plan = build_plan(fn, horizontal=horizontal,
                          merge_uniform=merge_uniform)
    if target == "vector":
        prog = WGProgram(plan, local_size, horizontal=horizontal,
                         merge_uniform=merge_uniform, use_vml=use_vml)
    elif target == "loop":
        prog = LoopWGProgram(plan, local_size, horizontal=horizontal,
                             merge_uniform=merge_uniform, use_vml=use_vml)
    elif target == "pallas":
        from .targets.pallas_target import PallasWGProgram
        prog = PallasWGProgram(plan, local_size, horizontal=horizontal,
                               merge_uniform=merge_uniform, use_vml=use_vml)
    else:
        raise InvalidArgError(f"unknown target {target!r}")
    return CompiledKernel(prog, name)


def _compile_kernel(build: Callable[[], Function],
                    local_size: Sequence[int],
                    target: str = "vector",
                    horizontal: bool = True,
                    merge_uniform: bool = True,
                    use_vml: bool = False,
                    cache: Union[bool, CompilationCache, None] = True,
                    device_key: Optional[str] = None,
                    plan_cache: Optional[CompilationCache] = None):
    """Compile ``build()`` for ``local_size`` on ``target``.

    ``cache=True`` uses the process-default compilation cache; pass a
    :class:`CompilationCache` for a private one (runtime devices do) or
    ``False``/``None`` to always recompile.  ``target="auto"`` defers the
    choice to the autotuner and returns an
    :class:`repro.core.autotune.AutotunedKernel`; ``device_key`` names the
    device the tuning decision belongs to (runtime devices pass their
    name), so heterogeneous devices tune independently.  Compiled code is
    device-independent here, so ``device_key`` never enters the
    compilation-cache key — only the tuning-table key.

    ``plan_cache`` holds the *stage-level* cache for the
    target-independent pipeline prefix (:class:`WorkGroupPlan`).  It
    defaults to the kernel cache, so a cold multi-target sweep of one
    kernel (the autotuner's) runs region formation exactly once; pass it
    explicitly to share plans across compiles that bypass the kernel
    cache (the autotuner does).  ``cache=False`` with no explicit
    ``plan_cache`` recompiles everything, plan included.
    """
    opts = dict(horizontal=horizontal, merge_uniform=merge_uniform,
                use_vml=use_vml)
    cache_obj: Optional[CompilationCache]
    if cache is True:
        cache_obj = default_cache()
    elif isinstance(cache, CompilationCache):
        cache_obj = cache
    else:
        cache_obj = None
    if plan_cache is None:
        plan_cache = cache_obj
    fn = build()
    if target == "auto":
        from .autotune import (AutotunedKernel, DEFAULT_CANDIDATES,
                               default_table)
        return AutotunedKernel(fn, build, local_size, opts,
                               DEFAULT_CANDIDATES, default_table(),
                               cache_obj, _compile_kernel,
                               device_key=device_key or "",
                               plan_cache=plan_cache)
    if cache_obj is None:
        return _run_pipeline(fn, local_size, target, plan_cache=plan_cache,
                             **opts)
    key = CacheKey.make(fn, local_size, target, **opts)
    return cache_obj.get_or_compile(
        key, lambda: _run_pipeline(fn, local_size, target,
                                   plan_cache=plan_cache, _ir=key.ir,
                                   **opts))


def compile_kernel(build: Callable[[], Function],
                   local_size: Sequence[int],
                   target: str = "vector",
                   **opts):
    """Deprecated host entry point — compile ``build()`` directly.

    Superseded by the first-class host object model (docs/host_api.md)::

        ctx = Context()
        prog = ctx.create_program(build)
        kernel = prog.create_kernel(name)

    which routes the identical compilation (same cache keys, same
    compile counts) through :class:`~repro.core.program.Program`'s lazy
    per-(device, local_size, target) specialization and adds typed
    argument validation.  This shim stays for existing call sites and
    benchmarks of the compiler layer; new code should build kernels
    through a :class:`~repro.runtime.context.Context`.
    """
    warnings.warn(
        "compile_kernel() is deprecated as a host entry point; build a "
        "Context and use ctx.create_program(build).create_kernel(name) "
        "(docs/host_api.md)", DeprecationWarning, stacklevel=2)
    return _compile_kernel(build, local_size, target=target, **opts)
