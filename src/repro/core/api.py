"""Public kernel-compiler API.

``compile_kernel(build, local_size, target=...)`` runs the full pocl-style
pipeline at *enqueue* time (the paper specializes the work-group function per
local size, §4.1) and returns a callable compiled kernel.

Targets:
  ``vector``  — work-items on lanes, if-converted divergence (SIMD mapping)
  ``loop``    — serial work-item loops ('basic' driver analogue)
  ``pallas``  — vector mapping wrapped in a ``pl.pallas_call`` (TPU path,
                validated with interpret=True on CPU)

``build`` is a zero-argument function returning a fresh
:class:`repro.core.ir.Function` (the pipeline mutates the CFG, and one
work-group function is generated per local size, so the builder is re-run
per compilation — the analogue of recompiling the kernel per enqueue).
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .ir import Function
from .targets.loop import LoopWGProgram
from .targets.vector import WGProgram


class CompiledKernel:
    def __init__(self, prog: WGProgram, name: str):
        self.prog = prog
        self.name = name
        self._jit_cache: Dict[tuple, Callable] = {}

    def __call__(self, buffers: Dict[str, np.ndarray],
                 global_size: Sequence[int],
                 scalars: Optional[Dict[str, object]] = None,
                 jit: bool = True) -> Dict[str, np.ndarray]:
        gsz = tuple(global_size)
        scalars = scalars or {}
        # the pallas target needs scalar args as jaxpr literals (pallas
        # rejects captured device constants), so launch it un-jitted —
        # pallas_call compiles the kernel itself
        if type(self.prog).__name__ == "PallasWGProgram":
            jit = False
        if not jit:
            out = self.prog.run_ndrange(buffers, scalars, gsz)
            return {k: np.asarray(v) for k, v in out.items()}
        key = (gsz, tuple(sorted((k, v.shape, str(v.dtype))
                                 for k, v in buffers.items())))
        fn = self._jit_cache.get(key)
        if fn is None:
            def launch(bufs, scals):
                return self.prog.run_ndrange(bufs, scals, gsz)
            fn = jax.jit(launch)
            self._jit_cache[key] = fn
        out = fn(buffers, {k: np.asarray(v) for k, v in scalars.items()})
        return {k: np.asarray(v) for k, v in out.items()}

    # compiler introspection (used by tests/benchmarks)
    @property
    def num_regions(self) -> int:
        return len(self.prog.wg.regions)

    @property
    def context_stats(self) -> Dict[str, int]:
        return self.prog.plan.stats(self.prog.L)


def compile_kernel(build: Callable[[], Function],
                   local_size: Sequence[int],
                   target: str = "vector",
                   horizontal: bool = True,
                   merge_uniform: bool = True,
                   use_vml: bool = False) -> CompiledKernel:
    fn = build()
    if target == "vector":
        prog = WGProgram(fn, local_size, horizontal=horizontal,
                         merge_uniform=merge_uniform, use_vml=use_vml)
    elif target == "loop":
        prog = LoopWGProgram(fn, local_size, horizontal=horizontal,
                             merge_uniform=merge_uniform, use_vml=use_vml)
    elif target == "pallas":
        from .targets.pallas_target import PallasWGProgram
        prog = PallasWGProgram(fn, local_size, horizontal=horizontal,
                               merge_uniform=merge_uniform, use_vml=use_vml)
    else:
        raise ValueError(f"unknown target {target!r}")
    return CompiledKernel(prog, fn.name)
