"""Private-variable context allocation (paper §4.7).

Values and virtual registers whose lifetime spans more than one parallel
region are placed in *context data arrays*: one element per work-item.
Values used only inside their defining region stay in (vector) registers —
the paper's lifetime optimization.  Uniform values are *merged* into a single
shared scalar instead of a per-WI array (§4.7 "merging of uniform
variables"), cutting context space; the saving is reported by
``ContextPlan.stats`` and benchmarked in ``benchmarks/bench_context.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Set, Tuple

import numpy as np

from .ir import CondBranch, Function, Value
from .regions import WGInfo
from .uniformity import Uniformity


@dataclass(frozen=True)
class Slot:
    kind: str          # 'val' | 'vreg'
    key: object        # Value id (int) or vreg name (str)
    dtype: str
    uniform: bool      # uniform slots are merged to a shared scalar
    name: str


@dataclass
class ContextPlan:
    slots: List[Slot]
    val_slots: Dict[int, Slot]
    vreg_slots: Dict[str, Slot]

    def stats(self, local_size: int) -> Dict[str, int]:
        merged = sum(1 for s in self.slots if s.uniform)
        bytes_merged = sum(
            np.dtype(s.dtype).itemsize * (1 if s.uniform else local_size)
            for s in self.slots)
        bytes_unmerged = sum(np.dtype(s.dtype).itemsize * local_size
                             for s in self.slots)
        return {
            "slots": len(self.slots),
            "uniform_merged": merged,
            "context_bytes": bytes_merged,
            "context_bytes_unmerged": bytes_unmerged,
        }


def fold_constants(fn: Function) -> None:
    """Replace uses of ``const`` results with numpy literals and delete the
    const instructions — cross-region constants are rematerialized for free
    instead of occupying context slots."""
    lits: Dict[int, object] = {}
    for blk in fn.blocks.values():
        for ins in blk.instrs:
            if ins.op == "const":
                lits[ins.result.id] = np.dtype(ins.result.dtype).type(
                    ins.attrs["value"])

    def sub(o):
        if isinstance(o, Value) and o.id in lits:
            return lits[o.id]
        return o

    for blk in fn.blocks.values():
        blk.instrs = [i for i in blk.instrs if i.op != "const"]
        for ins in blk.instrs:
            ins.operands = [sub(o) for o in ins.operands]
        term = blk.terminator
        if isinstance(term, CondBranch):
            term.cond = sub(term.cond)


def _region_touches(wg: WGInfo) -> Tuple[
        Dict[int, Set[str]], Dict[int, str], Dict[str, Set[str]]]:
    """Returns (value uses per region, value def block, vreg touch regions)."""
    fn = wg.fn
    val_use_regions: Dict[int, Set[str]] = {}
    val_def_block: Dict[int, str] = {}
    vreg_regions: Dict[str, Set[str]] = {}
    for bar, region in wg.regions.items():
        for bname in region.blocks:
            blk = fn.blocks[bname]
            for ins in blk.instrs:
                for o in ins.operands:
                    if isinstance(o, Value):
                        val_use_regions.setdefault(o.id, set()).add(bar)
                if ins.op in ("vreg_read", "vreg_write"):
                    vreg_regions.setdefault(ins.attrs["vreg"], set()).add(bar)
                if ins.result is not None:
                    val_def_block[ins.result.id] = bname
            term = blk.terminator
            if isinstance(term, CondBranch) and isinstance(term.cond, Value):
                val_use_regions.setdefault(term.cond.id, set()).add(bar)
    return val_use_regions, val_def_block, vreg_regions


def _schedule_reentrant(wg: WGInfo) -> Set[str]:
    """Barriers reachable from themselves through the schedule graph."""
    out: Set[str] = set()
    for b in wg.regions:
        seen: Set[str] = set()
        stack = list(wg.regions[b].exits)
        while stack:
            n = stack.pop()
            if n == b:
                out.add(b)
                break
            if n in seen:
                continue
            seen.add(n)
            stack.extend(wg.regions[n].exits)
    return out


def build_context_plan(wg: WGInfo, uni: Uniformity,
                       merge_uniform: bool = True) -> ContextPlan:
    fn = wg.fn
    val_uses, val_defs, vreg_regions = _region_touches(wg)
    reentrant = _schedule_reentrant(wg)

    # value dtype lookup
    val_dtype: Dict[int, str] = {}
    val_name: Dict[int, str] = {}
    for blk in fn.blocks.values():
        for ins in blk.instrs:
            if ins.result is not None:
                val_dtype[ins.result.id] = ins.result.dtype
                val_name[ins.result.id] = ins.result.name
    arg_ids = {v.id for v in fn.arg_values.values()}

    slots: List[Slot] = []
    val_slots: Dict[int, Slot] = {}
    vreg_slots: Dict[str, Slot] = {}

    # region -> blocks set for membership checks
    region_blocks = {bar: r.blocks for bar, r in wg.regions.items()}

    for vid, uses in sorted(val_uses.items()):
        if vid in arg_ids or vid not in val_defs:
            continue  # kernel args are ambient; undefined = builder constant
        defb = val_defs[vid]
        crossing = any(defb not in region_blocks[r] for r in uses)
        # values in re-entrant regions whose def might be bypassed are still
        # fine: SSA def-before-use holds within each execution
        if crossing:
            uniform = merge_uniform and uni.value_id_uniform(vid)
            s = Slot("val", vid, val_dtype[vid], uniform,
                     f"v_{val_name.get(vid, vid)}")
            slots.append(s)
            val_slots[vid] = s

    vreg_dtype: Dict[str, str] = {}
    for blk in fn.blocks.values():
        for ins in blk.instrs:
            if ins.op in ("vreg_read", "vreg_write"):
                vreg_dtype[ins.attrs["vreg"]] = ins.attrs["dtype"]

    for vreg, regions in sorted(vreg_regions.items()):
        crossing = len(regions) > 1 or any(r in reentrant for r in regions)
        if crossing:
            uniform = merge_uniform and uni.vreg_uniform(vreg)
            s = Slot("vreg", vreg, vreg_dtype[vreg], uniform, vreg)
            slots.append(s)
            vreg_slots[vreg] = s

    return ContextPlan(slots, val_slots, vreg_slots)
