"""Horizontal inner-loop parallelization (paper §4.6).

Sequential inner loops written by the programmer cannot be parallelized
across work-items unless the compiler proves their trip count is the same
for every work-item.  When the uniformity analysis shows that the loop exit
condition *and* the predicates on the path to the loop entry are
work-item-invariant, implicit barriers are inserted around/inside the loop —
exactly the §4.5 b-loop barriers — which interchanges the work-item loop with
the inner loop: the inner loop becomes the outer, lock-step loop, and each
iteration's body is a parallel region executed for all work-items at once.

On the vector target this turns a per-lane masked loop into a single scalar
loop over a fully vectorized body (the paper's DCT case study, §6.4).
"""

from __future__ import annotations

from typing import Dict, Set

from .ir import CondBranch, Function, Value
from . import uniformity as ua


def horizontal_candidates(fn: Function) -> Set[str]:
    """Headers of barrier-free natural loops that are legal to interchange:
    uniform exit condition, uniform entry predicate, and all enclosing loops
    equally uniform (so the b-loop fixpoint never forces lockstep onto a
    divergent loop)."""
    info = ua.analyze(fn)
    loops = fn.natural_loops()

    def loop_uniform(header: str, body: Set[str]) -> bool:
        hdr = fn.blocks[header]
        term = hdr.terminator
        if not isinstance(term, CondBranch):
            return False  # not in canonical while form
        if isinstance(term.cond, Value) and not info.value_uniform(term.cond):
            return False
        if not info.block_uniform(header):
            return False
        return True

    uniform_headers: Set[str] = set()
    body_of: Dict[str, Set[str]] = {}
    for header, body in loops:
        body_of[header] = body
        if loop_uniform(header, body):
            uniform_headers.add(header)

    # a loop qualifies only if every enclosing loop is uniform as well
    out: Set[str] = set()
    for header in uniform_headers:
        enclosing = [h for h, b in body_of.items()
                     if h != header and header in b]
        if all(h in uniform_headers for h in enclosing):
            out.add(header)
    # the barrier-containing loops are already b-loops; only add barrier-free
    out = {h for h in out
           if not any(fn.blocks[b].has_barrier() for b in body_of[h])}
    return out
