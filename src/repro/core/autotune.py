"""Per-kernel target autotuner (docs/caching.md §Autotuning).

pocl picks the parallel mapping per *device driver*; which mapping wins for
a given kernel is platform- and kernel-dependent (the central observation of
Rupp & Weinbub's portability study).  Instead of hard-coding the choice we
measure it:

* ``compile_kernel(build, lsz, target="auto")`` returns an
  :class:`AutotunedKernel`.
* On the **first launch of a (kernel, local size, global size) shape**, the
  candidate targets (``loop``, ``vector``, and ``pallas`` where it works for
  the kernel) are compiled through the compilation cache, warmed up, and
  timed on the real launch buffers.
* The winner is recorded in a :class:`TuningTable` (JSON on disk when a path
  is configured, e.g. via ``REPRO_TUNING_TABLE``), so later processes skip
  the measurement entirely.
* Every subsequent launch routes straight through the cached winner — a dict
  lookup, no timing, no recompilation.

A kernel can be **pinned** to a target (``table.pin("mykernel", "vector")``)
which bypasses measurement for every shape of that kernel.
"""

from __future__ import annotations

import json
import math
import os
import threading
import time
import warnings
from typing import Callable, Dict, Optional, Sequence, Tuple

from .cache import CacheKey, ir_hash
from .errors import BuildError
from .ir import Function

DEFAULT_CANDIDATES: Tuple[str, ...] = ("loop", "vector", "pallas")


class TuningTable:
    """Persistent (kernel shape -> winning target) table.

    Schema (JSON): ``{"winners": {key: {"target", "timings_us",
    "failed"?}}, "pins": {kernel_name: target},
    "coexec": {key: {"weights": {class: share}, "launches": n}},
    "sweeps": {key: {"params": {...}, "timings_us": {...}}}}``.
    Winner keys are ``"<ir-hash>|l=<local>|g=<global>|<options>"`` so a
    tuning decision is exactly as specific as the compilation it
    selects.  The ``coexec`` section persists converged multi-device
    split weights per *device class* (docs/runtime.md §Scheduler), keyed
    ``"<ir-hash>|coexec=<class>+<class>+..."`` — the ImageCL-style
    per-platform mapping decision, so a warm process starts a co-executed
    launch near the converged split instead of re-learning it.  The
    ``sweeps`` section persists *tuning-space* winners (tile/local
    sizes, unroll factors — the scoreboard's per-target parameter
    sweeps, docs/scoreboard.md): unlike winner keys, sweep keys cannot
    be IR hashes because each swept configuration builds a *different*
    kernel, so they are keyed by suite-kernel name + target + problem
    shape (:meth:`make_sweep_key`), and a warm run re-measures only the
    persisted winning configuration instead of the whole space.
    """

    def __init__(self, path: "Optional[str | os.PathLike]" = None):
        self.path = os.fspath(path) if path is not None else None
        self._winners: Dict[str, Dict[str, object]] = {}
        self._coexec: Dict[str, Dict[str, object]] = {}
        self._sweeps: Dict[str, Dict[str, object]] = {}
        self._pins: Dict[str, str] = {}
        self._lock = threading.Lock()
        # per-key tuning locks: concurrent first launches of the same
        # shape must not time candidates against each other's noise and
        # must record exactly one decision; unrelated shapes tune freely
        self._tune_locks: Dict[str, threading.Lock] = {}
        if path and os.path.exists(path):
            self._load()

    def tune_lock(self, key: str) -> threading.Lock:
        with self._lock:
            lk = self._tune_locks.get(key)
            if lk is None:
                lk = threading.Lock()
                self._tune_locks[key] = lk
            return lk

    # -- keying ----------------------------------------------------------------
    @staticmethod
    def make_key(ir: str, local_size: Sequence[int],
                 global_size: Sequence[int],
                 options: Sequence[Tuple[str, object]],
                 device: str = "") -> str:
        """Tuning key: kernel identity + specialization + (optionally) the
        device the measurement was taken on.  Runtime devices pass their
        name (``Device.build_kernel``), so a slow device's winner never
        leaks onto a fast one; ``device=""`` keeps the device-agnostic key
        (process-default tuning outside the runtime layer)."""
        l = "x".join(str(int(x)) for x in local_size)
        g = "x".join(str(int(x)) for x in global_size)
        o = ",".join(f"{k}={v}" for k, v in options)
        d = f"|dev={device}" if device else ""
        return f"{ir}{d}|l={l}|g={g}|{o}"

    @staticmethod
    def make_coexec_key(ir: str, device_classes: Sequence[str]) -> str:
        """Key for a persisted co-execution split: kernel identity plus
        the ordered *device-class vector* of the platform.  Classes (not
        device names) make the entry portable across processes whose
        device objects differ but whose platform shape is the same; the
        vector is ordered because weights are positional."""
        return f"{ir}|coexec={'+'.join(device_classes)}"

    @staticmethod
    def make_sweep_key(kernel: str, target: str, shape_desc: str,
                       device: str = "") -> str:
        """Key for a persisted tuning-space sweep winner.

        Sweep entries record *which point of a parameter space* (tile
        size, unroll factor, items-per-thread, ...) won for a suite
        kernel on one target — not which target won for one compiled
        kernel, which is what winner keys do.  Every swept point builds
        a different kernel (tile sizes are baked into the IR), so the IR
        hash cannot identify the sweep; the stable identity is the suite
        kernel's name, the target it was swept on, and the problem shape
        the timings were taken at."""
        d = f"|dev={device}" if device else ""
        return f"{kernel}|sweep|tgt={target}|shape={shape_desc}{d}"

    # -- persistence -----------------------------------------------------------
    def _load(self) -> None:
        try:
            with open(self.path) as f:
                raw = json.load(f)
            self._winners = dict(raw.get("winners", {}))
            self._coexec = dict(raw.get("coexec", {}))
            self._sweeps = dict(raw.get("sweeps", {}))
            self._pins = dict(raw.get("pins", {}))
        except Exception:
            self._winners, self._coexec, self._pins = {}, {}, {}
            self._sweeps = {}

    def _save(self) -> None:
        if not self.path:
            return
        try:
            tmp = self.path + ".tmp"
            os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                        exist_ok=True)
            with open(tmp, "w") as f:
                json.dump({"winners": self._winners,
                           "coexec": self._coexec, "pins": self._pins,
                           "sweeps": self._sweeps},
                          f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except Exception as e:
            # keep tuning decisions usable in-process even when the table
            # path is unwritable (read-only FS, bad REPRO_TUNING_TABLE);
            # mirror the disk cache's soft-failure policy but stay audible
            warnings.warn(f"tuning table not persisted to {self.path!r}: "
                          f"{type(e).__name__}: {e}", RuntimeWarning)

    # -- API --------------------------------------------------------------------
    def get(self, key: str) -> Optional[str]:
        with self._lock:
            ent = self._winners.get(key)
            return ent["target"] if ent else None

    def record(self, key: str, target: str, timings_us: Dict[str, float],
               failures: Optional[Dict[str, str]] = None) -> None:
        with self._lock:
            ent = {"target": target, "timings_us": dict(timings_us)}
            if failures:
                ent["failed"] = dict(failures)
            self._winners[key] = ent
            self._save()

    def record_coexec(self, key: str, weights: Dict[str, float],
                      blend: float = 0.5) -> None:
        """Fold one launch's converged per-class split weights into the
        persisted entry.

        ``weights`` maps device class -> observed share; they are
        normalized here so the stored entry is always a distribution.
        Existing entries are blended (``blend`` is the weight of the new
        observation) rather than overwritten: per-launch noise averages
        out across launches, the ImageCL persistence idea.  Non-finite
        or non-positive totals are dropped — a persisted entry must never
        poison a warm start."""
        try:
            vals = {str(c): float(w) for c, w in weights.items()}
        except (TypeError, ValueError):
            return
        total = sum(vals.values())
        if not vals or not all(math.isfinite(w) and w >= 0
                               for w in vals.values()) or total <= 0:
            return
        vals = {c: w / total for c, w in vals.items()}
        with self._lock:
            ent = self._coexec.get(key)
            if ent and set(ent.get("weights", {})) == set(vals):
                old = ent["weights"]
                mixed = {c: blend * vals[c] + (1 - blend) * float(old[c])
                         for c in vals}
                tot = sum(mixed.values())
                vals = {c: w / tot for c, w in mixed.items()}
                launches = int(ent.get("launches", 0)) + 1
            else:
                launches = 1
            self._coexec[key] = {"weights": vals, "launches": launches}
            self._save()

    def get_coexec(self, key: str) -> Optional[Dict[str, object]]:
        """The persisted co-execution entry for ``key`` —
        ``{"weights": {class: share}, "launches": n}`` — or None."""
        with self._lock:
            ent = self._coexec.get(key)
            if ent is None:
                return None
            return {"weights": dict(ent.get("weights", {})),
                    "launches": int(ent.get("launches", 0))}

    def record_sweep(self, key: str, params: Dict[str, object],
                     timings_us: Dict[str, float]) -> None:
        """Persist one sweep's winning parameter point.

        ``params`` is the winning configuration (e.g. ``{"ts": 8,
        "unroll": 8}``), ``timings_us`` maps each swept configuration's
        canonical string to its measured time so a later reader can see
        the whole space, not just the winner.  Non-finite winner timings
        are dropped — a poisoned measurement must not become a warm
        start."""
        try:
            times = {str(c): float(t) for c, t in timings_us.items()}
        except (TypeError, ValueError):
            return
        if not times or not all(math.isfinite(t) for t in times.values()):
            return
        with self._lock:
            self._sweeps[key] = {"params": dict(params),
                                 "timings_us": times}
            self._save()

    def get_sweep(self, key: str) -> Optional[Dict[str, object]]:
        """The persisted sweep entry for ``key`` — ``{"params": {...},
        "timings_us": {config: us}}`` — or None."""
        with self._lock:
            ent = self._sweeps.get(key)
            if ent is None:
                return None
            return {"params": dict(ent.get("params", {})),
                    "timings_us": dict(ent.get("timings_us", {}))}

    def pin(self, kernel_name: str, target: str) -> None:
        with self._lock:
            self._pins[kernel_name] = target
            self._save()

    def pinned(self, kernel_name: str) -> Optional[str]:
        with self._lock:
            return self._pins.get(kernel_name)

    def clear(self) -> None:
        with self._lock:
            self._winners.clear()
            self._coexec.clear()
            self._sweeps.clear()
            self._pins.clear()
            self._save()

    def __len__(self) -> int:
        with self._lock:
            return len(self._winners)


class AutotunedKernel:
    """A launchable kernel whose target is chosen by measurement.

    Compilation of every candidate goes through the compilation cache, so
    tuning N candidates costs N cached compiles once; the steady state is a
    tuning-table lookup plus the winner's cache hit.
    """

    def __init__(self, fn: Function, build: Callable[[], Function],
                 local_size: Sequence[int],
                 options: Dict[str, object],
                 candidates: Sequence[str],
                 table: TuningTable,
                 cache: object,
                 compile_fn: Callable[..., object],
                 warmup: int = 1, repeats: int = 3,
                 device_key: str = "",
                 plan_cache: Optional[object] = None):
        self.name = fn.name
        self.device_key = device_key   # tuning decisions are per device
        # stage-level cache for the target-independent prefix: the sweep
        # over candidate targets shares one WorkGroupPlan per kernel
        # (docs/caching.md); defaults to the kernel cache
        self.plan_cache = plan_cache if plan_cache is not None else cache
        self._ir = ir_hash(fn)
        self.local_size = tuple(int(x) for x in local_size)
        self.options = dict(options)
        self.candidates = tuple(candidates)
        self.table = table
        self.cache = cache
        self._compile = compile_fn        # compile_kernel, injected (no cycle)
        self._build = build
        self._kernels: Dict[str, object] = {}
        self._kernels_lock = threading.Lock()
        self.warmup, self.repeats = warmup, repeats
        self.last_winner: Optional[str] = None

    # -- candidate compilation (cached) -----------------------------------------
    def kernel_for(self, target: str):
        with self._kernels_lock:
            return self._kernel_for_locked(target)

    def _kernel_for_locked(self, target: str):
        k = self._kernels.get(target)
        if k is None:
            if self.cache is not None:
                # reuse the IR hash computed at construction: a cache hit
                # here costs a key build + dict lookup, not a re-build and
                # re-canonicalization of the kernel
                key = CacheKey(self._ir, self.local_size, target,
                               tuple(sorted(self.options.items())))
                k = self.cache.get_or_compile(
                    key, lambda: self._compile(
                        self._build, self.local_size, target=target,
                        cache=None, plan_cache=self.plan_cache,
                        **self.options))
            else:
                k = self._compile(self._build, self.local_size,
                                  target=target, cache=None,
                                  plan_cache=self.plan_cache,
                                  **self.options)
            self._kernels[target] = k
        return k

    # -- launch ------------------------------------------------------------------
    def __call__(self, buffers, global_size, scalars=None, jit: bool = True,
                 group_range=None):
        gsz = tuple(int(x) for x in global_size)
        pinned = self.table.pinned(self.name)
        if pinned is not None:
            self.last_winner = pinned
            return self.kernel_for(pinned)(buffers, gsz, scalars, jit=jit,
                                           group_range=group_range)
        key = TuningTable.make_key(self._ir, self.local_size, gsz,
                                   sorted(self.options.items()),
                                   device=self.device_key)
        winner = self.table.get(key)
        if winner is None:
            # single-flight tuning: concurrent first launches of the same
            # shape would time candidates against each other's load and
            # race the recorded decision
            with self.table.tune_lock(key):
                winner = self.table.get(key)
                if winner is None:
                    winner, out = self._tune(key, buffers, gsz, scalars,
                                             jit, group_range)
                    self.last_winner = winner
                    return out
        self.last_winner = winner
        return self.kernel_for(winner)(buffers, gsz, scalars, jit=jit,
                                       group_range=group_range)

    def _tune(self, key: str, buffers, gsz, scalars, jit, group_range=None):
        """Time every candidate on the real launch; returns (winner, output).

        Kernel launches are functional over the buffer dict (inputs are never
        mutated), so timing candidates back-to-back is safe.  A
        ``group_range`` sub-launch times only the sub-range (the decision is
        still keyed on the full shape — co-executed chunks of one NDRange
        share the winner).
        """
        timings: Dict[str, float] = {}
        outputs: Dict[str, object] = {}
        failures: Dict[str, str] = {}
        for target in self.candidates:
            try:
                k = self.kernel_for(target)
                for _ in range(self.warmup):
                    outputs[target] = k(buffers, gsz, scalars, jit=jit,
                                        group_range=group_range)
                best = float("inf")
                for _ in range(self.repeats):
                    t0 = time.perf_counter()
                    outputs[target] = k(buffers, gsz, scalars, jit=jit,
                                        group_range=group_range)
                    best = min(best, time.perf_counter() - t0)
                timings[target] = best * 1e6
            except Exception as e:
                # a candidate failing may be expected (target unsupported
                # for this kernel) or a real backend bug — keep it visible:
                # warn now and persist the error next to the timings
                failures[target] = f"{type(e).__name__}: {e}"
                warnings.warn(
                    f"autotuner: candidate {target!r} failed for "
                    f"{self.name!r}: {failures[target]}", RuntimeWarning)
        if not timings:
            # every candidate failed: a build failure of the kernel, not
            # a tuning decision (typed, CL_BUILD_PROGRAM_FAILURE)
            raise BuildError(
                f"autotuner: no candidate target compiled {self.name!r} "
                f"(tried {self.candidates}): {failures}",
                build_log="\n".join(f"{t}: {msg}"
                                    for t, msg in failures.items()))
        winner = min(timings, key=timings.get)
        self.table.record(key, winner, timings, failures)
        if self.cache is not None:
            self.cache.note_tune_decision()
        return winner, outputs[winner]

    # -- introspection (mirror CompiledKernel) ------------------------------------
    def _delegate(self):
        """The compiled kernel introspection reads from: the winner or pin
        when known, else any already-compiled candidate, else (before the
        first launch) the first candidate — which is then compiled as the
        reference.  Region/context structure is produced by the
        target-independent pipeline half, so the numbers agree across
        candidates."""
        tgt = self.last_winner or self.table.pinned(self.name)
        if tgt is None:
            with self._kernels_lock:
                if self._kernels:
                    return next(iter(self._kernels.values()))
            tgt = self.candidates[0]
        return self.kernel_for(tgt)

    @property
    def num_regions(self) -> int:
        return self._delegate().num_regions

    @property
    def context_stats(self):
        return self._delegate().context_stats


# ---------------------------------------------------------------------------
# Process-default tuning table
# ---------------------------------------------------------------------------

_default_table: Optional[TuningTable] = None
_table_lock = threading.Lock()


def default_table() -> TuningTable:
    global _default_table
    with _table_lock:
        if _default_table is None:
            _default_table = TuningTable(
                os.environ.get("REPRO_TUNING_TABLE") or None)
        return _default_table


def set_default_table(table: Optional[TuningTable]) -> None:
    global _default_table
    with _table_lock:
        _default_table = table
