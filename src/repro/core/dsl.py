"""Kernel builder DSL: the OpenCL-C analogue for authoring SPMD kernels.

Users write per-work-item kernels against :class:`KernelBuilder`, with
structured control flow (``if_``/``else_``/``while_loop``/``for_range``),
address-space-qualified buffers, and explicit ``barrier()`` calls — a Python
rendering of the OpenCL C kernel language (paper §2, Fig. 1).  The builder
lowers to the plain CFG IR in :mod:`repro.core.ir`; downstream passes recover
structure from the graph (dominators, natural loops) exactly as pocl does on
LLVM IR, so no pass trusts the builder's nesting.
"""

from __future__ import annotations

import contextlib
from typing import Dict, List, Optional, Union


from . import ir
from .ir import (BufferArg, CondBranch, Function, Instr, Jump, Phi, Return,
                 ScalarArg, Value)

Number = Union[int, float, bool]


def _const_dtype(x: Number) -> str:
    if isinstance(x, bool):
        return "bool"
    if isinstance(x, int):
        return "int32"
    return "float32"


class Expr:
    """Operator-overloading wrapper around an SSA Value (or constant)."""

    __array_priority__ = 100

    def __init__(self, builder: "KernelBuilder", value: Value):
        self.b = builder
        self.value = value

    @property
    def dtype(self) -> str:
        return self.value.dtype

    # arithmetic ------------------------------------------------------------
    def _bin(self, op: str, other, rev: bool = False) -> "Expr":
        o = self.b._as_value(other)
        a, c = (o, self.value) if rev else (self.value, o)
        dt = ir.infer_binop_dtype(op, a.dtype, c.dtype)
        return self.b._emit(op, [a, c], dt)

    def __add__(self, o): return self._bin("add", o)
    def __radd__(self, o): return self._bin("add", o, True)
    def __sub__(self, o): return self._bin("sub", o)
    def __rsub__(self, o): return self._bin("sub", o, True)
    def __mul__(self, o): return self._bin("mul", o)
    def __rmul__(self, o): return self._bin("mul", o, True)
    def __truediv__(self, o): return self._bin("div", o)
    def __rtruediv__(self, o): return self._bin("div", o, True)
    def __floordiv__(self, o):
        d = self._bin("div", o)
        return d if d.dtype.startswith("int") else self.b.floor(d)
    def __mod__(self, o): return self._bin("rem", o)
    def __rmod__(self, o): return self._bin("rem", o, True)
    def __pow__(self, o): return self._bin("pow", o)
    def __and__(self, o): return self._bin("and", o)
    def __or__(self, o): return self._bin("or", o)
    def __xor__(self, o): return self._bin("xor", o)
    def __lshift__(self, o): return self._bin("shl", o)
    def __rshift__(self, o): return self._bin("shr", o)
    def __neg__(self): return self.b._emit("neg", [self.value], self.dtype)
    def __invert__(self): return self.b._emit("not", [self.value], self.dtype)

    # comparisons -----------------------------------------------------------
    def __lt__(self, o): return self._bin("lt", o)
    def __le__(self, o): return self._bin("le", o)
    def __gt__(self, o): return self._bin("gt", o)
    def __ge__(self, o): return self._bin("ge", o)
    def __eq__(self, o): return self._bin("eq", o)  # type: ignore[override]
    def __ne__(self, o): return self._bin("ne", o)  # type: ignore[override]
    __hash__ = None  # type: ignore[assignment]

    def astype(self, dtype: str) -> "Expr":
        return self.b._emit("convert", [self.value], dtype)


class Var:
    """A mutable variable handle (lowered to SSA with phis at joins)."""

    def __init__(self, builder: "KernelBuilder", name: str, init: Value):
        self.b = builder
        self.name = name
        builder._env[name] = init

    def get(self) -> Expr:
        return Expr(self.b, self.b._env[self.name])

    def set(self, v) -> None:
        self.b._env[self.name] = self.b._as_value(v)

    # sugar
    def __iadd__(self, o):
        self.set(self.get() + o)
        return self


class Buf:
    """A buffer handle: ``buf[idx]`` loads, ``buf[idx] = v`` stores."""

    def __init__(self, builder: "KernelBuilder", arg: BufferArg):
        self.b = builder
        self.arg = arg

    def __getitem__(self, idx) -> Expr:
        iv = self.b._as_value(idx, "int32")
        return self.b._emit("load", [iv], self.arg.dtype,
                            attrs={"buffer": self.arg.name,
                                   "space": self.arg.space})

    def __setitem__(self, idx, val) -> None:
        iv = self.b._as_value(idx, "int32")
        vv = self.b._as_value(val, self.arg.dtype)
        self.b._emit("store", [iv, vv], None,
                     attrs={"buffer": self.arg.name,
                            "space": self.arg.space})


class TileView:
    """Row-major 2-D indexing view over a flat :class:`Buf`.

    ``view[i, j]`` loads / ``view[i, j] = v`` stores ``buf[i*ld + j]``;
    ``ld`` (the leading dimension) may be a Python int or a uniform
    scalar ``Expr``.  This is the DSL's local-memory *tile* abstraction:
    OpenCL kernels address 2-D tiles of ``__local`` (and row-major
    global matrices) through exactly this flattening, and the suite's
    tiled-GEMM/stencil kernels want it spelled once, not at every
    index expression (docs/scoreboard.md §Authoring)."""

    def __init__(self, buf: Buf, ld):
        self.buf = buf
        self.ld = ld

    def _flat(self, idx):
        i, j = idx
        return i * self.ld + j

    def __getitem__(self, idx) -> Expr:
        return self.buf[self._flat(idx)]

    def __setitem__(self, idx, val) -> None:
        self.buf[self._flat(idx)] = val


class _LoopCtx:
    def __init__(self, builder: "KernelBuilder"):
        self.b = builder
        self.header: Optional[str] = None
        self.body: Optional[str] = None
        self.exit: Optional[str] = None
        self._cond_set = False
        self.header_phis: Dict[str, Phi] = {}
        self.preheader_env: Dict[str, Value] = {}

    def cond(self, c) -> None:
        """End the loop header: branch to body if ``c`` else to exit."""
        assert not self._cond_set, "loop cond() called twice"
        self._cond_set = True
        b = self.b
        cv = b._as_value(c, "bool")
        body = b.fn.new_block("body")
        exitb = b.fn.new_block("loopexit")
        self.body, self.exit = body.name, exitb.name
        b._cur.terminator = CondBranch(cv, body.name, exitb.name)
        b._cur = body


class KernelBuilder:
    """Builds a :class:`repro.core.ir.Function` from structured Python code."""

    def __init__(self, name: str, ndim: int = 1):
        self.fn = Function(name, ndim)
        entry = self.fn.new_block("entry")
        self.fn.entry = entry.name
        self._cur = entry
        self._env: Dict[str, Value] = {}
        self._var_counter = 0
        self._pending_else: Optional[tuple] = None

    # -- argument declaration -------------------------------------------------
    def arg_buffer(self, name: str, dtype: str = "float32",
                   space: str = ir.GLOBAL) -> Buf:
        arg = BufferArg(name, dtype, space)
        self.fn.buffer_args.append(arg)
        return Buf(self, arg)

    def local_array(self, name: str, dtype: str, size: int) -> Buf:
        """Automatic local array — converted to an extra buffer argument with a
        fixed allocation size, exactly as pocl §4.7 converts automatic locals
        to work-group-function arguments."""
        arg = BufferArg(name, dtype, ir.LOCAL, size=size)
        self.fn.buffer_args.append(arg)
        return Buf(self, arg)

    def local_tile(self, name: str, dtype: str,
                   shape: "tuple[int, int]") -> TileView:
        """A 2-D local-memory tile: a flat automatic local array of
        ``shape[0] * shape[1]`` elements wrapped in a row-major
        :class:`TileView` (``tile[i, j]``).  The flat array follows the
        pocl §4.7 automatic-local rule (:meth:`local_array`)."""
        h, w = int(shape[0]), int(shape[1])
        flat = self.local_array(name, dtype, h * w)
        return TileView(flat, w)

    def strided(self, buf: Buf, ld) -> TileView:
        """View a flat (row-major) global buffer as 2-D: ``v[i, j]``
        addresses ``buf[i*ld + j]``.  ``ld`` is the leading dimension —
        a Python int or a uniform scalar ``Expr`` (e.g. a matrix width
        argument)."""
        return TileView(buf, ld)

    def range_unrolled(self, stop: int, unroll: int = 1):
        """Iterate ``0 .. stop`` with build-time unrolling: an IR loop of
        stride ``unroll`` whose body is replicated ``unroll`` times, or —
        when ``unroll >= stop`` — pure straight-line code (no IR loop at
        all).  This is the suite kernels' *unroll* tuning axis: the same
        per-iteration body lowers to materially different CFGs, which is
        exactly what the per-target sweep measures.

        ``stop`` and ``unroll`` must be Python ints with
        ``stop % unroll == 0`` (callers pad their trip counts).  The
        generator must be consumed to exhaustion (a plain ``for`` does),
        because the IR loop closes when the final index is yielded."""
        stop, unroll = int(stop), int(unroll)
        assert stop >= 0 and unroll >= 1, (stop, unroll)
        if unroll >= stop:
            for k in range(stop):
                yield self.const(k, "int32")
            return
        assert stop % unroll == 0, \
            f"range_unrolled: {unroll} does not divide {stop}"
        with self.for_range(0, stop, step=unroll) as i:
            if unroll == 1:
                yield i
            else:
                for u in range(unroll):
                    yield i + u

    def arg_scalar(self, name: str, dtype: str = "int32") -> Expr:
        self.fn.scalar_args.append(ScalarArg(name, dtype))
        v = Value(dtype, name)
        self.fn.arg_values[name] = v
        return Expr(self, v)

    # -- value plumbing --------------------------------------------------------
    def _as_value(self, x, dtype: Optional[str] = None) -> Value:
        if isinstance(x, Expr):
            v = x.value
        elif isinstance(x, Var):
            v = x.get().value
        elif isinstance(x, Value):
            v = x
        else:
            dt = dtype or _const_dtype(x)
            e = self._emit("const", [], dt, attrs={"value": x})
            v = e.value
        if dtype is not None and v.dtype != dtype and dtype != "any":
            v = self._emit("convert", [v], dtype).value
        return v

    def _emit(self, op: str, operands: List[object], dtype: Optional[str],
              attrs: Optional[dict] = None) -> Optional[Expr]:
        res = Value(dtype) if dtype is not None else None
        self._cur.instrs.append(Instr(op, operands, res, attrs or {}))
        return Expr(self, res) if res is not None else None

    def const(self, x: Number, dtype: Optional[str] = None) -> Expr:
        return Expr(self, self._as_value(x, dtype or _const_dtype(x)))

    # -- builtins ----------------------------------------------------------------
    def _id(self, op: str, dim: int) -> Expr:
        return self._emit(op, [], "int32", attrs={"dim": dim})

    def local_id(self, dim: int = 0) -> Expr: return self._id("local_id", dim)
    def global_id(self, dim: int = 0) -> Expr: return self._id("global_id", dim)
    def group_id(self, dim: int = 0) -> Expr: return self._id("group_id", dim)
    def local_size(self, dim: int = 0) -> Expr: return self._id("local_size", dim)
    def num_groups(self, dim: int = 0) -> Expr: return self._id("num_groups", dim)
    def global_size(self, dim: int = 0) -> Expr: return self._id("global_size", dim)

    def barrier(self) -> None:
        self._emit("barrier", [], None)

    # -- math -----------------------------------------------------------------
    def _un(self, op: str, x, dtype: Optional[str] = None) -> Expr:
        v = self._as_value(x)
        return self._emit(op, [v], dtype or v.dtype)

    def exp(self, x): return self._un("exp", x)
    def log(self, x): return self._un("log", x)
    def sin(self, x): return self._un("sin", x)
    def cos(self, x): return self._un("cos", x)
    def tanh(self, x): return self._un("tanh", x)
    def erf(self, x): return self._un("erf", x)
    def sqrt(self, x): return self._un("sqrt", x)
    def rsqrt(self, x): return self._un("rsqrt", x)
    def floor(self, x): return self._un("floor", x)
    def abs(self, x): return self._un("abs", x)

    def minimum(self, a, b):
        av = self._as_value(a)
        bv = self._as_value(b)
        return self._emit("min", [av, bv],
                          ir.infer_binop_dtype("min", av.dtype, bv.dtype))

    def maximum(self, a, b):
        av = self._as_value(a)
        bv = self._as_value(b)
        return self._emit("max", [av, bv],
                          ir.infer_binop_dtype("max", av.dtype, bv.dtype))

    def select(self, c, a, b) -> Expr:
        cv = self._as_value(c, "bool")
        av = self._as_value(a)
        bv = self._as_value(b, av.dtype)
        return self._emit("select", [cv, av, bv], av.dtype)

    # -- variables -----------------------------------------------------------
    def var(self, init, name: Optional[str] = None) -> Var:
        self._var_counter += 1
        nm = name or f"var{self._var_counter}"
        return Var(self, nm, self._as_value(init))

    # -- structured control flow ------------------------------------------------
    @contextlib.contextmanager
    def if_(self, cond):
        self._pending_else = None
        cv = self._as_value(cond, "bool")
        then_blk = self.fn.new_block("then")
        join_blk = self.fn.new_block("join")
        branch_blk = self._cur
        snapshot = dict(self._env)
        self._cur.terminator = CondBranch(cv, then_blk.name, join_blk.name)
        self._cur = then_blk
        yield
        then_end = self._cur
        then_env = dict(self._env)
        then_end.terminator = Jump(join_blk.name)
        # stash state so an immediately-following else_() can rewire
        self._pending_else = (branch_blk, then_end.name, then_env,
                              snapshot, join_blk)
        self._env = snapshot
        self._cur = join_blk
        self._insert_join_phis(join_blk, [(then_end.name, then_env),
                                          (branch_blk.name, snapshot)])

    @contextlib.contextmanager
    def else_(self):
        assert self._pending_else is not None, "else_ without preceding if_"
        branch_blk, then_end_name, then_env, snapshot, join_blk = \
            self._pending_else
        self._pending_else = None
        # undo the phis/else-edge wiring done at if_ exit
        join_blk.phis = []
        else_blk = self.fn.new_block("else")
        term = branch_blk.terminator
        assert isinstance(term, CondBranch)
        branch_blk.terminator = CondBranch(term.cond, term.if_true,
                                           else_blk.name)
        self._env = dict(snapshot)
        self._cur = else_blk
        yield
        else_end = self._cur
        else_env = dict(self._env)
        else_end.terminator = Jump(join_blk.name)
        self._cur = join_blk
        self._env = dict(snapshot)
        self._insert_join_phis(join_blk, [(then_end_name, then_env),
                                          (else_end.name, else_env)])

    def _insert_join_phis(self, join_blk, incomings) -> None:
        """incomings: [(pred_block_name, env_at_pred_end)]"""
        names = set()
        for _, env in incomings:
            names |= set(env)
        for nm in sorted(names):
            vals = [env.get(nm) for _, env in incomings]
            if any(v is None for v in vals):
                continue  # defined on one path only: dead past the join
            if all(v is vals[0] for v in vals):
                self._env[nm] = vals[0]
                continue
            phi_res = Value(vals[0].dtype, f"{nm}.phi")
            join_blk.phis.append(
                Phi(phi_res, {pred: env[nm] for pred, env in incomings}))
            self._env[nm] = phi_res

    @contextlib.contextmanager
    def while_loop(self):
        self._pending_else = None
        pre = self._cur
        header = self.fn.new_block("header")
        pre.terminator = Jump(header.name)
        ctx = _LoopCtx(self)
        ctx.header = header.name
        ctx.preheader_env = dict(self._env)
        # Eager header phis for every live variable; trivial ones are
        # simplified away in finish() (standard SSA construction for loops).
        for nm, val in sorted(self._env.items()):
            phi_res = Value(val.dtype, f"{nm}.loop")
            header.phis.append(Phi(phi_res, {pre.name: val}))
            ctx.header_phis[nm] = header.phis[-1]
            self._env[nm] = phi_res
        self._cur = header
        yield ctx
        assert ctx._cond_set, "while_loop body must call ctx.cond(...)"
        latch = self._cur
        latch.terminator = Jump(ctx.header)
        for nm, phi in ctx.header_phis.items():
            phi.incomings[latch.name] = self._env[nm]
        # continue at the exit block; only preheader-visible vars survive the
        # loop (body-local vars do not dominate the exit block).
        self._env = {nm: phi.result for nm, phi in ctx.header_phis.items()}
        self._cur = self.fn.blocks[ctx.exit]

    @contextlib.contextmanager
    def for_range(self, start, stop, step=1):
        i = self.var(self._as_value(start, "int32"), name=f"i{self._var_counter}")
        with self.while_loop() as loop:
            loop.cond(i.get() < stop)
            yield i.get()
            i.set(i.get() + step)

    # -- finish ------------------------------------------------------------------
    def finish(self) -> Function:
        if self._cur.terminator is None:
            self._cur.terminator = Return()
        self.fn.prune_unreachable()
        simplify_phis(self.fn)
        self.fn.verify()
        return self.fn


def simplify_phis(fn: Function) -> None:
    """Remove trivial phis (all non-self incomings identical)."""
    changed = True
    while changed:
        changed = False
        replace: Dict[int, Value] = {}
        for blk in fn.blocks.values():
            keep = []
            for phi in blk.phis:
                ops = {v.id if isinstance(v, Value) else ("c", repr(v))
                       for v in phi.incomings.values()
                       if not (isinstance(v, Value) and v.id == phi.result.id)}
                vals = [v for v in phi.incomings.values()
                        if not (isinstance(v, Value) and v.id == phi.result.id)]
                if len(ops) == 1:
                    tgt = vals[0]
                    if isinstance(tgt, Value):
                        replace[phi.result.id] = tgt
                        changed = True
                        continue
                keep.append(phi)
            blk.phis = keep
        if not replace:
            break

        def sub(v):
            while isinstance(v, Value) and v.id in replace:
                v = replace[v.id]
            return v

        for blk in fn.blocks.values():
            for phi in blk.phis:
                phi.incomings = {p: sub(v) for p, v in phi.incomings.items()}
            for ins in blk.instrs:
                ins.operands = [sub(o) for o in ins.operands]
            term = blk.terminator
            if isinstance(term, CondBranch):
                term.cond = sub(term.cond)
