"""Typed error hierarchy with OpenCL-style status codes (docs/host_api.md).

Every error the reproduction raises on a *user-facing* path derives from
:class:`ReproError` and carries a numeric ``code`` plus a symbolic
``code_name`` mirroring the OpenCL status-code convention (CL_INVALID_*,
CL_BUILD_PROGRAM_FAILURE, ...).  Host code can therefore handle failures
by family::

    try:
        kernel.set_arg("x", wrong_thing)
    except ReproError as e:
        print(e.code, e.code_name)      # -50 CL_INVALID_ARG_VALUE

Each concrete class also inherits the *untyped* exception it replaced
(``ValueError``, ``RuntimeError``, ``MemoryError``, ``AssertionError``),
so pre-existing ``except ValueError`` style call sites keep working —
the hierarchy is a refinement, not a break.

Classes defined elsewhere for layering reasons but folded into the
hierarchy: :class:`~repro.runtime.events.CommandError` /
:class:`~repro.runtime.events.DependencyError` (a failed command and its
abandoned dependents), :class:`~repro.runtime.bufalloc.OutOfMemory` (the
arena allocator), and :class:`~repro.core.passes.VerifierError` (a
structural IR invariant broken by a middle-end pass, a build failure).
"""

from __future__ import annotations

from typing import Dict


class ReproError(Exception):
    """Base of the typed error hierarchy.

    ``code``/``code_name`` follow the OpenCL status-code style: 0 is
    success (never raised), failures are negative.
    """

    code: int = -9999
    code_name: str = "REPRO_ERROR"

    @property
    def status(self) -> int:
        """The numeric status code (negative, OpenCL convention)."""
        return self.code


class InvalidArgError(ReproError, ValueError):
    """Bad argument to a host API call: unknown kernel-arg name/index,
    a value whose dtype contradicts the kernel signature, a scalar where
    the IR declares a buffer (CL_INVALID_ARG_VALUE family), or a launch
    with unset kernel arguments (CL_INVALID_KERNEL_ARGS)."""

    code = -50
    code_name = "CL_INVALID_ARG_VALUE"


class InvalidBufferError(InvalidArgError):
    """Illegal buffer creation request: zero/negative element count or
    an unknown dtype string (CL_INVALID_BUFFER_SIZE)."""

    code = -61
    code_name = "CL_INVALID_BUFFER_SIZE"


class BuildError(ReproError, RuntimeError):
    """Program/kernel build failure (CL_BUILD_PROGRAM_FAILURE).

    ``build_log`` carries the accumulated diagnostics the way
    ``clGetProgramBuildInfo(CL_PROGRAM_BUILD_LOG)`` does — including the
    verifier report when a middle-end pass broke an IR invariant.
    """

    code = -11
    code_name = "CL_BUILD_PROGRAM_FAILURE"

    def __init__(self, message: str, build_log: str = ""):
        super().__init__(message)
        self.build_log = build_log


class MapError(ReproError, RuntimeError):
    """Illegal sub-buffer or map/unmap operation (CL_MAP_FAILURE /
    CL_INVALID_* family, docs/memory.md).  Raised by ``create_sub_buffer``
    bounds/alignment checks, by overlapping-write-map guards, and by
    launches over write-mapped allocations."""

    code = -12
    code_name = "CL_MAP_FAILURE"


class DeviceLostError(ReproError, RuntimeError):
    """A device became unavailable mid-pipeline (CL_DEVICE_NOT_AVAILABLE).

    The serving scheduler surfaces this on the *affected request's*
    result when a device-side DAG command fails mid-group, while sibling
    requests keep running (docs/serving.md §Failure handling); the
    fault-injection harness raises it to drive that path."""

    code = -2
    code_name = "CL_DEVICE_NOT_AVAILABLE"


#: status code -> symbolic name, for every code the hierarchy can raise
#: (populated below; the paper's hosts report these via clGetEventInfo)
STATUS_NAMES: Dict[int, str] = {}


def status_name(code: int) -> str:
    """Symbolic name for a status ``code`` (``"UNKNOWN(<code>)"`` when no
    class claims it)."""
    return STATUS_NAMES.get(code, f"UNKNOWN({code})")


def _register(cls) -> None:
    STATUS_NAMES.setdefault(cls.code, cls.code_name)


def register_error(cls):
    """Fold an externally-defined exception class into the status table
    (used by the runtime/compiler classes that live in their own modules
    for layering reasons: CommandError, OutOfMemory, VerifierError)."""
    _register(cls)
    return cls


for _cls in (ReproError, InvalidArgError, InvalidBufferError, BuildError,
             MapError, DeviceLostError):
    _register(_cls)


__all__ = [
    "ReproError", "InvalidArgError", "InvalidBufferError", "BuildError",
    "MapError", "DeviceLostError", "status_name", "register_error",
    "STATUS_NAMES",
]
