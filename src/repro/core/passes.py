"""Pass-manager pipeline for the kernel-compiler middle-end (paper Fig. 3).

The paper's central claim is that the kernel compiler is *modular*: the
target-independent parallel region formation runs once, and its product —
parallel regions, the region schedule, and the data-parallelism facts the
later passes exploit (the paper's ``llvm.mem.parallel_loop_access``
metadata, §4) — is consumed unchanged by every target-specific parallel
mapping.  This module makes that architecture explicit:

* :class:`Pass` — a named pipeline stage declaring which structural
  *properties* of the IR it requires and establishes (``single-exit``,
  ``barriers-isolated``, ``phi-free``, ...), with the transformation as a
  function over a :class:`PipelineState`.
* :class:`PassManager` — runs a pass list in order, enforcing the
  requires/establishes contracts, optionally running the structural IR
  verifier between passes (``verify=True`` or ``REPRO_VERIFY_IR=1``),
  recording per-pass wall times, and calling dump hooks after every pass
  (``tools/dump_pipeline.py`` and the golden-IR tests are built on these).
* :func:`verify_ir` — the structural verifier: CFG well-formedness,
  single exit, barrier isolation, phi/vreg consistency.  Violations raise
  :class:`VerifierError` naming the pass that produced the bad IR.
* :class:`WorkGroupPlan` — the pipeline's product: everything about a
  kernel that does not depend on the execution target.  All three targets
  (``loop`` / ``vector`` / ``pallas``) are thin parallel mappings over one
  shared plan; the plan is cached per canonical-IR hash
  (:mod:`repro.core.cache`), so an autotune sweep over the targets runs
  region formation exactly once per kernel (``plan_count()`` proves it;
  ``benchmarks/bench_compile.py`` measures it).

Pass order (identical semantics to the pre-refactor function chain):

  normalize → inject_loop_barriers → out_of_ssa → horizontal →
  tail_duplicate → form_regions → uniformity → fold_constants →
  context_planning → structure_regions → annotate_parallel_md
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from .errors import BuildError, register_error
from .ir import CONSTANT, CondBranch, Function, GLOBAL, Jump, LOCAL, Return
from .regions import (WGInfo, form_regions, inject_loop_barriers, normalize,
                      out_of_ssa, tail_duplicate)
from .context import ContextPlan, build_context_plan, fold_constants
from .uniformity import AllVarying, analyze

# running count of actual pipeline runs (plan-cache misses).  The
# companion to ``api.compile_count()`` one stage earlier: tests and
# benchmarks use the delta to prove the target-independent prefix runs
# once per kernel across a multi-target autotune sweep.
_plans_built = 0
_plans_lock = threading.Lock()


def plan_count() -> int:
    with _plans_lock:
        return _plans_built


@register_error
class VerifierError(BuildError, AssertionError):
    """Structural IR invariant violation, attributed to the pass whose
    output failed verification (``.pass_name``).

    Part of the typed :class:`~repro.core.errors.ReproError` hierarchy as
    a :class:`~repro.core.errors.BuildError`: a pass breaking the IR is a
    program-build failure, and :meth:`repro.core.program.Program.build`
    folds the report into the build log.  (``AssertionError`` is kept as
    a base for pre-hierarchy call sites.)"""

    code = -45
    code_name = "CL_INVALID_PROGRAM_EXECUTABLE"

    def __init__(self, pass_name: str, message: str):
        self.pass_name = pass_name
        text = f"[after pass {pass_name!r}] {message}"
        super().__init__(text, build_log=text)


# ---------------------------------------------------------------------------
# Structural IR verifier
# ---------------------------------------------------------------------------

def verify_ir(fn: Function, properties: Sequence[str] = (),
              pass_name: str = "<unknown>") -> None:
    """Check CFG well-formedness plus every property in ``properties``.

    Base checks (always): entry block exists, every block has a
    terminator, every successor edge targets an existing block, every
    block is reachable from entry, and phi incomings name actual
    predecessors.

    Property checks:
      ``single-exit``        exactly one ``Return`` block
      ``barriers-isolated``  every barrier instr is alone in its block,
                             terminated by an unconditional ``Jump``
      ``phi-free``           no phi nodes remain; every virtual register
                             has one consistent dtype across all
                             reads/writes
    """
    def fail(msg: str) -> None:
        raise VerifierError(pass_name, msg)

    if fn.entry not in fn.blocks:
        fail(f"entry block {fn.entry!r} missing")
    for name, blk in fn.blocks.items():
        if blk.terminator is None:
            fail(f"block {name!r} has no terminator")
        if not isinstance(blk.terminator, (Jump, CondBranch, Return)):
            fail(f"block {name!r} has unknown terminator "
                 f"{type(blk.terminator).__name__}")
        for s in blk.successors():
            if s not in fn.blocks:
                fail(f"block {name!r} branches to missing block {s!r}")
    reachable = set(fn.rpo())
    unreachable = sorted(set(fn.blocks) - reachable)
    if unreachable:
        fail(f"unreachable blocks: {unreachable}")
    preds = fn.predecessors()
    for name, blk in fn.blocks.items():
        for phi in blk.phis:
            for p in phi.incomings:
                if p not in preds[name]:
                    fail(f"phi in {name!r} names non-predecessor {p!r}")

    props = set(properties)
    if "single-exit" in props:
        exits = fn.exit_blocks()
        if len(exits) != 1:
            fail(f"expected a single exit block, found {exits}")
    if "barriers-isolated" in props:
        for name, blk in fn.blocks.items():
            bars = [i for i in blk.instrs if i.op == "barrier"]
            if not bars:
                continue
            if len(blk.instrs) != 1 or blk.phis \
                    or not isinstance(blk.terminator, Jump):
                fail(f"barrier in {name!r} is not isolated "
                     f"(instrs={len(blk.instrs)}, phis={len(blk.phis)}, "
                     f"terminator={type(blk.terminator).__name__})")
    if "phi-free" in props:
        vreg_dtype: Dict[str, str] = {}
        for name, blk in fn.blocks.items():
            if blk.phis:
                fail(f"block {name!r} still has {len(blk.phis)} phi(s)")
            for ins in blk.instrs:
                if ins.op in ("vreg_read", "vreg_write"):
                    nm, dt = ins.attrs["vreg"], ins.attrs["dtype"]
                    if vreg_dtype.setdefault(nm, dt) != dt:
                        fail(f"vreg {nm!r} used at dtype {dt!r} and "
                             f"{vreg_dtype[nm]!r}")


# ---------------------------------------------------------------------------
# Region structuring (target-independent; moved here from targets/vector.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BlockNode:
    name: str


@dataclasses.dataclass
class LoopNode:
    header: str
    body_entry: str
    exit_target: str            # header's out-of-loop successor
    body_items: List[object]
    blocks: Set[str]            # all loop blocks incl. header


def _sccs(nodes: Set[str], succs: Dict[str, List[str]]) -> List[List[str]]:
    """Tarjan SCCs (iterative).  Returned in reverse topological order."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    out: List[List[str]] = []
    counter = [0]

    for root in sorted(nodes):
        if root in index:
            continue
        work = [(root, iter(succs.get(root, [])))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in nodes:
                    continue
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(succs.get(w, []))))
                    advanced = True
                    break
                elif w in on_stack:
                    low[v] = min(low[v], index[w])
            if not advanced:
                work.pop()
                if work:
                    pv = work[-1][0]
                    low[pv] = min(low[pv], low[v])
                if low[v] == index[v]:
                    scc = []
                    while True:
                        w = stack.pop()
                        on_stack.discard(w)
                        scc.append(w)
                        if w == v:
                            break
                    out.append(scc)
    return out


def structure_region(fn: Function, entry: str,
                     blocks: Set[str]) -> List[object]:
    """Collapse cyclic SCCs of the region sub-CFG to loop supernodes and
    order the resulting DAG topologically (reachable-from-entry only)."""
    succs = {b: [s for s in fn.blocks[b].successors() if s in blocks]
             for b in blocks}
    preds: Dict[str, List[str]] = {b: [] for b in blocks}
    for b, ss in succs.items():
        for s in ss:
            preds[s].append(b)

    sccs = _sccs(blocks, succs)  # reverse topological order
    scc_of: Dict[str, int] = {}
    for i, scc in enumerate(sccs):
        for b in scc:
            scc_of[b] = i

    # reachability from the entry's SCC over the SCC DAG
    reach: Set[int] = set()
    stack = [scc_of[entry]]
    while stack:
        i = stack.pop()
        if i in reach:
            continue
        reach.add(i)
        for b in sccs[i]:
            for s in succs[b]:
                if scc_of[s] != i:
                    stack.append(scc_of[s])

    items: List[object] = []
    for i in reversed(range(len(sccs))):  # topological order
        if i not in reach:
            continue
        scc = sccs[i]
        sset = set(scc)
        cyclic = len(scc) > 1 or any(b in succs[b] for b in scc)
        if not cyclic:
            items.append(BlockNode(scc[0]))
            continue
        # loop supernode: unique header = the block entered from outside
        heads = {b for b in scc
                 if b == entry or any(p not in sset for p in preds[b])}
        assert len(heads) == 1, \
            f"irreducible loop in region (headers {heads})"
        header = heads.pop()
        hdr = fn.blocks[header]
        term = hdr.terminator
        assert isinstance(term, CondBranch), \
            f"loop header {header} must end in a conditional branch"
        inside = [s for s in term.successors() if s in sset]
        outside = [s for s in term.successors() if s not in sset]
        assert len(inside) == 1 and len(outside) == 1, \
            f"loop {header} not in canonical while form"
        body_items = structure_region(fn, inside[0], sset - {header})
        items.append(LoopNode(header, inside[0], outside[0], body_items,
                              sset))
    return items


# ---------------------------------------------------------------------------
# ParallelRegionMD — the §4 parallelism metadata carried on each region
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelRegionMD:
    """Per-region data-parallelism facts, the analogue of the
    ``llvm.mem.parallel_loop_access`` metadata pocl attaches to the
    work-item loops it generates (§4): region formation *proves* these
    properties, and the target mappings rely on them instead of
    re-deriving (or conservatively forgetting) them.

    ``wi_parallel``    the region's work-item loop carries no cross-WI
                       dependencies — by construction: barriers bound the
                       region, so every lane may run concurrently.  This
                       is what licenses the vector/pallas lane mapping
                       and the loop target's unordered ``fori_loop``.
    ``uniform_exits``  every branch selecting the region's successor
                       barrier is provably work-group-uniform — what
                       licenses reading the next region id from a single
                       peeled work-item (§4.4).  OpenCL requires this of
                       well-formed kernels; ``False`` means the analysis
                       could not prove it (the peeled-WI schedule is
                       still used, per the OpenCL contract).
    ``lockstep``       region boundary produced by a b-loop implicit
                       barrier (§4.5) or the horizontal pass (§4.6): all
                       work-items iterate the enclosing loop together.
    """

    barrier: str                # barrier block this region starts after
    rid: int                    # region id in the schedule order
    wi_parallel: bool
    uniform_exits: bool
    lockstep: bool
    n_blocks: int

    def describe(self) -> str:
        tags = [t for t, on in (("wi-parallel", self.wi_parallel),
                                ("uniform-exits", self.uniform_exits),
                                ("lockstep", self.lockstep)) if on]
        return (f"region[{self.rid}] @{self.barrier}: "
                f"{self.n_blocks} block(s), {', '.join(tags) or '-'}")


def _region_md(fn: Function, wg: WGInfo, uni) -> Dict[str, ParallelRegionMD]:
    md: Dict[str, ParallelRegionMD] = {}
    rid_of = {b: i for i, b in enumerate(wg.order)}
    for bar in wg.order:
        region = wg.regions[bar]
        uniform = True
        for bname in region.blocks:
            term = fn.blocks[bname].terminator
            if not isinstance(term, CondBranch):
                continue
            # a branch with a region-exit (barrier) successor decides the
            # schedule; it must be WG-uniform for the peeled-WI rule
            if any(s not in region.blocks for s in term.successors()):
                if not uni.value_uniform(term.cond):
                    uniform = False
        bar_instr = next(i for i in fn.blocks[bar].instrs
                         if i.op == "barrier")
        implicit = str(bar_instr.attrs.get("implicit", ""))
        m = ParallelRegionMD(
            barrier=bar, rid=rid_of[bar], wi_parallel=True,
            uniform_exits=uniform,
            lockstep=implicit.startswith("bloop"),
            n_blocks=len(region.blocks))
        md[bar] = m
        region.attrs["md"] = m
    return md


# ---------------------------------------------------------------------------
# Fusibility facts — what the DAG-level fusion optimizer needs per kernel
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BufferFootprint:
    """Static access footprint of one buffer parameter: how many loads
    and stores the kernel body performs on it, and whether every access
    index is the work-item's own ``global_id(0)`` — the property that
    makes per-lane value forwarding between a producer's store and a
    consumer's load legal (:mod:`repro.core.fusion`)."""

    name: str
    space: str
    loads: int
    stores: int
    gid_only: bool


@dataclass(frozen=True)
class KernelFusibility:
    """Per-kernel fusion facts exported by the middle-end.

    ``elementwise`` is the DAG optimizer's admission test: a 1-D,
    loop-free, user-barrier-free kernel with no LOCAL arrays whose every
    global-buffer access indexes at ``global_id(0)`` — i.e. a pure map
    over the NDRange where work-item *i* touches exactly element *i* of
    every buffer.  ``reasons`` names the first facts that broke the
    classification (for ``dag_stats``/debugging); ``footprints`` carries
    the per-parameter access counts the buffer-elision decision reads."""

    elementwise: bool
    reasons: Tuple[str, ...]
    footprints: Tuple[BufferFootprint, ...]

    def footprint(self, name: str) -> Optional[BufferFootprint]:
        for f in self.footprints:
            if f.name == name:
                return f
        return None


def kernel_fusibility(fn: Function) -> KernelFusibility:
    """Compute :class:`KernelFusibility` for ``fn``.

    Works on both the raw builder IR and the post-pipeline CFG: the
    facts it reads (``global_id`` instrs, load/store buffer attrs, user
    barriers, natural loops) survive every pass unchanged."""
    reasons: List[str] = []
    if fn.ndim != 1:
        reasons.append(f"ndim={fn.ndim}")
    if any(a.space == LOCAL for a in fn.buffer_args):
        reasons.append("local-array")
    if fn.natural_loops():
        reasons.append("loop")
    # SSA ids of values that *are* the work-item's global_id(0)
    gid_ids: Set[int] = set()
    for blk in fn.blocks.values():
        for ins in blk.instrs:
            if ins.op == "global_id" and int(ins.attrs.get("dim", 0)) == 0 \
                    and ins.result is not None:
                gid_ids.add(ins.result.id)
            elif ins.op == "barrier" and not ins.attrs.get("implicit"):
                if "user-barrier" not in reasons:
                    reasons.append("user-barrier")
    loads: Dict[str, int] = {}
    stores: Dict[str, int] = {}
    gid_ok: Dict[str, bool] = {}
    for blk in fn.blocks.values():
        for ins in blk.instrs:
            if ins.op not in ("load", "store"):
                continue
            buf = str(ins.attrs.get("buffer"))
            idx = ins.operands[0]
            at_gid = getattr(idx, "id", None) in gid_ids
            gid_ok[buf] = gid_ok.get(buf, True) and at_gid
            if ins.op == "load":
                loads[buf] = loads.get(buf, 0) + 1
            else:
                stores[buf] = stores.get(buf, 0) + 1
    fps = tuple(BufferFootprint(
        name=a.name, space=a.space,
        loads=loads.get(a.name, 0), stores=stores.get(a.name, 0),
        gid_only=gid_ok.get(a.name, True)) for a in fn.buffer_args)
    for f in fps:
        if f.space in (GLOBAL, CONSTANT) and not f.gid_only:
            reasons.append(f"non-gid-access:{f.name}")
    return KernelFusibility(elementwise=not reasons,
                            reasons=tuple(reasons), footprints=fps)


# ---------------------------------------------------------------------------
# WorkGroupPlan — the shared target-independent product
# ---------------------------------------------------------------------------

@dataclass
class WorkGroupPlan:
    """Everything the middle-end knows about a kernel that is independent
    of the execution target (and of the local size — lane counts are bound
    at target-construction time).  One plan is computed per canonical
    kernel IR + plan options and shared by all target mappings; it is the
    unit of stage-level caching (:class:`repro.core.cache.PlanKey`)."""

    fn: Function                            # transformed (phi-free) CFG
    wg: WGInfo                              # regions + schedule (§4.3)
    uni: object                             # Uniformity | AllVarying (§4.6)
    ctx: ContextPlan                        # context slots (§4.7)
    region_plans: Dict[str, List[object]]   # structured region exec plans
    md: Dict[str, ParallelRegionMD]         # §4 parallelism metadata
    options: Tuple[Tuple[str, object], ...]  # (horizontal, merge_uniform)
    pass_times: Dict[str, float] = field(default_factory=dict)
    fusibility: Optional[KernelFusibility] = None   # DAG-fusion facts

    @property
    def order(self) -> List[str]:
        return self.wg.order

    def rid_of(self) -> Dict[str, int]:
        return {b: i for i, b in enumerate(self.wg.order)}

    def describe(self) -> str:
        # slot names for SSA values embed the process-global value counter;
        # rename to the same first-reference indices canonical_ir prints
        # with, so descriptions are stable and match the IR dumps
        from .cache import canonical_value_names
        canon = canonical_value_names(self.fn)
        slots = []
        for s in self.ctx.slots:
            name = canon.get(s.key, s.name) if s.kind == "val" else s.name
            slots.append((name, s.dtype,
                          "uniform" if s.uniform else "per-wi"))
        lines = [f"plan for {self.fn.name!r} "
                 f"({dict(self.options)}):",
                 f"  schedule: {self.wg.order} "
                 f"chain={self.wg.is_chain()}"]
        for bar in self.wg.order:
            lines.append("  " + self.md[bar].describe())
        lines.append(f"  context slots: {slots}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Pass + PassManager
# ---------------------------------------------------------------------------

@dataclass
class PipelineState:
    """Mutable state threaded through the passes: the CFG plus the
    analysis artifacts later passes consume."""

    fn: Function
    options: Dict[str, object]
    props: Set[str] = field(default_factory=set)
    wg: Optional[WGInfo] = None
    uni: Optional[object] = None
    ctx: Optional[ContextPlan] = None
    region_plans: Optional[Dict[str, List[object]]] = None
    md: Optional[Dict[str, ParallelRegionMD]] = None
    fusibility: Optional[KernelFusibility] = None


@dataclass(frozen=True)
class Pass:
    """A named pipeline stage.

    ``requires``     properties that must hold on entry (established by
                     earlier passes) — enforced by the manager.
    ``establishes``  properties guaranteed on exit; the verifier checks
                     the structural ones after every subsequent pass.
    ``invalidates``  properties this pass may break (the manager drops
                     them before running it).
    ``mutates_cfg``  whether the pass rewrites ``state.fn`` (dump hooks
                     re-print the IR only for these).
    """

    name: str
    run: Callable[[PipelineState], None]
    requires: Tuple[str, ...] = ()
    establishes: Tuple[str, ...] = ()
    invalidates: Tuple[str, ...] = ()
    mutates_cfg: bool = True
    paper: str = ""


def _p_normalize(st: PipelineState) -> None:
    normalize(st.fn)


def _p_inject_loop_barriers(st: PipelineState) -> None:
    inject_loop_barriers(st.fn)


def _p_out_of_ssa(st: PipelineState) -> None:
    out_of_ssa(st.fn)


def _p_horizontal(st: PipelineState) -> None:
    if not st.options.get("horizontal", True):
        return
    from .horizontal import horizontal_candidates  # cycle-free import
    cands = horizontal_candidates(st.fn)
    if cands:
        inject_loop_barriers(st.fn, extra_loop_headers=cands)


def _p_tail_duplicate(st: PipelineState) -> None:
    tail_duplicate(st.fn)


def _p_form_regions(st: PipelineState) -> None:
    st.wg = form_regions(st.fn)


def _p_uniformity(st: PipelineState) -> None:
    # the paper's no-uniformity baseline treats everything as varying;
    # options mirror the pre-refactor behaviour where horizontal=False
    # also disabled the analysis
    if st.options.get("horizontal", True):
        st.uni = analyze(st.fn)
    else:
        st.uni = AllVarying()


def _p_fold_constants(st: PipelineState) -> None:
    fold_constants(st.fn)


def _p_context_planning(st: PipelineState) -> None:
    st.ctx = build_context_plan(
        st.wg, st.uni,
        merge_uniform=bool(st.options.get("merge_uniform", True)))


def _p_structure_regions(st: PipelineState) -> None:
    st.region_plans = {
        bar: structure_region(st.fn, r.entry, r.blocks)
        for bar, r in st.wg.regions.items() if r.entry is not None}


def _p_annotate_md(st: PipelineState) -> None:
    st.md = _region_md(st.fn, st.wg, st.uni)


def _p_annotate_fusibility(st: PipelineState) -> None:
    st.fusibility = kernel_fusibility(st.fn)


DEFAULT_PASSES: Tuple[Pass, ...] = (
    Pass("normalize", _p_normalize,
         establishes=("single-exit", "barriers-isolated"),
         paper="§4.3 Alg. 1 step 1"),
    Pass("inject_loop_barriers", _p_inject_loop_barriers,
         requires=("single-exit", "barriers-isolated"),
         paper="§4.5"),
    Pass("out_of_ssa", _p_out_of_ssa,
         requires=("barriers-isolated",),
         establishes=("phi-free",),
         paper="§4.7 prep"),
    Pass("horizontal", _p_horizontal,
         requires=("phi-free",),
         paper="§4.6"),
    # duplicating a tail that reaches the exit duplicates the Return —
    # single-exit legitimately dies here (regions handle multiple exits)
    Pass("tail_duplicate", _p_tail_duplicate,
         requires=("phi-free", "barriers-isolated"),
         establishes=("barrier-tails-unique",),
         invalidates=("single-exit",),
         paper="§4.3 Alg. 2"),
    # analysis products ("regions-formed", "uniformity-known",
    # "context-planned") are modelled as properties too, so a misordered
    # custom pipeline fails the requires check with a VerifierError naming
    # the pass, not an unattributed AttributeError on a missing artifact
    Pass("form_regions", _p_form_regions,
         requires=("barrier-tails-unique",),
         establishes=("regions-formed",),
         mutates_cfg=False, paper="§4.3 Def. 1"),
    Pass("uniformity", _p_uniformity,
         requires=("phi-free",),
         establishes=("uniformity-known",),
         mutates_cfg=False, paper="§4.6"),
    Pass("fold_constants", _p_fold_constants,
         requires=("phi-free",),
         paper="§4.7 (constant rematerialization)"),
    Pass("context_planning", _p_context_planning,
         requires=("phi-free", "regions-formed", "uniformity-known"),
         establishes=("context-planned",),
         mutates_cfg=False, paper="§4.7"),
    Pass("structure_regions", _p_structure_regions,
         requires=("regions-formed",),
         mutates_cfg=False, paper="§4.4 (region scheduling prep)"),
    Pass("annotate_parallel_md", _p_annotate_md,
         requires=("regions-formed", "uniformity-known"),
         mutates_cfg=False,
         paper="§4 (llvm.mem.parallel_loop_access analogue)"),
    Pass("annotate_fusibility", _p_annotate_fusibility,
         requires=("regions-formed",),
         mutates_cfg=False,
         paper="§4 (parallelism facts consumed by later generic passes)"),
)


def _env_verify() -> bool:
    return os.environ.get("REPRO_VERIFY_IR", "") not in ("", "0", "false")


class PassManager:
    """Runs a pass pipeline over a kernel CFG and assembles the
    :class:`WorkGroupPlan`.

    ``verify``   run :func:`verify_ir` after every pass, checking all
                 properties established so far (default: the
                 ``REPRO_VERIFY_IR`` environment variable).
    ``on_pass``  hook called as ``on_pass(pass_obj, state)`` after each
                 pass — the dump/golden-test surface.
    ``timings``  per-pass wall-clock seconds of the last ``run``.
    """

    def __init__(self, passes: Sequence[Pass] = DEFAULT_PASSES,
                 verify: Optional[bool] = None,
                 on_pass: Optional[Callable[[Pass, PipelineState],
                                            None]] = None):
        self.passes: Tuple[Pass, ...] = tuple(passes)
        self.verify = _env_verify() if verify is None else bool(verify)
        self.on_pass = on_pass
        self.timings: Dict[str, float] = {}

    def pass_names(self) -> List[str]:
        return [p.name for p in self.passes]

    def run(self, fn: Function, horizontal: bool = True,
            merge_uniform: bool = True) -> WorkGroupPlan:
        global _plans_built
        with _plans_lock:
            _plans_built += 1
        st = PipelineState(fn, {"horizontal": bool(horizontal),
                                "merge_uniform": bool(merge_uniform)})
        self.timings = {}
        for p in self.passes:
            missing = [r for r in p.requires if r not in st.props]
            if missing:
                raise VerifierError(
                    p.name, f"pass requires {missing} but only "
                            f"{sorted(st.props)} are established")
            for prop in p.invalidates:
                st.props.discard(prop)
            t0 = time.perf_counter()
            p.run(st)
            self.timings[p.name] = time.perf_counter() - t0
            st.props.update(p.establishes)
            if self.verify:
                verify_ir(st.fn, sorted(st.props), pass_name=p.name)
            if self.on_pass is not None:
                self.on_pass(p, st)
        assert st.wg is not None and st.uni is not None \
            and st.ctx is not None and st.region_plans is not None \
            and st.md is not None, "pipeline did not produce a full plan"
        return WorkGroupPlan(
            fn=st.fn, wg=st.wg, uni=st.uni, ctx=st.ctx,
            region_plans=st.region_plans, md=st.md,
            options=(("horizontal", bool(horizontal)),
                     ("merge_uniform", bool(merge_uniform))),
            pass_times=dict(self.timings),
            fusibility=st.fusibility)


def build_plan(fn: Function, horizontal: bool = True,
               merge_uniform: bool = True,
               verify: Optional[bool] = None,
               on_pass: Optional[Callable] = None) -> WorkGroupPlan:
    """Run the default pipeline on ``fn`` (mutating it) and return the
    shared target-independent :class:`WorkGroupPlan`."""
    pm = PassManager(verify=verify, on_pass=on_pass)
    return pm.run(fn, horizontal=horizontal, merge_uniform=merge_uniform)
