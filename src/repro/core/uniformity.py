"""Uniformity / divergence analysis (paper §4.6, §4.7).

A value is *uniform* when it is known to hold the same value for every
work-item in the work-group; the analysis "resolves the origin of the
variables ... until a known uniform root is found" (§4.6).  Uniform roots:
constants, kernel (scalar) arguments, ``group_id``/``local_size``/
``num_groups``/``global_size``.  Varying roots: ``local_id``/``global_id``
and (conservatively) non-constant memory loads.

Divergence propagates through *control dependence*: a value computed in a
block whose execution is controlled by a varying branch is varying even if
its operands are uniform.  We compute control dependence from the
post-dominator tree (Ferrante et al.), which is the precision the paper needs
to prove §4.6 loop-entry predicates WI-invariant.

Runs on the phi-free (post out-of-SSA) CFG: virtual registers are uniform
iff every write is uniform and every writing block has a uniform predicate.
"""

from __future__ import annotations

from typing import Dict, List, Set

from . import ir
from .ir import CondBranch, Function, Value

UNIFORM_ID_OPS = {"group_id", "local_size", "num_groups", "global_size"}
VARYING_ID_OPS = {"local_id", "global_id"}


def postdominators(fn: Function) -> Dict[str, Set[str]]:
    """Post-dominator sets over the reversed CFG with a virtual exit."""
    exits = fn.exit_blocks()
    names = fn.rpo()
    succs = {n: fn.blocks[n].successors() for n in names}
    VEXIT = "__vexit__"
    rsuccs: Dict[str, List[str]] = {n: [] for n in names}
    rsuccs[VEXIT] = list(exits)
    preds_rev: Dict[str, List[str]] = {n: [] for n in names + [VEXIT]}
    for n in names:
        for s in succs[n]:
            preds_rev[n].append(s)  # reversed edge s -> n means pred_rev[n]+=[s]
    for e in exits:
        preds_rev[e].append(VEXIT)
    allb = set(names) | {VEXIT}
    pdom: Dict[str, Set[str]] = {n: set(allb) for n in allb}
    pdom[VEXIT] = {VEXIT}
    changed = True
    while changed:
        changed = False
        for n in names:  # any order; iterate to fixpoint
            ps = preds_rev[n]
            new = set(allb)
            for p in ps:
                new &= pdom[p]
            if not ps:
                new = set()
            new |= {n}
            if new != pdom[n]:
                pdom[n] = new
                changed = True
    return pdom


def control_deps(fn: Function) -> Dict[str, Set[str]]:
    """block -> set of CondBranch blocks it is control-dependent on."""
    pdom = postdominators(fn)
    cd: Dict[str, Set[str]] = {n: set() for n in fn.blocks}
    for c, blk in fn.blocks.items():
        if not isinstance(blk.terminator, CondBranch):
            continue
        for s in blk.terminator.successors():
            # blocks post-dominating s but not post-dominating c are CD on c
            for b in fn.blocks:
                if b == c:
                    continue
                if b in pdom.get(s, set()) and b not in pdom.get(c, set()):
                    cd[b].add(c)
    return cd


class AllVarying:
    """Degraded uniformity used when the §4.6 analysis is disabled: every
    value is treated as work-item-variant (the paper's no-pass baseline).
    Drop-in for :class:`Uniformity` in the context planner and targets."""

    def value_uniform(self, v) -> bool:
        return False

    def value_id_uniform(self, vid) -> bool:
        return False

    def vreg_uniform(self, name) -> bool:
        return False

    def block_uniform(self, name) -> bool:
        return False


class Uniformity:
    def __init__(self, varying_values: Set[int], varying_vregs: Set[str],
                 varying_blocks: Set[str]):
        self._vals = varying_values
        self._vregs = varying_vregs
        self._blocks = varying_blocks

    def value_uniform(self, v) -> bool:
        if not isinstance(v, Value):
            return True  # constants
        return v.id not in self._vals

    def value_id_uniform(self, vid: int) -> bool:
        return vid not in self._vals

    def vreg_uniform(self, name: str) -> bool:
        return name not in self._vregs

    def block_uniform(self, name: str) -> bool:
        return name not in self._blocks


def analyze(fn: Function) -> Uniformity:
    cd = control_deps(fn)
    cond_of: Dict[str, Value] = {}
    for n, blk in fn.blocks.items():
        if isinstance(blk.terminator, CondBranch):
            c = blk.terminator.cond
            if isinstance(c, Value):
                cond_of[n] = c

    varying_vals: Set[int] = set()
    varying_vregs: Set[str] = set()
    varying_blocks: Set[str] = set()

    def block_varying(n: str) -> bool:
        for c in cd.get(n, ()):
            cv = cond_of.get(c)
            if cv is not None and cv.id in varying_vals:
                return True
        return False

    changed = True
    while changed:
        changed = False
        for n in fn.rpo():
            blk = fn.blocks[n]
            bv = block_varying(n)
            if bv and n not in varying_blocks:
                varying_blocks.add(n)
                changed = True
            for insn in blk.instrs:
                var = False
                if insn.op in VARYING_ID_OPS:
                    var = True
                elif insn.op == "load":
                    # uniform only for constant-space loads at uniform index
                    idx = insn.operands[0]
                    uni_idx = not (isinstance(idx, Value)
                                   and idx.id in varying_vals)
                    var = not (insn.attrs.get("space") == ir.CONSTANT
                               and uni_idx)
                elif insn.op == "vreg_read":
                    var = insn.attrs["vreg"] in varying_vregs
                else:
                    var = any(isinstance(o, Value) and o.id in varying_vals
                              for o in insn.operands)
                # control dependence taints everything computed here
                var = var or bv
                if insn.op == "vreg_write":
                    if var and insn.attrs["vreg"] not in varying_vregs:
                        varying_vregs.add(insn.attrs["vreg"])
                        changed = True
                elif insn.result is not None:
                    if var and insn.result.id not in varying_vals:
                        varying_vals.add(insn.result.id)
                        changed = True
    return Uniformity(varying_vals, varying_vregs, varying_blocks)
