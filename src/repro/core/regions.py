"""Parallel region formation (paper §4.3–§4.5).

Pipeline (mirrors pocl's work-group function generation):

1. ``normalize``        — single exit; implicit entry/exit barriers
                          (Algorithm 1, step 1); each barrier in its own block.
2. ``inject_loop_barriers`` — §4.5 implicit barriers for loops containing
                          barriers (b-loops): end of pre-header, before the
                          latch branch, after the header phi region.
3. ``out_of_ssa``       — phis become virtual registers (``vreg_read`` /
                          ``vreg_write``).  This is the IR realization of the
                          paper's *context data arrays* (§4.7): a vreg that
                          lives across parallel regions becomes a per-WI
                          context slot downstream.
4. ``tail_duplicate``   — Algorithm 2: replicate the tail sub-CFG of every
                          loop-free conditional barrier until every non-loop
                          barrier has a single immediate predecessor barrier
                          in the Barrier CFG (Definition 1 / Proposition 1).
5. ``form_regions``     — emit ``Region`` objects (single-entry sub-CFGs
                          between barriers) plus the region schedule graph.

Deviation noted in DESIGN.md: conditional barriers *inside* natural loops are
exempt from tail duplication; the run-time region scheduler (a uniform
switch, the analogue of the paper's peeled first work-item, §4.4/Fig. 7)
dispatches them dynamically.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from . import ir
from .ir import (
    Function, Instr, create_subgraph, ensure_single_exit, replicate_cfg, split_at_barriers)

ENTRY_BARRIER = "__entry_barrier__"


# ---------------------------------------------------------------------------
# Step 1: normalization (Algorithm 1, step 1)
# ---------------------------------------------------------------------------

def normalize(fn: Function) -> None:
    fn.prune_unreachable()
    exit_name = ensure_single_exit(fn)
    # implicit barrier at the entry node
    entry_blk = fn.blocks[fn.entry]
    entry_blk.instrs.insert(0, Instr("barrier", [], None,
                                     {"implicit": "entry"}))
    # implicit barrier at the exit node
    exit_blk = fn.blocks[exit_name]
    exit_blk.instrs.append(Instr("barrier", [], None, {"implicit": "exit"}))
    split_at_barriers(fn)
    fn.verify()


def barrier_blocks(fn: Function) -> List[str]:
    return [n for n, b in fn.blocks.items() if b.has_barrier()]


# ---------------------------------------------------------------------------
# Step 2: b-loop implicit barriers (§4.5)
# ---------------------------------------------------------------------------

def inject_loop_barriers(fn: Function, extra_loop_headers: Optional[Set[str]] = None) -> int:
    """Add the three §4.5 implicit barriers around every loop that contains a
    barrier.  ``extra_loop_headers`` lets the horizontal-parallelization pass
    (§4.6) force barrier treatment onto barrier-free loops.  Returns the
    number of loops processed.  Iterates until a fixpoint (outer loops whose
    bodies gained barriers become b-loops themselves)."""
    extra = set(extra_loop_headers or ())
    total = 0
    for _ in range(64):  # fixpoint cap; loop nests are shallow
        processed = _inject_once(fn, extra)
        extra = set()
        total += processed
        if processed == 0:
            break
    return total


def _inject_once(fn: Function, extra_headers: Set[str]) -> int:
    done: Set[str] = getattr(fn, "_bloop_done", set())
    fn._bloop_done = done  # type: ignore[attr-defined]
    loops = fn.natural_loops()
    preds = fn.predecessors()
    count = 0
    for header, body in loops:
        has_bar = any(fn.blocks[b].has_barrier() for b in body)
        if not (has_bar or header in extra_headers):
            continue
        if header in done:
            continue  # already processed
        done.add(header)
        hdr = fn.blocks[header]
        count += 1
        latches = [p for p in preds[header] if p in body]
        pre = [p for p in preds[header] if p not in body]
        assert pre, f"loop {header} has no pre-header"
        # 1. end of the loop pre-header block(s)
        for p in pre:
            blk = fn.blocks[p]
            if not (blk.instrs and blk.instrs[-1].op == "barrier"):
                blk.instrs.append(Instr("barrier", [], None,
                                        {"implicit": "bloop-pre"}))
        # 2. before the loop latch branch
        for l in latches:
            blk = fn.blocks[l]
            if not (blk.instrs and blk.instrs[-1].op == "barrier"):
                blk.instrs.append(Instr("barrier", [], None,
                                        {"implicit": "bloop-latch"}))
        # 3. after the phi-node region of the loop header (post out-of-SSA the
        # "phi region" is the leading run of vreg_read instructions)
        pos = 0
        while pos < len(hdr.instrs) and hdr.instrs[pos].op == "vreg_read":
            pos += 1
        hdr.instrs.insert(pos, Instr("barrier", [], None,
                                     {"implicit": "bloop-header"}))
    if count:
        split_at_barriers(fn)
        fn.verify()
    return count


# ---------------------------------------------------------------------------
# Step 3: out-of-SSA — phis to virtual registers
# ---------------------------------------------------------------------------

def out_of_ssa(fn: Function) -> None:
    preds = fn.predecessors()
    for name in list(fn.blocks.keys()):
        blk = fn.blocks[name]
        if not blk.phis:
            continue
        reads: List[Instr] = []
        for phi in blk.phis:
            vreg = f"r.{phi.result.name}"
            # parallel-copy writes at the end of each predecessor block
            for pred, val in phi.incomings.items():
                pblk = fn.blocks[pred]
                pblk.instrs.append(
                    Instr("vreg_write", [val], None,
                          {"vreg": vreg, "dtype": phi.result.dtype}))
            reads.append(Instr("vreg_read", [], phi.result,
                               {"vreg": vreg, "dtype": phi.result.dtype}))
        blk.phis = []
        blk.instrs[0:0] = reads
    # phi-incoming writes may have landed after a barrier in a barrier block;
    # re-split so barriers stay alone in their blocks.
    split_at_barriers(fn)
    fn.verify()


# ---------------------------------------------------------------------------
# Barrier CFG (Definition 1) and classification
# ---------------------------------------------------------------------------

def build_barrier_cfg(fn: Function) -> Dict[str, List[str]]:
    """Edges between barrier blocks when a no-barrier path connects them.
    Terminal barriers (implicit exit barriers) have no successors."""
    bcfg: Dict[str, List[str]] = {}
    for b in barrier_blocks(fn):
        succs: List[str] = []
        seen: Set[str] = set()
        stack = list(fn.blocks[b].successors())
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            if fn.blocks[n].has_barrier():
                if n not in succs:
                    succs.append(n)
                continue
            stack.extend(fn.blocks[n].successors())
        bcfg[b] = sorted(succs)
    return bcfg


def entry_barrier(fn: Function) -> str:
    """The implicit entry barrier block (first barrier from function entry)."""
    n = fn.entry
    while not fn.blocks[n].has_barrier():
        succ = fn.blocks[n].successors()
        assert len(succ) == 1, "pre-barrier entry code must be straight-line"
        n = succ[0]
    return n


def immediate_pred_barriers(fn: Function) -> Dict[str, List[str]]:
    bcfg = build_barrier_cfg(fn)
    preds: Dict[str, List[str]] = {b: [] for b in bcfg}
    for b, succs in bcfg.items():
        for s in succs:
            preds[s].append(b)
    return preds


def conditional_barriers(fn: Function) -> Set[str]:
    """Barriers that do not dominate every exit block (paper §4.3)."""
    dom = fn.dominators()
    exits = fn.exit_blocks()
    out: Set[str] = set()
    for b in barrier_blocks(fn):
        if not all(b in dom.get(e, set()) for e in exits):
            out.add(b)
    return out


def _loop_blocks(fn: Function) -> Set[str]:
    s: Set[str] = set()
    for _, body in fn.natural_loops():
        s |= body
    return s


# ---------------------------------------------------------------------------
# Step 4: tail duplication (Algorithm 2)
# ---------------------------------------------------------------------------

def tail_duplicate(fn: Function, max_iters: int = 256) -> int:
    """Replicate the tail of each loop-free conditional barrier until every
    loop-free barrier has at most one immediate predecessor barrier.  Returns
    the number of replications performed."""
    n_dup = 0
    suffix = 0
    for _ in range(max_iters):
        in_loop = _loop_blocks(fn)
        preds = immediate_pred_barriers(fn)
        cond = conditional_barriers(fn)
        # find a barrier with >=2 immediate predecessor barriers whose
        # ambiguity comes from a loop-free conditional barrier predecessor
        target: Optional[str] = None
        for b in fn.rpo():
            if b not in preds or len(preds[b]) < 2 or b in in_loop:
                continue
            culprits = [p for p in preds[b] if p in cond and p not in in_loop]
            if culprits:
                target = culprits[0]
                break
        if target is None:
            return n_dup
        # tail = everything reachable from the conditional barrier (CreateSubgraph
        # from the barrier to the exit nodes), excluding the barrier itself
        tail = create_subgraph(fn, target, set())
        if not tail:
            return n_dup
        suffix += 1
        mapping = replicate_cfg(fn, tail, f"t{suffix}")
        # redirect the conditional barrier's out-edges into the fresh copy
        term = fn.blocks[target].terminator
        fn.blocks[target].terminator = term.replace(mapping)
        fn.prune_unreachable()
        # stale phi incomings (none expected post out-of-ssa) and verify
        ir.remap_phi_preds(fn)
        fn.verify()
        n_dup += 1
    raise RuntimeError("tail duplication did not converge")


# ---------------------------------------------------------------------------
# Step 5: region formation
# ---------------------------------------------------------------------------

@dataclass
class Region:
    """A parallel region: single-entry sub-CFG between barriers (§4.3).

    ``barrier``  — the barrier block this region starts *after*;
    ``entry``    — first block of the region (successor of the barrier);
    ``blocks``   — region block set (no barrier blocks);
    ``exits``    — successor barrier blocks, in deterministic order.
    A terminal region has no exits (runs to Return).
    """

    barrier: str
    entry: Optional[str]
    blocks: Set[str]
    exits: List[str]
    attrs: Dict[str, object] = field(default_factory=dict)


@dataclass
class WGInfo:
    """The work-group function plan: regions + schedule over barrier ids."""

    fn: Function
    regions: Dict[str, Region]          # keyed by barrier block name
    order: List[str]                    # barrier blocks, entry first (RPO)
    entry: str                          # entry barrier block

    def is_chain(self) -> bool:
        """True if the schedule is a straight line (no cycles/branches)."""
        seen = set()
        cur = self.entry
        while True:
            if cur in seen:
                return False
            seen.add(cur)
            ex = self.regions[cur].exits
            if len(ex) == 0:
                return len(seen) == len(self.regions)
            if len(ex) != 1:
                return False
            cur = ex[0]

    def chain(self) -> List[str]:
        out = [self.entry]
        while self.regions[out[-1]].exits:
            out.append(self.regions[out[-1]].exits[0])
        return out


def form_regions(fn: Function) -> WGInfo:
    regions: Dict[str, Region] = {}
    bars = barrier_blocks(fn)
    for b in bars:
        succ = fn.blocks[b].successors()
        assert len(succ) <= 1, "barrier blocks are straight-line"
        if not succ:  # barrier immediately followed by nothing (shouldn't happen)
            regions[b] = Region(b, None, set(), [])
            continue
        entry = succ[0]
        blocks: Set[str] = set()
        exits: List[str] = []
        stack = [entry]
        while stack:
            n = stack.pop()
            if fn.blocks[n].has_barrier():
                if n not in exits:
                    exits.append(n)
                continue
            if n in blocks:
                continue
            blocks.add(n)
            stack.extend(fn.blocks[n].successors())
        regions[b] = Region(b, entry, blocks, sorted(exits))
    # barrier order: RPO restricted to barrier blocks
    order = [n for n in fn.rpo() if n in regions]
    ent = entry_barrier(fn)
    order.remove(ent)
    order.insert(0, ent)
    return WGInfo(fn, regions, order, ent)


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------

def lower_to_regions(fn: Function,
                     horizontal: bool = True) -> WGInfo:
    """Compatibility wrapper: run the full pass-manager pipeline
    (:mod:`repro.core.passes`) and return the region product only.

    Note two differences from the pre-pass-manager version: ``fn`` is
    mutated slightly further (``fold_constants`` deletes ``const``
    instructions and inlines their literals), and the full plan —
    uniformity facts, context slots, structured region plans, metadata —
    is computed and discarded.  Callers that want the plan (every target
    does) should use :func:`repro.core.passes.build_plan` instead."""
    from .passes import build_plan  # cycle-free import

    return build_plan(fn, horizontal=horizontal).wg
