"""DAG-level kernel fusion: IR stitching for producer→consumer chains.

The paper's §4 metadata (:class:`~repro.core.passes.ParallelRegionMD`,
the ``llvm.mem.parallel_loop_access`` analogue) exists so that *later
generic passes* can exploit data-parallelism the source level has lost.
This module is such a pass, operating one level above the kernel
compiler: given a chain of elementwise kernels enqueued back-to-back on
one queue — each a pure map where work-item *i* touches exactly element
*i* of every buffer — it composes ONE stitched :class:`~repro.core.ir.
Function` by concatenating the kernels' CFGs and *value-forwarding* the
producer's store into the consumer's load (docs/compiler.md §Fusion):

* each segment's blocks are renamed ``k<i>_…`` and its ``Return`` is
  replaced by a ``Jump`` to the next segment's entry;
* buffer parameters bound to the *same* Buffer object across segments
  collapse into one fused parameter (scalars stay per-segment);
* for every chain edge, the producer's single store to the chained
  buffer defines an SSA value that replaces every consumer load of that
  buffer — legal because both sides index at ``global_id(0)``
  (:class:`~repro.core.passes.BufferFootprint.gid_only`), so the
  forwarding is per-lane exact;
* an *elided* edge additionally deletes the store and drops the buffer
  from the fused signature — the intermediate is never allocated (lazy
  pool-backed buffers, docs/memory.md) and never written back.

The stitched function is checked by :func:`~repro.core.passes.verify_ir`
and wrapped in a :class:`~repro.core.program.Program`, so it flows
through the ordinary plan tier and device compilation caches; the
:class:`FusedSpec` produced here is itself cached under a structural
:class:`~repro.core.cache.FusedKey`, making steady-state fusion of a
repeated chain one dict lookup (docs/caching.md §Fused-chain caching).

The legality analysis (which enqueued commands may chain, which edges
may elide) lives with the DAG pattern-matcher in
:mod:`repro.runtime.queue`; this module provides the per-kernel
admission test (:func:`fusible_kernel`) and the pure IR surgery, so it
is testable without a runtime in sight.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .cache import CompilationCache, FusedKey, ir_hash
from .errors import BuildError, register_error
from .ir import (BufferArg, Function, Jump, LOCAL, Return, ScalarArg,
                 Value)
from .passes import (KernelFusibility, WorkGroupPlan, kernel_fusibility,
                     verify_ir)
from .program import Program


@register_error
class FusionError(BuildError):
    """A chain that passed the DAG matcher failed IR stitching — always
    a bug in the legality analysis, surfaced typed so the queue can fall
    back to unfused execution instead of corrupting results."""

    code = -9997
    code_name = "REPRO_FUSION_FAILED"


@dataclass(frozen=True)
class ChainEdge:
    """One forwarded buffer between two adjacent chain segments."""

    producer: int        # segment index writing the buffer
    consumer: int        # segment index (producer + 1) reading it
    prod_arg: str        # parameter name in the producer's signature
    cons_arg: str        # parameter name in the consumer's signature
    elide: bool          # drop the store + the fused parameter entirely


def fusible_kernel(plan_or_fn) -> bool:
    """Admission test for one kernel: elementwise per the middle-end's
    :class:`~repro.core.passes.KernelFusibility` facts, and — when a
    :class:`~repro.core.passes.WorkGroupPlan` is given — every region's
    :class:`~repro.core.passes.ParallelRegionMD` proves ``wi_parallel``
    (no region may carry cross-work-item dependencies the forwarding
    would reorder)."""
    if isinstance(plan_or_fn, WorkGroupPlan):
        facts = plan_or_fn.fusibility
        if facts is None:
            facts = kernel_fusibility(plan_or_fn.fn)
        if not all(m.wi_parallel for m in plan_or_fn.md.values()):
            return False
        return facts.elementwise
    facts = plan_or_fn if isinstance(plan_or_fn, KernelFusibility) \
        else kernel_fusibility(plan_or_fn)
    return facts.elementwise


def _single_return_block(fn: Function, seg: int) -> str:
    exits = fn.exit_blocks()
    if len(exits) != 1:
        raise FusionError(
            f"fusion segment {seg} ({fn.name!r}) has {len(exits)} return "
            f"blocks; elementwise kernels are straight-line")
    return exits[0]


def stitch_functions(fns: Sequence[Function],
                     edges: Sequence[ChainEdge],
                     alias_groups: Sequence[Sequence[Tuple[int, str]]],
                     name: Optional[str] = None
                     ) -> Tuple[Function, Dict[Tuple[int, str], str],
                                Dict[Tuple[int, str], str]]:
    """Compose one stitched Function from ``fns`` (chain order).

    ``alias_groups`` lists the (segment, arg-name) pairs bound to one
    buffer object; each group becomes a single fused parameter named
    after its first member (``k<seg>_<arg>`` — deterministic, so the
    canonical IR hash of the stitched function is stable across
    processes).  Returns ``(fused_fn, buffer_map, scalar_map)`` where
    the maps take ``(segment, original_name)`` to the fused parameter
    name (elided parameters are absent from ``buffer_map``).

    The input functions are mutated (renamed in place); callers pass
    freshly built IR, exactly as the compilation pipeline does.
    """
    if len(fns) < 2:
        raise FusionError("a fusion chain needs at least 2 kernels")
    fused_name = name or ("fused__" + "__".join(f.name for f in fns))
    for i, fn in enumerate(fns):
        facts = kernel_fusibility(fn)
        if not facts.elementwise:
            raise FusionError(
                f"fusion segment {i} ({fn.name!r}) is not elementwise: "
                f"{list(facts.reasons)}")

    # -- fused parameter names --------------------------------------------------
    group_of: Dict[Tuple[int, str], str] = {}
    for grp in alias_groups:
        members = sorted(grp)
        fname = f"k{members[0][0]}_{members[0][1]}"
        for m in members:
            group_of[tuple(m)] = fname
    buffer_map: Dict[Tuple[int, str], str] = {}
    scalar_map: Dict[Tuple[int, str], str] = {}
    fused = Function(fused_name, ndim=1)
    fused.blocks = {}
    seen_params: Dict[str, BufferArg] = {}
    for i, fn in enumerate(fns):
        for a in fn.buffer_args:
            if a.space == LOCAL:
                raise FusionError(
                    f"segment {i} has LOCAL array {a.name!r}")
            fname = group_of.get((i, a.name), f"k{i}_{a.name}")
            prev = seen_params.get(fname)
            if prev is None:
                arg = BufferArg(fname, a.dtype, a.space, a.size)
                seen_params[fname] = arg
                fused.buffer_args.append(arg)
            elif prev.dtype != a.dtype:
                raise FusionError(
                    f"aliased parameter {fname!r} bound with dtypes "
                    f"{prev.dtype} and {a.dtype}")
            buffer_map[(i, a.name)] = fname
        for a in fn.scalar_args:
            fname = f"k{i}_{a.name}"
            fused.scalar_args.append(ScalarArg(fname, a.dtype))
            fused.arg_values[fname] = fn.arg_values[a.name]
            scalar_map[(i, a.name)] = fname

    # -- rename + concatenate the CFGs ------------------------------------------
    entries: List[str] = []
    exits: List[str] = []
    for i, fn in enumerate(fns):
        exits.append(f"k{i}_{_single_return_block(fn, i)}")
        bmap = {n: f"k{i}_{n}" for n in fn.blocks}
        for old, blk in list(fn.blocks.items()):
            blk.name = bmap[old]
            blk.terminator = blk.terminator.replace(bmap)
            for phi in blk.phis:
                phi.incomings = {bmap.get(p, p): v
                                 for p, v in phi.incomings.items()}
            for ins in blk.instrs:
                if ins.op in ("load", "store"):
                    ins.attrs = dict(ins.attrs)
                    ins.attrs["buffer"] = buffer_map[
                        (i, str(ins.attrs["buffer"]))]
            fused.blocks[blk.name] = blk
        entries.append(f"k{i}_{fn.entry}")
    fused.entry = entries[0]
    for i in range(len(fns) - 1):
        fused.blocks[exits[i]].terminator = Jump(entries[i + 1])
    assert isinstance(fused.blocks[exits[-1]].terminator, Return)

    # -- value-forward each chain edge ------------------------------------------
    elided_params: List[str] = []
    for e in edges:
        if e.consumer != e.producer + 1:
            raise FusionError(
                f"chain edge {e} is not between adjacent segments")
        pname = buffer_map[(e.producer, e.prod_arg)]
        cname = buffer_map[(e.consumer, e.cons_arg)]
        if pname != cname:
            raise FusionError(
                f"edge {e}: producer arg maps to {pname!r} but consumer "
                f"arg to {cname!r} — not one buffer object")
        stores = [(blk, ins) for blk in fused.blocks.values()
                  if blk.name.startswith(f"k{e.producer}_")
                  for ins in blk.instrs
                  if ins.op == "store" and ins.attrs["buffer"] == pname]
        if len(stores) != 1:
            raise FusionError(
                f"edge {e}: producer has {len(stores)} stores to "
                f"{pname!r}; forwarding needs exactly one")
        store_blk, store = stores[0]
        forwarded: Value = store.operands[1]
        if not isinstance(forwarded, Value):
            raise FusionError(f"edge {e}: store of a raw constant")
        loads = [(blk, ins) for blk in fused.blocks.values()
                 if blk.name.startswith(f"k{e.consumer}_")
                 for ins in blk.instrs
                 if ins.op == "load" and ins.attrs["buffer"] == pname]
        if not loads:
            raise FusionError(
                f"edge {e}: consumer never loads {pname!r}")
        # SSA legality: a store under producer control flow does not
        # define the value on every path — it must dominate every load
        # it replaces (straight-line producers trivially satisfy this)
        dom = fused.dominators()
        for blk, _ in loads:
            if store_blk.name not in dom.get(blk.name, set()):
                raise FusionError(
                    f"edge {e}: store in {store_blk.name!r} does not "
                    f"dominate load in {blk.name!r}")
        replace: Dict[int, Value] = {}
        for _, ld in loads:
            if ld.result.dtype != forwarded.dtype:
                raise FusionError(
                    f"edge {e}: load dtype {ld.result.dtype} != stored "
                    f"value dtype {forwarded.dtype}")
            replace[ld.result.id] = forwarded
        dead = {id(ins) for _, ins in loads}
        for blk in fused.blocks.values():
            if not blk.name.startswith(f"k{e.consumer}_"):
                continue
            blk.instrs = [ins for ins in blk.instrs
                          if id(ins) not in dead]
            for ins in blk.instrs:
                ins.operands = [replace.get(o.id, o)
                                if isinstance(o, Value) else o
                                for o in ins.operands]
            for phi in blk.phis:
                phi.incomings = {p: replace.get(v.id, v)
                                 if isinstance(v, Value) else v
                                 for p, v in phi.incomings.items()}
        if e.elide:
            store_blk.instrs = [ins for ins in store_blk.instrs
                                if ins is not store]
            elided_params.append(pname)
    for pname in elided_params:
        still_used = any(
            ins.attrs.get("buffer") == pname
            for blk in fused.blocks.values() for ins in blk.instrs
            if ins.op in ("load", "store"))
        if still_used:
            raise FusionError(
                f"elided parameter {pname!r} still accessed after "
                f"forwarding — elision legality was mis-judged")
        fused.buffer_args = [a for a in fused.buffer_args
                             if a.name != pname]
        for key in [k for k, v in buffer_map.items() if v == pname]:
            del buffer_map[key]

    fused.verify()
    verify_ir(fused, (), pass_name="fusion-stitch")
    return fused, buffer_map, scalar_map


# ---------------------------------------------------------------------------
# FusedSpec — the cached, relaunchable product of one stitched chain
# ---------------------------------------------------------------------------

class _FusionContext:
    """Minimal Program-context shim: just the shared plan-cache tier, so
    a fused Program created inside the runtime reuses the same
    :class:`~repro.core.cache.CompilationCache` that holds its
    :class:`FusedSpec` (one cache object per device: fused tier, plan
    tier, and compiled-kernel tier all in one place)."""

    def __init__(self, cache: CompilationCache):
        self.cache = cache


@dataclass
class FusedSpec:
    """Everything the DAG rewriter needs to launch a stitched chain.

    Steady-state relaunch is argument re-binding through ``buffer_map``/
    ``scalar_map`` plus a memoized ``program.binary_for`` lookup — no
    stitching, planning, or compilation.
    """

    key: FusedKey
    kernel_name: str
    program: Program
    buffer_map: Dict[Tuple[int, str], str]   # (seg, arg) -> fused param
    scalar_map: Dict[Tuple[int, str], str]
    elided: Tuple[Tuple[int, str], ...]      # (seg, producer arg) elided
    names: Tuple[str, ...]                   # constituent kernel names

    def bind_launch(self, buffers_per_seg: Sequence[Dict[str, object]],
                    scalars_per_seg: Sequence[Dict[str, object]]
                    ) -> Tuple[Dict[str, object], Dict[str, object]]:
        """Rebind one chain's per-segment launch arguments to the fused
        signature (elided parameters are skipped — their buffers are
        never touched)."""
        buffers: Dict[str, object] = {}
        for i, segbufs in enumerate(buffers_per_seg):
            for arg, buf in segbufs.items():
                fname = self.buffer_map.get((i, arg))
                if fname is not None:
                    buffers[fname] = buf
        scalars: Dict[str, object] = {}
        for i, segscal in enumerate(scalars_per_seg):
            for arg, val in segscal.items():
                scalars[self.scalar_map[(i, arg)]] = val
        return buffers, scalars


def make_fused_key(ir_hashes: Sequence[str], edges: Sequence[ChainEdge],
                   alias_groups: Sequence[Sequence[Tuple[int, str]]],
                   **options) -> FusedKey:
    return FusedKey(
        parts=tuple(ir_hashes),
        edges=tuple((e.producer, e.consumer, e.prod_arg, e.cons_arg,
                     e.elide) for e in edges),
        aliases=tuple(tuple(sorted(tuple(m) for m in g))
                      for g in alias_groups),
        options=tuple(sorted(options.items())))


def build_fused_spec(builders: Sequence[Callable[[], Function]],
                     names: Sequence[str],
                     edges: Sequence[ChainEdge],
                     alias_groups: Sequence[Sequence[Tuple[int, str]]],
                     cache: CompilationCache,
                     key: Optional[FusedKey] = None,
                     **program_options) -> FusedSpec:
    """Build (or fetch from ``cache``'s fused tier) the
    :class:`FusedSpec` for one chain topology.

    ``builders`` are the constituent kernels' zero-argument IR builders
    (the Program contract: every call yields a fresh CFG), so the fused
    Program can re-stitch deterministically whenever a specialization
    needs fresh IR.
    """
    edges = tuple(edges)
    alias_groups = tuple(tuple(tuple(m) for m in g) for g in alias_groups)
    if key is None:
        key = make_fused_key([ir_hash(b()) for b in builders], edges,
                             alias_groups, **program_options)

    def construct() -> FusedSpec:
        def fused_builder() -> Function:
            fn, _, _ = stitch_functions([b() for b in builders], edges,
                                        alias_groups)
            return fn
        fn, buffer_map, scalar_map = stitch_functions(
            [b() for b in builders], edges, alias_groups)
        program = Program([fused_builder], context=_FusionContext(cache),
                          **program_options)
        # Program re-derived the builder's IR; assert the stitch is
        # deterministic (equal canonical hashes) so cached binaries match
        assert program.ir_hash(fn.name) == ir_hash(fn), \
            "stitched chain is not deterministic"
        elided = tuple(
            (e.producer, e.prod_arg) for e in edges if e.elide)
        return FusedSpec(key=key, kernel_name=fn.name, program=program,
                         buffer_map=buffer_map, scalar_map=scalar_map,
                         elided=elided, names=tuple(names))

    return cache.get_or_build_fused(key, construct)


__all__ = ["ChainEdge", "FusedSpec", "FusionError", "build_fused_spec",
           "fusible_kernel", "make_fused_key", "stitch_functions"]
