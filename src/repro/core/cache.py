"""Kernel-compiler compilation cache (docs/caching.md).

pocl compiles one work-group function per (kernel, local size) at enqueue
time and *reuses* it across enqueues — recompilation only happens when the
kernel or the specialization parameters change.  Our pipeline (normalize →
region formation → target lowering) previously re-ran on every
``compile_kernel`` call.  This module memoizes the whole compilation:

* **Key** — ``CacheKey``: a canonical, content-addressed hash of the kernel
  IR (stable across DSL re-definition: SSA value ids and block-name counters
  are renamed away), plus the local size, the target name, and the target
  option tuple.  Two ``build()`` closures producing structurally identical
  CFGs map to the same entry.
* **In-memory tier** — an LRU over compiled :class:`CompiledKernel` objects
  (``capacity`` entries; least-recently-used eviction).
* **Disk tier** (optional) — pickled kernels under ``disk_dir`` for
  cross-process reuse; per-shape jit caches are dropped on pickle and
  rebuilt lazily after load.  Entries that fail to pickle (e.g. exotic
  targets) are silently kept memory-only.

Invalidation is purely content-driven: any IR change, local-size change, or
option change produces a different key.  ``CACHE_SCHEMA_VERSION`` is folded
into every key so that compiler-pipeline changes invalidate stale disk
entries wholesale.

Stats (hits / misses / compiles / evictions / disk traffic) are surfaced
per-device through :meth:`repro.runtime.platform.Device.cache_stats`.
"""

from __future__ import annotations

import hashlib
import os
import pickle
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

from .errors import InvalidArgError
from .ir import CondBranch, Function, Jump, Return, Value

# bump when the compiler pipeline changes in ways that invalidate old
# compiled programs (folded into every cache key, incl. disk entries)
# v2: pass-manager pipeline — compiled kernels embed a WorkGroupPlan
# v3: WorkGroupPlan carries fusibility facts (DAG-level kernel fusion)
CACHE_SCHEMA_VERSION = 3


# ---------------------------------------------------------------------------
# Canonical IR text + content hash
# ---------------------------------------------------------------------------

def canonical_value_names(fn: Function) -> Dict[int, str]:
    """SSA value id -> first-reference canonical name (``v0``, ``v1``, ...)
    in the exact order :func:`canonical_ir` prints references: scalar args
    first, then per RPO block its phis (incomings, then the result), each
    instruction's operands then result, and the branch condition.  Shared
    by ``canonical_ir`` and ``WorkGroupPlan.describe`` so slot names in
    plan dumps match the printed IR."""
    names: Dict[int, str] = {}

    def ref(v: object) -> None:
        if isinstance(v, Value) and v.id not in names:
            names[v.id] = f"v{len(names)}"

    for a in fn.scalar_args:
        ref(fn.arg_values[a.name])
    for n in fn.rpo():
        blk = fn.blocks[n]
        for phi in blk.phis:
            # canonical_ir renders the sorted incoming list before the
            # "<result> = phi" text, so incomings take names first
            for v in phi.incomings.values():
                ref(v)
            ref(phi.result)
        for ins in blk.instrs:
            for o in ins.operands:
                ref(o)
            if ins.result is not None:
                ref(ins.result)
        term = blk.terminator
        if isinstance(term, CondBranch):
            ref(term.cond)
    return names


def canonical_ir(fn: Function) -> str:
    """Render ``fn`` to a canonical text form.

    Canonicalization renames every basic block to its reverse-post-order
    index and every SSA value to its first-reference index, so the result is
    independent of the process-global value counter and the builder's block
    name counters — re-running the same DSL code yields the same text.
    """
    order = fn.rpo()
    bmap = {n: f"b{i}" for i, n in enumerate(order)}
    vmap: Dict[int, str] = canonical_value_names(fn)

    def vref(v: object) -> str:
        if isinstance(v, Value):
            if v.id not in vmap:  # unreferenced-elsewhere safety net
                vmap[v.id] = f"v{len(vmap)}"
            return f"{vmap[v.id]}:{v.dtype}"
        return f"lit({type(v).__name__},{v!r})"

    lines = [f"func {fn.name} ndim={fn.ndim}"]
    for a in fn.buffer_args:
        lines.append(f"buf {a.name}:{a.dtype}@{a.space} size={a.size}")
    for a in fn.scalar_args:
        # scalar args bind SSA values; fix their canonical names up front
        lines.append(f"scalar {a.name}:{a.dtype} {vref(fn.arg_values[a.name])}")

    for n in order:
        blk = fn.blocks[n]
        lines.append(f"block {bmap[n]}")
        for phi in blk.phis:
            incs = sorted((bmap.get(p, p), vref(val))
                          for p, val in phi.incomings.items())
            lines.append(f"  {vref(phi.result)} = phi {incs}")
        for ins in blk.instrs:
            ops = ",".join(vref(o) for o in ins.operands)
            attrs = ";".join(f"{k}={v!r}" for k, v in sorted(ins.attrs.items()))
            res = vref(ins.result) if ins.result is not None else "_"
            lines.append(f"  {res} = {ins.op}({ops}) [{attrs}]")
        t = blk.terminator
        if isinstance(t, CondBranch):
            lines.append(f"  condbr {vref(t.cond)} "
                         f"{bmap.get(t.if_true, t.if_true)} "
                         f"{bmap.get(t.if_false, t.if_false)}")
        elif isinstance(t, Jump):
            lines.append(f"  jump {bmap.get(t.target, t.target)}")
        elif isinstance(t, Return):
            lines.append("  return")
        else:
            lines.append(f"  term {t!r}")
    return "\n".join(lines)


def ir_hash(fn: Function) -> str:
    """Content hash of the kernel (sha256 of the canonical IR text)."""
    return hashlib.sha256(canonical_ir(fn).encode()).hexdigest()


@dataclass(frozen=True)
class CacheKey:
    """(what to compile, how to specialize it) — the full cache identity."""

    ir: str                      # canonical IR hash
    local_size: Tuple[int, ...]
    target: str
    options: Tuple[Tuple[str, object], ...]  # sorted (name, value) pairs
    schema: int = CACHE_SCHEMA_VERSION

    @classmethod
    def make(cls, fn: Function, local_size: Sequence[int], target: str,
             **options) -> "CacheKey":
        return cls(ir_hash(fn), tuple(int(x) for x in local_size), target,
                   tuple(sorted(options.items())))

    def digest(self) -> str:
        """Filesystem-safe digest for the disk tier."""
        raw = repr((self.ir, self.local_size, self.target, self.options,
                    self.schema))
        return hashlib.sha256(raw.encode()).hexdigest()


@dataclass(frozen=True)
class PlanKey:
    """Identity of the *target-independent prefix* of a compilation: the
    :class:`repro.core.passes.WorkGroupPlan`.  Deliberately narrower than
    :class:`CacheKey` — no ``local_size`` (lane counts bind at target
    construction), no ``target``, and only the options that feed the
    middle-end (``horizontal``, ``merge_uniform``).  One plan entry is
    therefore shared by every target and local size of a kernel: the
    autotuner's 3-target sweep runs region formation once
    (docs/caching.md §Stage-level plan caching)."""

    ir: str                                   # canonical IR hash
    options: Tuple[Tuple[str, object], ...]   # sorted middle-end options
    schema: int = CACHE_SCHEMA_VERSION

    PLAN_OPTIONS = ("horizontal", "merge_uniform")

    @classmethod
    def make(cls, ir: str, **options) -> "PlanKey":
        opts = {k: v for k, v in options.items() if k in cls.PLAN_OPTIONS}
        return cls(ir, tuple(sorted(opts.items())))


@dataclass(frozen=True)
class FusedKey:
    """Identity of a stitched kernel chain in the fused tier
    (docs/caching.md §Fused-chain caching).

    ``parts`` are the constituent kernels' canonical IR hashes in chain
    order; ``edges`` is the chain topology — one
    ``(producer_seg, consumer_seg, producer_arg, consumer_arg, elided)``
    tuple per forwarded buffer; ``aliases`` records which (segment, arg)
    pairs were bound to one buffer object and therefore folded into one
    fused parameter.  The key is purely structural: two chains of
    structurally identical kernels wired the same way hit the same entry
    regardless of which Buffer objects or queues are involved."""

    parts: Tuple[str, ...]
    edges: Tuple[Tuple[int, int, str, str, bool], ...]
    aliases: Tuple[Tuple[Tuple[int, str], ...], ...]
    options: Tuple[Tuple[str, object], ...]
    schema: int = CACHE_SCHEMA_VERSION


# ---------------------------------------------------------------------------
# The cache
# ---------------------------------------------------------------------------

@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    compiles: int = 0
    evictions: int = 0
    disk_hits: int = 0
    disk_writes: int = 0
    tune_decisions: int = 0
    # stage-level plan tier (target-independent prefix sharing)
    plan_hits: int = 0
    plan_misses: int = 0
    plan_builds: int = 0
    # fused tier (stitched kernel chains, keyed by FusedKey)
    fused_hits: int = 0
    fused_misses: int = 0
    fused_builds: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)

    def reset(self) -> None:
        for k in list(self.__dict__):
            setattr(self, k, 0)


class CompilationCache:
    """LRU compilation cache with an optional on-disk pickle tier.

    Thread-safe and single-flight: the command queue compiles from worker
    threads, and concurrent ``get_or_compile`` calls for the same key run
    the pipeline exactly once — the winner compiles outside the lock while
    the others wait on a per-key event and then take the memory hit.
    """

    def __init__(self, capacity: int = 128,
                 disk_dir: Optional[str] = None,
                 plan_capacity: Optional[int] = None):
        if int(capacity) <= 0 or (plan_capacity is not None
                                  and int(plan_capacity) <= 0):
            # a zero-capacity LRU would evict every insert immediately —
            # callers who want no caching pass cache=False instead
            raise InvalidArgError(
                f"CompilationCache capacity must be positive, got "
                f"capacity={capacity!r} plan_capacity={plan_capacity!r}")
        self.capacity = int(capacity)
        self.plan_capacity = int(plan_capacity if plan_capacity is not None
                                 else capacity)
        self.disk_dir = disk_dir
        self._entries: "OrderedDict[CacheKey, object]" = OrderedDict()
        # stage-level tier: WorkGroupPlan per PlanKey, separate from the
        # kernel LRU so plan sharing never evicts compiled kernels (and
        # len(cache) keeps meaning "compiled kernels resident")
        self._plans: "OrderedDict[PlanKey, object]" = OrderedDict()
        # fused tier: FusedSpec per FusedKey (stitched kernel chains) —
        # memory-only, like plans: the compiled fused kernels land in the
        # normal kernel tiers through the usual device.compile path
        self._fused: "OrderedDict[FusedKey, object]" = OrderedDict()
        self._inflight: Dict[object, threading.Event] = {}
        self._lock = threading.Lock()
        self.stats = CacheStats()

    @classmethod
    def from_env(cls, capacity: int = 128) -> "CompilationCache":
        """A cache whose disk tier follows REPRO_KERNEL_CACHE_DIR (the one
        place this env var is interpreted)."""
        return cls(capacity=capacity,
                   disk_dir=os.environ.get("REPRO_KERNEL_CACHE_DIR") or None)

    def note_tune_decision(self) -> None:
        with self._lock:
            self.stats.tune_decisions += 1

    # -- lookup ---------------------------------------------------------------
    def get_or_compile(self, key: CacheKey, compile_fn: Callable[[], object]):
        while True:
            with self._lock:
                ent = self._entries.get(key)
                if ent is not None:
                    self._entries.move_to_end(key)
                    self.stats.hits += 1
                    return ent
                ev = self._inflight.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[key] = ev
                    owner = True
                else:
                    owner = False
            if not owner:
                # another thread is compiling this key; wait and re-check
                # (re-loop also handles the owner failing: we take over)
                ev.wait()
                continue
            store_to_disk = False
            try:
                ent = self._disk_load(key)
                if ent is not None:
                    with self._lock:
                        self.stats.hits += 1
                        self.stats.disk_hits += 1
                    self._insert(key, ent)
                    return ent
                with self._lock:
                    self.stats.misses += 1
                ent = compile_fn()
                with self._lock:
                    self.stats.compiles += 1
                self._insert(key, ent)
                store_to_disk = True
                return ent
            finally:
                # release waiters as soon as the memory tier is populated;
                # the (potentially slow) disk write must not block them
                with self._lock:
                    self._inflight.pop(key, None)
                ev.set()
                if store_to_disk:
                    self._disk_store(key, ent)

    # -- stage-level plan tier --------------------------------------------------
    def get_or_build_plan(self, key: PlanKey,
                          build_fn: Callable[[], object]):
        """Memoize the target-independent pipeline prefix (the
        :class:`~repro.core.passes.WorkGroupPlan`).  Memory-only — plans
        are embedded in the compiled kernels the disk tier persists —
        and single-flight, like :meth:`get_or_compile`."""
        while True:
            with self._lock:
                ent = self._plans.get(key)
                if ent is not None:
                    self._plans.move_to_end(key)
                    self.stats.plan_hits += 1
                    return ent
                ev = self._inflight.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[key] = ev
                    owner = True
                else:
                    owner = False
            if not owner:
                ev.wait()
                continue
            try:
                with self._lock:
                    self.stats.plan_misses += 1
                ent = build_fn()
                with self._lock:
                    self.stats.plan_builds += 1
                    self._plans[key] = ent
                    self._plans.move_to_end(key)
                    while len(self._plans) > self.plan_capacity:
                        self._plans.popitem(last=False)
                return ent
            finally:
                with self._lock:
                    self._inflight.pop(key, None)
                ev.set()

    def plan_cache_size(self) -> int:
        with self._lock:
            return len(self._plans)

    # -- fused tier (stitched kernel chains) ------------------------------------
    def get_or_build_fused(self, key: FusedKey,
                           build_fn: Callable[[], object]):
        """Memoize a stitched-chain artifact (a
        :class:`~repro.core.fusion.FusedSpec`) under its structural
        :class:`FusedKey`.  Memory-only and single-flight like the plan
        tier: steady-state fusion of a repeated chain is one dict
        lookup — the stitching, verification, and planning all happened
        on the first flush."""
        while True:
            with self._lock:
                ent = self._fused.get(key)
                if ent is not None:
                    self._fused.move_to_end(key)
                    self.stats.fused_hits += 1
                    return ent
                ev = self._inflight.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[key] = ev
                    owner = True
                else:
                    owner = False
            if not owner:
                ev.wait()
                continue
            try:
                with self._lock:
                    self.stats.fused_misses += 1
                ent = build_fn()
                with self._lock:
                    self.stats.fused_builds += 1
                    self._fused[key] = ent
                    self._fused.move_to_end(key)
                    while len(self._fused) > self.plan_capacity:
                        self._fused.popitem(last=False)
                return ent
            finally:
                with self._lock:
                    self._inflight.pop(key, None)
                ev.set()

    def fused_cache_size(self) -> int:
        with self._lock:
            return len(self._fused)

    # -- mutation --------------------------------------------------------------
    def _insert(self, key: CacheKey, ent: object) -> None:
        with self._lock:
            self._entries[key] = ent
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._plans.clear()
            self._fused.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: CacheKey) -> bool:
        with self._lock:
            return key in self._entries

    # -- disk tier --------------------------------------------------------------
    def _disk_path(self, key: CacheKey) -> Optional[str]:
        if not self.disk_dir:
            return None
        return os.path.join(self.disk_dir, key.digest() + ".pkl")

    def _disk_load(self, key: CacheKey):
        path = self._disk_path(key)
        if path is None or not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                return pickle.load(f)
        except Exception:
            # stale/corrupt entry: content-addressed, so just drop it
            try:
                os.remove(path)
            except OSError:
                pass
            return None

    def _disk_store(self, key: CacheKey, ent: object) -> None:
        path = self._disk_path(key)
        if path is None:
            return
        try:
            os.makedirs(self.disk_dir, exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                pickle.dump(ent, f)
            os.replace(tmp, path)
            with self._lock:
                self.stats.disk_writes += 1
        except Exception:
            pass  # memory-only fallback (e.g. unpicklable target state)


# ---------------------------------------------------------------------------
# Process-default cache (used by compile_kernel when cache=True)
# ---------------------------------------------------------------------------

_default_cache: Optional[CompilationCache] = None
_default_lock = threading.Lock()


def default_cache() -> CompilationCache:
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = CompilationCache.from_env()
        return _default_cache


def reset_default_cache() -> None:
    """Testing hook: drop the process-default cache (stats included)."""
    global _default_cache
    with _default_lock:
        _default_cache = None
