"""First-class Program / Kernel host objects (docs/host_api.md, paper §3).

OpenCL's host object model separates *what* is compiled from *where* and
*how* it runs: a ``cl_program`` holds source for one or more kernels, is
built per device, and hands out ``cl_kernel`` objects whose arguments are
bound with ``clSetKernelArg`` before any number of enqueues.  This module
rebuilds that tier over the existing compiler:

* :class:`Program` — created from one or more IR builders
  (``Context.create_program``).  The middle-end (the pass-manager
  pipeline producing the shared
  :class:`~repro.core.passes.WorkGroupPlan`) runs through the owning
  context's *shared* plan tier, so every device specializing the same
  program reuses one region-formation run.  Per-(device, local_size,
  target) work-group functions are specialized **lazily at enqueue
  time** (the paper compiles one work-group function per local size,
  §4.1) through each device's compilation cache — ``Program.build()``
  only runs the target-independent pipeline plus the structural IR
  verifier, accumulating a ``build_log()`` the way
  ``clGetProgramBuildInfo`` does.
* :class:`Kernel` — one named kernel of a program with OpenCL
  ``set_arg`` semantics: positional or named argument binding, validated
  against the IR signature (buffer vs. scalar, dtype, LOCAL args are
  auto-materialized and not settable), and a cheap :meth:`Kernel.clone`
  so concurrent enqueues on out-of-order queues never share mutable
  argument state.

One ``Kernel`` object flows unchanged through single-device enqueue
(``CommandQueue.enqueue_nd_range``), multi-device co-execution
(``CoExecutor.launch``), and direct host launch (``Context.launch``);
the compiled artifact underneath is identical in all three (same cache
keys, bitwise-identical results — tests/test_host_api.py).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import ir
from .api import _compile_kernel
from .cache import CompilationCache, PlanKey, default_cache, ir_hash
from .errors import BuildError, InvalidArgError
from .ir import Function
from .passes import VerifierError, build_plan


def _classify(value) -> str:
    """Host-API argument class of ``value``: ``"host"`` (ndarray),
    ``"shared"`` (SharedBuffer), ``"device"`` (Buffer/SubBuffer view),
    or ``"scalar"``.  Duck-typed so the core layer never imports the
    runtime layer."""
    if isinstance(value, np.ndarray) and value.ndim > 0:
        return "host"
    if hasattr(value, "tracker") and hasattr(value, "host"):
        return "shared"
    # probe `origin`, not `data`: hasattr(value, "data") would invoke the
    # property getter, materializing a still-lazy pooled buffer and
    # defeating fusion's intermediate elision
    if hasattr(value, "root") and hasattr(value, "origin"):
        return "device"
    return "scalar"


def _buffer_dtype(value, kind: str):
    """The raw dtype spec of a buffer-class argument (normalized by the
    caller via ``np.dtype`` — buffers may carry any dtype spelling)."""
    if kind == "shared":
        return value.host.dtype
    return value.dtype             # ndarray / device Buffer / SubBuffer


class Program:
    """A set of kernels compiled together (``cl_program`` analogue).

    Parameters
    ----------
    builders:
        Zero-argument callables, each returning a fresh
        :class:`~repro.core.ir.Function` (the same contract
        ``compile_kernel`` had — the pipeline mutates the CFG, so every
        specialization rebuilds from source).  Kernel names come from
        the built functions.
    context:
        The owning :class:`~repro.runtime.context.Context` (may be
        ``None`` for context-free compiler-level use).  Provides the
        shared compilation/plan cache tier.
    options:
        Build options applied to every kernel: ``horizontal``,
        ``merge_uniform``, ``use_vml`` — the ``clBuildProgram`` options
        string analogue.
    """

    def __init__(self, builders: Sequence[Callable[[], Function]],
                 context=None, horizontal: bool = True,
                 merge_uniform: bool = True, use_vml: bool = False):
        if not builders:
            raise InvalidArgError("Program needs at least one IR builder")
        self.context = context
        self.options: Dict[str, object] = dict(
            horizontal=horizontal, merge_uniform=merge_uniform,
            use_vml=use_vml)
        self._builders: Dict[str, Callable[[], Function]] = {}
        self._fns: Dict[str, Function] = {}       # signature reference
        self._ir: Dict[str, str] = {}             # canonical IR hashes
        for build in builders:
            fn = build()
            if fn.name in self._builders:
                raise InvalidArgError(
                    f"duplicate kernel name {fn.name!r} in program")
            self._builders[fn.name] = build
            self._fns[fn.name] = fn
            self._ir[fn.name] = ir_hash(fn)
        self._log: List[str] = []
        self._built = False
        self._binaries: Dict[tuple, object] = {}
        self._lock = threading.Lock()

    # -- introspection ---------------------------------------------------------
    def kernel_names(self) -> List[str]:
        """clGetProgramInfo(CL_PROGRAM_KERNEL_NAMES)."""
        return list(self._builders)

    def function(self, name: str) -> Function:
        """The *unmutated* signature IR of kernel ``name`` (argument
        validation reads this; specializations rebuild their own)."""
        try:
            return self._fns[name]
        except KeyError:
            raise InvalidArgError(
                f"no kernel {name!r} in program; have "
                f"{self.kernel_names()}") from None

    def builder(self, name: str) -> Callable[[], Function]:
        """The zero-argument IR builder of kernel ``name`` — the source
        the queue's fusion rewrite re-stitches chains from
        (:mod:`repro.core.fusion`)."""
        try:
            return self._builders[name]
        except KeyError:
            raise InvalidArgError(
                f"no kernel {name!r} in program; have "
                f"{self.kernel_names()}") from None

    def build_log(self) -> str:
        """clGetProgramBuildInfo(CL_PROGRAM_BUILD_LOG): accumulated
        middle-end diagnostics, including the structural-verifier report
        of a failed :meth:`build`."""
        return "\n".join(self._log)

    # -- build (middle-end + verifier; specialization stays lazy) -------------
    def _plan_cache(self) -> CompilationCache:
        if self.context is not None:
            return self.context.cache
        return default_cache()

    def plan_key(self, name: str) -> PlanKey:
        return PlanKey.make(self._ir[name],
                            horizontal=self.options["horizontal"],
                            merge_uniform=self.options["merge_uniform"])

    def ir_hash(self, name: str) -> str:
        """Canonical IR hash of kernel ``name`` — the content-addressed
        kernel identity every persistent key is derived from (compilation
        cache, tuning-table winners, co-execution weight entries)."""
        try:
            return self._ir[name]
        except KeyError:
            raise InvalidArgError(
                f"no kernel {name!r} in program; have "
                f"{self.kernel_names()}") from None

    def build(self, verify: bool = True) -> "Program":
        """clBuildProgram: run the target-independent middle-end for
        every kernel through the shared plan tier, with the structural
        IR verifier between passes (``verify=True``).

        Per-(device, local_size, target) specialization is deliberately
        *not* done here — it happens at enqueue time (paper §4.1) and is
        memoized per device; this call only proves the kernels survive
        the pass pipeline and warms the plan tier every later
        specialization hits.  The verification pipeline always runs
        (the plan tier may already hold an *unverified* plan from a
        lazy specialization — a cache hit must not skip the proof);
        the verified plan then seeds the tier if it was empty.  On a
        verifier failure the offending pass's report lands in
        :meth:`build_log` and a
        :class:`~repro.core.errors.BuildError` is raised
        (CL_BUILD_PROGRAM_FAILURE semantics)."""
        cache = self._plan_cache()
        for name, build in self._builders.items():
            try:
                plan = build_plan(
                    build(), horizontal=self.options["horizontal"],
                    merge_uniform=self.options["merge_uniform"],
                    verify=verify)
                cache.get_or_build_plan(self.plan_key(name),
                                        lambda p=plan: p)
            except VerifierError as e:
                self._log.append(f"kernel {name!r}: {e}")
                raise BuildError(
                    f"program build failed for kernel {name!r} "
                    f"(see build_log())",
                    build_log=self.build_log()) from e
            self._log.append(f"kernel {name!r}: middle-end ok "
                             f"(plan {self.plan_key(name).ir[:12]}...)")
        self._built = True
        return self

    # -- lazy specialization ----------------------------------------------------
    def binary_for(self, name: str, local_size: Sequence[int],
                   device=None, target: Optional[str] = None):
        """The launchable work-group function of kernel ``name`` for
        ``(device, local_size, target)`` — a
        :class:`~repro.core.api.CompiledKernel` (or
        :class:`~repro.core.autotune.AutotunedKernel` for ``"auto"``).

        With a ``device``, compilation is memoized in that device's
        compilation cache and the target defaults to the device driver's
        mapping; the *plan* tier is always the program's shared cache,
        so N devices specializing one kernel run region formation once.
        """
        if name not in self._builders:
            raise InvalidArgError(
                f"no kernel {name!r} in program; have "
                f"{self.kernel_names()}")
        lsz = tuple(int(x) for x in local_size)
        dev_key = device.info.name if device is not None else ""
        key = (name, dev_key, lsz, target)
        with self._lock:
            binary = self._binaries.get(key)
        if binary is not None:
            return binary
        build = self._builders[name]
        if device is not None:
            opts = dict(self.options)
            if target is not None:
                opts["target"] = target
            binary = device.compile(build, lsz,
                                    plan_cache=self._plan_cache(), **opts)
        else:
            binary = _compile_kernel(
                build, lsz, target=target or "vector",
                cache=self.context.cache if self.context is not None
                else True,
                plan_cache=self._plan_cache(), **self.options)
        with self._lock:
            self._binaries.setdefault(key, binary)
            return self._binaries[key]

    # -- kernels -----------------------------------------------------------------
    def create_kernel(self, name: Optional[str] = None) -> "Kernel":
        """clCreateKernel: a fresh argument-binding object for kernel
        ``name`` (defaults to the program's only kernel)."""
        if name is None:
            names = self.kernel_names()
            if len(names) != 1:
                raise InvalidArgError(
                    f"program has {len(names)} kernels {names}; "
                    f"create_kernel needs an explicit name")
            name = names[0]
        return Kernel(self, name)

    def create_kernels(self) -> Dict[str, "Kernel"]:
        """clCreateKernelsInProgram: one Kernel per kernel name."""
        return {n: Kernel(self, n) for n in self.kernel_names()}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<Program kernels={self.kernel_names()} "
                f"built={self._built}>")


class Kernel:
    """One kernel of a :class:`Program` with bound arguments
    (``cl_kernel`` analogue).

    Arguments are set positionally or by name (:meth:`set_arg`,
    :meth:`set_args`) and validated against the IR signature
    immediately — wrong dtype, buffer-vs-scalar confusion, or unknown
    names raise :class:`~repro.core.errors.InvalidArgError` at
    ``set_arg`` time, not deep inside a launch.  The positional order is
    the declaration order: global/constant buffer arguments first, then
    scalars (LOCAL-space arrays are materialized by the work-group
    function itself, pocl §4.7, and cannot be set).

    A Kernel is intentionally *mutable* argument state over an immutable
    compiled artifact — for concurrent enqueues with different
    arguments, :meth:`clone` the kernel per enqueue (cheap: the program,
    IR, and every compiled binary are shared)."""

    def __init__(self, program: Program, name: str):
        self.program = program
        self.name = name
        self._fn = program.function(name)
        self._buffer_args = [a for a in self._fn.buffer_args
                             if a.space != ir.LOCAL]
        self._scalar_args = list(self._fn.scalar_args)
        self._order = ([a.name for a in self._buffer_args]
                       + [a.name for a in self._scalar_args])
        self._by_name = {a.name: a for a in self._buffer_args}
        self._by_name.update({a.name: a for a in self._scalar_args})
        self._args: Dict[str, object] = {}

    # -- signature introspection -------------------------------------------------
    @property
    def ir_hash(self) -> str:
        """Canonical IR hash of this kernel's function (stable across
        processes) — the identity the co-execution scheduler keys its
        persisted per-device-class split weights on (docs/caching.md)."""
        return self.program.ir_hash(self.name)

    @property
    def num_args(self) -> int:
        """clGetKernelInfo(CL_KERNEL_NUM_ARGS) over the settable args."""
        return len(self._order)

    def arg_info(self) -> List[Tuple[str, str, str]]:
        """``(name, kind, dtype)`` per settable argument, positional
        order (clGetKernelArgInfo)."""
        out = [(a.name, "buffer", a.dtype) for a in self._buffer_args]
        out += [(a.name, "scalar", a.dtype) for a in self._scalar_args]
        return out

    # -- argument binding ---------------------------------------------------------
    def set_arg(self, key, value) -> "Kernel":
        """clSetKernelArg: bind one argument by position (int) or name
        (str).  Returns ``self`` for chaining."""
        if isinstance(key, (int, np.integer)):
            idx = int(key)
            if not 0 <= idx < len(self._order):
                raise InvalidArgError(
                    f"kernel {self.name!r} has {len(self._order)} "
                    f"settable args, index {idx} out of range "
                    f"({self.arg_info()})")
            name = self._order[idx]
        elif isinstance(key, str):
            name = key
            if name not in self._by_name:
                local = [a.name for a in self._fn.buffer_args
                         if a.space == ir.LOCAL]
                hint = (f"; {name!r} is a LOCAL array, materialized by "
                        f"the work-group function (pocl §4.7), not "
                        f"settable" if name in local else
                        f"; settable args: {self._order}")
                raise InvalidArgError(
                    f"kernel {self.name!r} has no argument "
                    f"{name!r}{hint}")
        else:
            raise InvalidArgError(
                f"set_arg key must be an int index or str name, got "
                f"{type(key).__name__}")
        arg = self._by_name[name]
        self._validate(arg, name, value)
        self._args[name] = value
        return self

    def _validate(self, arg, name: str, value) -> None:
        kind = _classify(value)
        is_buffer = any(a.name == name for a in self._buffer_args)
        if is_buffer:
            if kind == "scalar":
                raise InvalidArgError(
                    f"kernel {self.name!r} argument {name!r} is a "
                    f"{arg.dtype} buffer; got scalar {value!r} "
                    f"(CL_INVALID_ARG_VALUE)")
            got = _buffer_dtype(value, kind)
            # compare normalized dtypes, not spellings: a buffer created
            # with np.float32 or "f4" is the same dtype as "float32"
            if np.dtype(got) != np.dtype(arg.dtype):
                raise InvalidArgError(
                    f"kernel {self.name!r} argument {name!r} expects "
                    f"dtype {arg.dtype}, got {np.dtype(got).name} "
                    f"(CL_INVALID_ARG_VALUE)")
        else:
            if kind != "scalar":
                raise InvalidArgError(
                    f"kernel {self.name!r} argument {name!r} is a "
                    f"{arg.dtype} scalar; got a {kind} buffer "
                    f"(CL_INVALID_ARG_VALUE)")
            if isinstance(value, bool) or not isinstance(
                    value, (int, float, complex, np.number)):
                raise InvalidArgError(
                    f"kernel {self.name!r} argument {name!r} expects a "
                    f"{arg.dtype} scalar, got "
                    f"{type(value).__name__} ({value!r})")
            kind_code = np.dtype(arg.dtype).kind
            if kind_code != "c" and isinstance(
                    value, (complex, np.complexfloating)):
                raise InvalidArgError(
                    f"kernel {self.name!r} argument {name!r} expects a "
                    f"{arg.dtype} scalar, got complex {value!r}")
            if kind_code in "iu" and isinstance(
                    value, (float, np.floating)) and \
                    not float(value).is_integer():
                raise InvalidArgError(
                    f"kernel {self.name!r} argument {name!r} expects an "
                    f"{arg.dtype} scalar; {value!r} has a fractional "
                    f"part (CL_INVALID_ARG_VALUE)")

    def set_args(self, *positional, **named) -> "Kernel":
        """Bind several arguments at once: positionally (declaration
        order) and/or by keyword."""
        for i, v in enumerate(positional):
            self.set_arg(i, v)
        for k, v in named.items():
            self.set_arg(k, v)
        return self

    def clone(self) -> "Kernel":
        """clCloneKernel: an independent argument binding sharing the
        program and every compiled binary — O(#args), no compilation.
        Clone per enqueue when launching concurrently with different
        arguments (out-of-order queues, co-execution chunks)."""
        k = Kernel.__new__(Kernel)
        k.program = self.program
        k.name = self.name
        k._fn = self._fn
        k._buffer_args = self._buffer_args
        k._scalar_args = self._scalar_args
        k._order = self._order
        k._by_name = self._by_name
        k._args = dict(self._args)
        return k

    # -- launch-side access -------------------------------------------------------
    def missing_args(self) -> List[str]:
        return [n for n in self._order if n not in self._args]

    def launch_args(self, accept: Sequence[str] = ("host", "shared",
                                                   "device")
                    ) -> Tuple[Dict[str, object], Dict[str, object]]:
        """The bound ``(buffers, scalars)`` dicts for a launch.

        Raises :class:`~repro.core.errors.InvalidArgError`
        (CL_INVALID_KERNEL_ARGS) when arguments are unset, or when a
        buffer argument's class is outside ``accept`` — e.g. a
        device-bound Buffer handed to a co-executed launch, which needs
        host arrays or SharedBuffers."""
        missing = self.missing_args()
        if missing:
            raise InvalidArgError(
                f"kernel {self.name!r} launched with unset arguments "
                f"{missing} (CL_INVALID_KERNEL_ARGS)")
        buffers: Dict[str, object] = {}
        scalars: Dict[str, object] = {}
        for a in self._buffer_args:
            v = self._args[a.name]
            kind = _classify(v)
            if kind not in accept:
                raise InvalidArgError(
                    f"kernel {self.name!r} argument {a.name!r} is a "
                    f"{kind} buffer; this launch path accepts "
                    f"{tuple(accept)}")
            buffers[a.name] = v
        for a in self._scalar_args:
            scalars[a.name] = self._args[a.name]
        return buffers, scalars

    def bind(self, device, local_size: Sequence[int],
             target: Optional[str] = None):
        """The compiled work-group function for ``(device, local_size)``
        — delegates to :meth:`Program.binary_for` (lazy, cached)."""
        return self.program.binary_for(self.name, local_size,
                                       device=device, target=target)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        bound = {n: _classify(v) for n, v in self._args.items()}
        return f"<Kernel {self.name!r} args={bound}>"


__all__ = ["Program", "Kernel"]
