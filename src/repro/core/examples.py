"""Canonical exemplar kernels for the compiler pipeline.

One definition shared by the dump tool (``tools/dump_pipeline.py`` →
docs/compiler.md), the golden-IR snapshot tests
(``tests/test_passes.py`` + ``tests/golden/``), and
``benchmarks/bench_compile.py`` — so the kernel the docs walk through is
*provably* the kernel the goldens pin.  Each builder is deterministic:
calling it twice yields structurally identical CFGs (equal canonical IR).
"""

from __future__ import annotations

from .dsl import KernelBuilder


def build_reduce2():
    """2-wide tree reduction with an in-loop barrier (the paper's
    canonical barrier kernel shape): exercises normalize, §4.5 b-loop
    barriers, out-of-SSA, region formation, and context slots."""
    b = KernelBuilder("reduce2")
    inp = b.arg_buffer("inp", "float32")
    out = b.arg_buffer("out", "float32")
    scratch = b.local_array("scratch", "float32", 2)
    lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
    scratch[lid] = inp[gid]
    b.barrier()
    s = b.var(b.const(1), name="s")
    with b.while_loop() as loop:
        loop.cond(s.get() > 0)
        with b.if_(lid < s.get()):
            scratch[lid] = scratch[lid] + scratch[lid + s.get()]
        b.barrier()
        s.set(s.get() / 2)
    with b.if_(lid == 0):
        out[grp] = scratch[0]
    return b.finish()


def build_condbar():
    """Loop-free conditional barrier (work-group-uniform condition): the
    §4.3 Algorithm 2 tail-duplication case."""
    b = KernelBuilder("condbar")
    x = b.arg_buffer("x", "float32")
    n = b.arg_scalar("n", "int32")
    gid = b.global_id(0)
    zero = b.const(0)
    with b.if_(n > zero):
        b.barrier()
    x[gid] = x[gid] + 1.0
    return b.finish()


def build_rmsnorm_ew():
    """Elementwise RMSNorm apply (the normalization half of a decode
    step, with ``1/rms`` precomputed on the host): the canonical
    *producer* of a fusible chain — one gid-indexed store, no barriers,
    no loops."""
    b = KernelBuilder("rmsnorm_ew")
    x = b.arg_buffer("x", "float32")
    w = b.arg_buffer("w", "float32")
    y = b.arg_buffer("y", "float32")
    inv_rms = b.arg_scalar("inv_rms", "float32")
    gid = b.global_id(0)
    y[gid] = x[gid] * w[gid] * inv_rms
    return b.finish()


def build_residual_add():
    """Elementwise residual connection ``z = y + r`` — the middle link
    of the rmsnorm→residual→quantize chain (both producer and
    consumer)."""
    b = KernelBuilder("residual_add")
    y = b.arg_buffer("y", "float32")
    r = b.arg_buffer("r", "float32")
    z = b.arg_buffer("z", "float32")
    gid = b.global_id(0)
    z[gid] = y[gid] + r[gid]
    return b.finish()


def build_quantize():
    """Elementwise symmetric int8-style quantization (round-to-nearest
    via ``floor(v*scale + 0.5)``, clamped to ±127, kept in float32 —
    the classic chain *consumer*."""
    b = KernelBuilder("quantize")
    z = b.arg_buffer("z", "float32")
    q = b.arg_buffer("q", "float32")
    scale = b.arg_scalar("scale", "float32")
    gid = b.global_id(0)
    v = b.floor(z[gid] * scale + 0.5)
    q[gid] = b.maximum(-127.0, b.minimum(127.0, v))
    return b.finish()


def build_dct():
    """Uniform-trip-count inner loop (the §4.6/Fig. 9 DCT pattern):
    exercises the horizontal parallelization pass."""
    b = KernelBuilder("dct")
    inp = b.arg_buffer("inp", "float32")
    coef = b.arg_buffer("coef", "float32")
    out = b.arg_buffer("out", "float32")
    width = b.arg_scalar("width", "int32")
    lid = b.local_id(0)
    acc = b.var(0.0, name="acc")
    k = b.var(b.const(0), name="k")
    with b.while_loop() as loop:
        loop.cond(k.get() < width)
        acc.set(acc.get() + coef[k.get()] * inp[lid * width + k.get()])
        k.set(k.get() + 1)
    out[lid] = acc.get()
    return b.finish()
