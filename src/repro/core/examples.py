"""Canonical exemplar kernels for the compiler pipeline.

One definition shared by the dump tool (``tools/dump_pipeline.py`` →
docs/compiler.md), the golden-IR snapshot tests
(``tests/test_passes.py`` + ``tests/golden/``), and
``benchmarks/bench_compile.py`` — so the kernel the docs walk through is
*provably* the kernel the goldens pin.  Each builder is deterministic:
calling it twice yields structurally identical CFGs (equal canonical IR).
"""

from __future__ import annotations

from .dsl import KernelBuilder


def build_reduce2():
    """2-wide tree reduction with an in-loop barrier (the paper's
    canonical barrier kernel shape): exercises normalize, §4.5 b-loop
    barriers, out-of-SSA, region formation, and context slots."""
    b = KernelBuilder("reduce2")
    inp = b.arg_buffer("inp", "float32")
    out = b.arg_buffer("out", "float32")
    scratch = b.local_array("scratch", "float32", 2)
    lid, gid, grp = b.local_id(0), b.global_id(0), b.group_id(0)
    scratch[lid] = inp[gid]
    b.barrier()
    s = b.var(b.const(1), name="s")
    with b.while_loop() as loop:
        loop.cond(s.get() > 0)
        with b.if_(lid < s.get()):
            scratch[lid] = scratch[lid] + scratch[lid + s.get()]
        b.barrier()
        s.set(s.get() / 2)
    with b.if_(lid == 0):
        out[grp] = scratch[0]
    return b.finish()


def build_condbar():
    """Loop-free conditional barrier (work-group-uniform condition): the
    §4.3 Algorithm 2 tail-duplication case."""
    b = KernelBuilder("condbar")
    x = b.arg_buffer("x", "float32")
    n = b.arg_scalar("n", "int32")
    gid = b.global_id(0)
    zero = b.const(0)
    with b.if_(n > zero):
        b.barrier()
    x[gid] = x[gid] + 1.0
    return b.finish()


def build_dct():
    """Uniform-trip-count inner loop (the §4.6/Fig. 9 DCT pattern):
    exercises the horizontal parallelization pass."""
    b = KernelBuilder("dct")
    inp = b.arg_buffer("inp", "float32")
    coef = b.arg_buffer("coef", "float32")
    out = b.arg_buffer("out", "float32")
    width = b.arg_scalar("width", "int32")
    lid = b.local_id(0)
    acc = b.var(0.0, name="acc")
    k = b.var(b.const(0), name="k")
    with b.while_loop() as loop:
        loop.cond(k.get() < width)
        acc.set(acc.get() + coef[k.get()] * inp[lid * width + k.get()])
        k.set(k.get() + 1)
    out[lid] = acc.get()
    return b.finish()
