"""SSA-flavoured CFG IR for SPMD kernels (the pocl kernel-compiler IR).

The paper (pocl, §4.2) represents kernels as SSA control-flow graphs of LLVM
IR.  We rebuild the same abstraction natively: a ``Function`` is a graph of
``BasicBlock``s holding typed ``Instr``s and a single ``Terminator`` each.
The properties the paper relies on hold here too:

* instructions have at most one result,
* a basic block is a branchless instruction sequence,
* edges are defined by the terminator of the *source* block (so replicating a
  block replicates its out-edges, exactly as Section 4.2 requires),
* multiple exit blocks are allowed.

Helper functions ``create_subgraph`` (CreateSubgraph in the paper) and
``replicate_cfg`` (ReplicateCFG) are provided for the tail-duplication pass.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

# --------------------------------------------------------------------------
# Address spaces (OpenCL memory model, §2)
# --------------------------------------------------------------------------
GLOBAL = "global"
LOCAL = "local"
PRIVATE = "private"
CONSTANT = "constant"

ADDRESS_SPACES = (GLOBAL, LOCAL, PRIVATE, CONSTANT)

# --------------------------------------------------------------------------
# Opcodes
# --------------------------------------------------------------------------
BINOPS = {
    "add", "sub", "mul", "div", "rem", "min", "max", "pow",
    "and", "or", "xor", "shl", "shr",
}
CMPOPS = {"lt", "le", "gt", "ge", "eq", "ne"}
UNOPS = {
    "neg", "not", "abs", "exp", "log", "sin", "cos", "tanh", "erf",
    "sqrt", "rsqrt", "floor", "ceil", "rint",
}
# builtins returning work-item identity (OpenCL §2): dim attr in attrs["dim"]
ID_OPS = {"local_id", "global_id", "group_id", "local_size", "num_groups",
          "global_size"}

_value_counter = itertools.count()


@dataclass(eq=False)
class Value:
    """An SSA value. ``dtype`` is a numpy dtype string ('float32', ...)."""

    dtype: str
    name: str = ""

    def __post_init__(self) -> None:
        self.id = next(_value_counter)
        if not self.name:
            self.name = f"v{self.id}"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"%{self.name}:{self.dtype}"


@dataclass(eq=False)
class Instr:
    """op(operands) -> result.  Operands are Values or python constants."""

    op: str
    operands: List[object]
    result: Optional[Value] = None
    attrs: Dict[str, object] = field(default_factory=dict)

    def value_operands(self) -> List[Value]:
        return [o for o in self.operands if isinstance(o, Value)]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        res = f"{self.result!r} = " if self.result is not None else ""
        return f"{res}{self.op} {self.operands} {self.attrs or ''}"


# Terminators ---------------------------------------------------------------


@dataclass(eq=False)
class Jump:
    target: str

    def successors(self) -> List[str]:
        return [self.target]

    def replace(self, mapping: Dict[str, str]) -> "Jump":
        return Jump(mapping.get(self.target, self.target))


@dataclass(eq=False)
class CondBranch:
    cond: Value
    if_true: str
    if_false: str

    def successors(self) -> List[str]:
        return [self.if_true, self.if_false]

    def replace(self, mapping: Dict[str, str]) -> "CondBranch":
        return CondBranch(self.cond, mapping.get(self.if_true, self.if_true),
                          mapping.get(self.if_false, self.if_false))


@dataclass(eq=False)
class Return:
    def successors(self) -> List[str]:
        return []

    def replace(self, mapping: Dict[str, str]) -> "Return":
        return Return()


Terminator = object  # Jump | CondBranch | Return


@dataclass(eq=False)
class Phi:
    """Phi node: result selects ``incomings[pred_block]`` on entry from pred."""

    result: Value
    incomings: Dict[str, object]  # pred block name -> Value | const

    def __repr__(self) -> str:  # pragma: no cover
        return f"{self.result!r} = phi {self.incomings}"


@dataclass(eq=False)
class BasicBlock:
    name: str
    phis: List[Phi] = field(default_factory=list)
    instrs: List[Instr] = field(default_factory=list)
    terminator: Optional[Terminator] = None

    def successors(self) -> List[str]:
        return [] if self.terminator is None else self.terminator.successors()

    def has_barrier(self) -> bool:
        return any(i.op == "barrier" for i in self.instrs)


@dataclass
class BufferArg:
    """A kernel buffer argument (pointer in OpenCL terms)."""

    name: str
    dtype: str
    space: str  # GLOBAL | LOCAL | CONSTANT
    size: Optional[int] = None  # local buffers have a static size


@dataclass
class ScalarArg:
    name: str
    dtype: str


class Function:
    """A kernel function: CFG + argument list."""

    def __init__(self, name: str, ndim: int = 1):
        self.name = name
        self.ndim = ndim
        self.blocks: Dict[str, BasicBlock] = {}
        self.entry: str = "entry"
        self.buffer_args: List[BufferArg] = []
        self.scalar_args: List[ScalarArg] = []
        self.arg_values: Dict[str, Value] = {}
        self._name_counter = itertools.count()

    # -- construction helpers ------------------------------------------------
    def new_block(self, hint: str = "bb") -> BasicBlock:
        name = f"{hint}{next(self._name_counter)}"
        blk = BasicBlock(name)
        self.blocks[name] = blk
        return blk

    def add_block(self, blk: BasicBlock) -> None:
        assert blk.name not in self.blocks
        self.blocks[blk.name] = blk

    # -- graph queries --------------------------------------------------------
    def predecessors(self) -> Dict[str, List[str]]:
        preds: Dict[str, List[str]] = {n: [] for n in self.blocks}
        for name, blk in self.blocks.items():
            for s in blk.successors():
                preds[s].append(name)
        return preds

    def exit_blocks(self) -> List[str]:
        return [n for n, b in self.blocks.items()
                if isinstance(b.terminator, Return)]

    def rpo(self) -> List[str]:
        """Reverse post-order from entry (unreachable blocks excluded)."""
        seen: Set[str] = set()
        order: List[str] = []

        def visit(n: str) -> None:
            stack = [(n, iter(self.blocks[n].successors()))]
            seen.add(n)
            while stack:
                cur, it = stack[-1]
                advanced = False
                for s in it:
                    if s not in seen:
                        seen.add(s)
                        stack.append((s, iter(self.blocks[s].successors())))
                        advanced = True
                        break
                if not advanced:
                    order.append(cur)
                    stack.pop()

        visit(self.entry)
        return list(reversed(order))

    def prune_unreachable(self) -> None:
        reachable = set(self.rpo())
        dead = [n for n in self.blocks if n not in reachable]
        for n in dead:
            del self.blocks[n]
        # drop phi incomings from removed blocks
        for blk in self.blocks.values():
            for phi in blk.phis:
                phi.incomings = {p: v for p, v in phi.incomings.items()
                                 if p in self.blocks}

    # -- analyses --------------------------------------------------------------
    def dominators(self) -> Dict[str, Set[str]]:
        """Classic iterative dominator sets (small graphs; clarity > speed)."""
        order = self.rpo()
        preds = self.predecessors()
        allb = set(order)
        dom: Dict[str, Set[str]] = {n: set(allb) for n in order}
        dom[self.entry] = {self.entry}
        changed = True
        while changed:
            changed = False
            for n in order:
                if n == self.entry:
                    continue
                ps = [p for p in preds[n] if p in dom]
                new = set(allb)
                for p in ps:
                    new &= dom[p]
                new |= {n}
                if new != dom[n]:
                    dom[n] = new
                    changed = True
        return dom

    def natural_loops(self) -> List[Tuple[str, Set[str]]]:
        """Return [(header, loop_blocks)] via back-edge detection."""
        dom = self.dominators()
        preds = self.predecessors()
        loops: Dict[str, Set[str]] = {}
        for name, blk in self.blocks.items():
            for s in blk.successors():
                if s in dom.get(name, set()):  # back edge name -> s
                    body = loops.setdefault(s, {s})
                    # all blocks that reach `name` without passing s
                    stack = [name]
                    while stack:
                        n = stack.pop()
                        if n in body:
                            continue
                        body.add(n)
                        stack.extend(p for p in preds[n] if p not in body)
        return [(h, b) for h, b in loops.items()]

    def verify(self) -> None:
        for name, blk in self.blocks.items():
            assert blk.terminator is not None, f"block {name} unterminated"
            for s in blk.successors():
                assert s in self.blocks, f"{name} -> missing {s}"


# --------------------------------------------------------------------------
# CreateSubgraph / ReplicateCFG  (paper §4.2 helper functions)
# --------------------------------------------------------------------------

def create_subgraph(fn: Function, a: str, b_set: Set[str]) -> Set[str]:
    """All nodes potentially visited when traversing from ``a`` to any node in
    ``b_set`` — depth-first search recording every node on paths to the exits,
    ignoring edges back to visited nodes (paper: CreateSubgraph).

    Returns the set of block names, *excluding* ``a`` itself and the targets.
    """
    # nodes reachable from a (without revisiting)
    fwd: Set[str] = set()
    stack = [s for s in fn.blocks[a].successors()]
    while stack:
        n = stack.pop()
        if n in fwd or n in b_set:
            if n in b_set:
                fwd.add(n)
            continue
        fwd.add(n)
        stack.extend(fn.blocks[n].successors())
    return fwd - b_set - {a}


def replicate_cfg(fn: Function, nodes: Set[str], suffix: str) -> Dict[str, str]:
    """Copy ``nodes`` (blocks + their edges) into ``fn`` with fresh names.

    Edges leaving the subgraph keep their original targets (the defining
    property of sub-CFG replication in §4.2).  Returns old->new name map.
    """
    mapping = {n: f"{n}.{suffix}" for n in nodes}
    # 1:1 copy of instructions; fresh result Values, remapped operands.
    val_map: Dict[int, Value] = {}

    def copy_val(v: object) -> object:
        if isinstance(v, Value) and v.id in val_map:
            return val_map[v.id]
        return v

    # First pass: allocate fresh result values for every instr/phi result.
    for n in nodes:
        blk = fn.blocks[n]
        for phi in blk.phis:
            nv = Value(phi.result.dtype, phi.result.name + "." + suffix)
            val_map[phi.result.id] = nv
        for ins in blk.instrs:
            if ins.result is not None:
                nv = Value(ins.result.dtype, ins.result.name + "." + suffix)
                val_map[ins.result.id] = nv

    for n in nodes:
        blk = fn.blocks[n]
        nb = BasicBlock(mapping[n])
        for phi in blk.phis:
            inc = {}
            for pred, v in phi.incomings.items():
                # predecessors inside the subgraph are remapped; outside preds
                # keep their names (the copy may be unreachable from them; the
                # caller rewires edges and must clean up phis afterwards).
                inc[mapping.get(pred, pred)] = copy_val(v)
            nb.phis.append(Phi(val_map[phi.result.id], inc))
        for ins in blk.instrs:
            nops = [copy_val(o) for o in ins.operands]
            res = val_map[ins.result.id] if ins.result is not None else None
            nb.instrs.append(Instr(ins.op, nops, res, dict(ins.attrs)))
        term = blk.terminator
        if isinstance(term, CondBranch):
            nb.terminator = CondBranch(copy_val(term.cond),
                                       mapping.get(term.if_true, term.if_true),
                                       mapping.get(term.if_false, term.if_false))
        elif isinstance(term, Jump):
            nb.terminator = Jump(mapping.get(term.target, term.target))
        else:
            nb.terminator = Return()
        fn.add_block(nb)

    # Uses of replicated values *inside* the copies were remapped above.  Uses
    # outside the subgraph still refer to the originals, which is correct:
    # the originals remain on their own paths.
    return mapping


def remap_phi_preds(fn: Function) -> None:
    """Drop phi incomings whose predecessor edge no longer exists."""
    preds = fn.predecessors()
    for name, blk in fn.blocks.items():
        for phi in blk.phis:
            phi.incomings = {p: v for p, v in phi.incomings.items()
                             if p in preds[name]}


def split_at_barriers(fn: Function) -> None:
    """Rewrite the CFG so each ``barrier`` instr sits alone in its own block.

    After this pass a block either contains exactly one barrier (and nothing
    else), or no barrier at all; region formation then treats barrier blocks
    as graph nodes directly (paper Def. 1 preparation).
    """
    work = list(fn.blocks.keys())
    for name in work:
        blk = fn.blocks[name]
        if len(blk.instrs) == 1 and blk.instrs[0].op == "barrier" \
                and not blk.phis and isinstance(blk.terminator, Jump):
            continue  # already isolated
        idx = next((i for i, ins in enumerate(blk.instrs)
                    if ins.op == "barrier"), None)
        while idx is not None:
            # head: instrs[:idx] stays in blk; barrier alone; tail gets rest.
            bar_blk = fn.new_block(f"{name}.bar")
            tail_blk = fn.new_block(f"{name}.cont")
            bar_blk.instrs = [blk.instrs[idx]]
            bar_blk.terminator = Jump(tail_blk.name)
            tail_blk.instrs = blk.instrs[idx + 1:]
            tail_blk.terminator = blk.terminator
            blk.instrs = blk.instrs[:idx]
            blk.terminator = Jump(bar_blk.name)
            # phi predecessors of blk's old successors must be renamed
            for s in tail_blk.successors():
                for phi in fn.blocks[s].phis:
                    if name in phi.incomings:
                        phi.incomings[tail_blk.name] = phi.incomings.pop(name)
            blk = tail_blk
            name = tail_blk.name
            idx = next((i for i, ins in enumerate(blk.instrs)
                        if ins.op == "barrier"), None)


def ensure_single_exit(fn: Function) -> str:
    """Merge multiple Return blocks into one unified exit block."""
    exits = fn.exit_blocks()
    if len(exits) == 1:
        return exits[0]
    unified = fn.new_block("exit")
    unified.terminator = Return()
    for e in exits:
        fn.blocks[e].terminator = Jump(unified.name)
    return unified.name


def infer_binop_dtype(op: str, a_dtype: str, b_dtype: str) -> str:
    if op in CMPOPS:
        return "bool"
    return str(np.result_type(a_dtype, b_dtype))
