"""Vector target: work-items mapped to lanes, divergence if-converted.

This is the target-specific *parallel mapping* stage of the pocl pipeline
(paper Fig. 3): the target-independent region formation has produced
parallel regions + a schedule; here every varying SSA value becomes a
``(local_size,)`` lane vector (one work-item per lane — the SIMD mapping of
§4.1), uniform values stay scalars (the §4.7 merge), and intra-region
divergent control flow is executed fully predicated (if-conversion — listed
as future work in the paper §8; on TPU it is the only option, and the natural
one).  Inter-region scheduling follows the paper's peeled-first-work-item
rule (§4.4): the branch that selects the next region is read from lane 0,
legal because OpenCL barrier semantics make it work-group-uniform.

The work-group function is emitted as either a straight-line chain of region
calls (linear schedules) or a ``lax.while_loop`` over a ``lax.switch`` of
regions (schedules with conditional barriers / b-loops).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .. import ir
from ..context import ContextPlan
from ..ir import CondBranch, Function, Instr, Jump, Value
from ..passes import BlockNode, LoopNode, WorkGroupPlan, build_plan
from ..regions import Region, WGInfo


# ---------------------------------------------------------------------------
# Predicates: None means "all lanes true"
# ---------------------------------------------------------------------------

def _pand(a, b):
    if a is None:
        return b
    if b is None:
        return a
    return jnp.logical_and(a, b)


def _pnot_and(a, c):
    """a AND NOT c."""
    nc = jnp.logical_not(c)
    return nc if a is None else jnp.logical_and(a, nc)


def _por(preds: List[object]):
    if any(p is None for p in preds):
        return None
    if not preds:
        return None  # unreachable block; treated as never-executed by caller
    out = preds[0]
    for p in preds[1:]:
        out = jnp.logical_or(out, p)
    return out


# ---------------------------------------------------------------------------
# The lane executor
# ---------------------------------------------------------------------------

class LaneExec:
    """Executes parallel regions for a batch of lanes (work-items).

    ``lids_linear``: (L,) linearized local ids of the lanes in this batch —
    ``jnp.arange(local_size)`` for the vector target, a single dynamic index
    for the serial loop target.
    """

    def __init__(self, prog: "WGProgram", lids_linear, group_linear,
                 buffers: Dict[str, jnp.ndarray],
                 vregs: Dict[str, jnp.ndarray],
                 env: Optional[Dict[int, jnp.ndarray]] = None):
        self.prog = prog
        self.fn = prog.wg.fn
        self.L = lids_linear.shape[0]
        self.lids = lids_linear
        self.gl = group_linear
        self.buffers = dict(buffers)
        self.vregs = dict(vregs)
        self.env: Dict[int, jnp.ndarray] = dict(env or {})
        for nm, v in self.fn.arg_values.items():
            self.env[v.id] = prog.scalars[nm]

    # -- value plumbing ------------------------------------------------------
    def val(self, o):
        if isinstance(o, Value):
            return self.env[o.id]
        return o  # numpy literal folded by fold_constants

    def _varying(self, name: str) -> bool:
        return not self.prog.uni.vreg_uniform(name)

    def _bcast_vreg(self, name: str, x):
        if self._varying(name) and jnp.ndim(x) == 0:
            return jnp.broadcast_to(x, (self.L,))
        return x

    # -- ids -------------------------------------------------------------------
    def _id_op(self, op: str, dim: int):
        lsz = self.prog.lsz
        ngrp = self.prog.ngrp
        if op == "local_size":
            return jnp.int32(lsz[dim])
        if op == "num_groups":
            return jnp.int32(ngrp[dim])
        if op == "global_size":
            return jnp.int32(lsz[dim] * ngrp[dim])
        if op == "local_id":
            return self._local_id(dim)
        if op == "group_id":
            return self._group_id(dim)
        if op == "global_id":
            return self._group_id(dim) * lsz[dim] + self._local_id(dim)
        raise AssertionError(op)

    def _local_id(self, dim: int):
        lsz = self.prog.lsz
        lin = self.lids
        if dim == 0:
            return lax.rem(lin, jnp.int32(lsz[0]))
        if dim == 1:
            return lax.rem(lax.div(lin, jnp.int32(lsz[0])), jnp.int32(lsz[1]))
        return lax.div(lin, jnp.int32(lsz[0] * lsz[1]))

    def _group_id(self, dim: int):
        ngrp = self.prog.ngrp
        g = jnp.asarray(self.gl, jnp.int32)
        if dim == 0:
            return lax.rem(g, jnp.int32(ngrp[0]))
        if dim == 1:
            return lax.rem(lax.div(g, jnp.int32(ngrp[0])), jnp.int32(ngrp[1]))
        return lax.div(g, jnp.int32(ngrp[0] * ngrp[1]))

    # -- instruction execution --------------------------------------------------
    def exec_instr(self, ins: Instr, pred) -> None:
        op = ins.op
        if op == "vreg_read":
            name = ins.attrs["vreg"]
            if name not in self.vregs:
                dt = ins.attrs["dtype"]
                shape = (self.L,) if self._varying(name) else ()
                self.vregs[name] = jnp.zeros(shape, dt)
            r = self.vregs[name]
        elif op == "vreg_write":
            name = ins.attrs["vreg"]
            v = jnp.asarray(self.val(ins.operands[0]))
            old = self.vregs.get(name)
            if pred is None or old is None:
                nv = v if pred is None else jnp.where(pred, v, jnp.zeros_like(v))
            else:
                nv = jnp.where(pred, v, old)
            self.vregs[name] = self._bcast_vreg(name, nv)
            return
        elif op == "convert":
            r = jnp.asarray(self.val(ins.operands[0])).astype(ins.result.dtype)
        elif op in ir.BINOPS or op in ir.CMPOPS:
            a = jnp.asarray(self.val(ins.operands[0]))
            b = jnp.asarray(self.val(ins.operands[1]))
            r = _BIN_JAX[op](a, b)
            if op not in ir.CMPOPS:
                r = r.astype(ins.result.dtype)
        elif op in ir.UNOPS:
            a = jnp.asarray(self.val(ins.operands[0]))
            r = self._unop(op, a).astype(ins.result.dtype)
        elif op == "select":
            c, a, b = (jnp.asarray(self.val(o)) for o in ins.operands)
            r = jnp.where(c, a, b)
        elif op in ir.ID_OPS:
            r = self._id_op(op, ins.attrs["dim"])
        elif op == "load":
            buf = self.buffers[ins.attrs["buffer"]]
            idx = jnp.asarray(self.val(ins.operands[0]), jnp.int32)
            r = jnp.take(buf, idx, mode="clip")
        elif op == "store":
            buf = self.buffers[ins.attrs["buffer"]]
            idx = jnp.asarray(self.val(ins.operands[0]), jnp.int32)
            v = jnp.asarray(self.val(ins.operands[1]), buf.dtype)
            if pred is None:
                idx_b, v_b = jnp.broadcast_arrays(idx, v)
                self.buffers[ins.attrs["buffer"]] = buf.at[idx_b].set(v_b)
            else:
                idx_b, v_b, p = jnp.broadcast_arrays(idx, v, pred)
                safe = jnp.where(p, idx_b, jnp.int32(buf.shape[0]))
                self.buffers[ins.attrs["buffer"]] = \
                    buf.at[safe].set(v_b, mode="drop")
            return
        elif op == "barrier":
            raise AssertionError("barrier inside a parallel region")
        else:
            raise NotImplementedError(f"vector target: op {op}")
        if ins.result is not None:
            self.env[ins.result.id] = r

    def _unop(self, op: str, a):
        if self.prog.use_vml and op in _VML_OPS:
            from ... import vml
            return getattr(vml, _VML_OPS[op])(a)
        return _UN_JAX[op](a)

    # -- region execution ---------------------------------------------------------
    def exec_region(self, region: Region) -> Dict[str, object]:
        """Run a region; returns {exit barrier -> predicate} ('' for Return)."""
        if region.entry is None:
            return {}
        plan = self.prog.region_plans[region.barrier]
        exits: Dict[str, object] = {}
        self._exec_items(plan, region, entry_pred=None,
                         entry_block=region.entry, exits=exits)
        return exits

    def _exec_items(self, items: List[object], region: Region, entry_pred,
                    entry_block: str, exits: Dict[str, object]) -> None:
        fn = self.fn
        edge_preds: Dict[Tuple[str, str], object] = {}
        reached: Set[str] = set()

        def incoming(name: str, scope_blocks: Set[str]):
            ps = [edge_preds[(p, name)] for p in scope_blocks
                  if (p, name) in edge_preds]
            if name == entry_block:
                if ps:
                    return _por(ps + [entry_pred])
                return entry_pred
            if not ps:
                return "UNREACHED"
            return _por(ps)

        scope_blocks: Set[str] = set()
        for it in items:
            if isinstance(it, BlockNode):
                scope_blocks.add(it.name)
            else:
                scope_blocks |= it.blocks

        for it in items:
            if isinstance(it, BlockNode):
                name = it.name
                pred = incoming(name, scope_blocks)
                if isinstance(pred, str):
                    continue  # unreachable within this execution
                blk = fn.blocks[name]
                for ins in blk.instrs:
                    self.exec_instr(ins, pred)
                term = blk.terminator
                if isinstance(term, Jump):
                    self._route(term.target, pred, region, edge_preds, exits,
                                name)
                elif isinstance(term, CondBranch):
                    c = jnp.asarray(self.val(term.cond))
                    self._route(term.if_true, _pand(pred, c), region,
                                edge_preds, exits, name)
                    self._route(term.if_false, _pnot_and(pred, c), region,
                                edge_preds, exits, name)
                else:  # Return — terminal region
                    exits[""] = pred
            else:  # LoopNode
                pred_enter = incoming(it.header, scope_blocks)
                if isinstance(pred_enter, str):
                    continue
                self._exec_loop(it, region, pred_enter)
                self._route(it.exit_target, pred_enter, region, edge_preds,
                            exits, it.header)

    def _route(self, target: str, pred, region: Region,
               edge_preds, exits, src: str) -> None:
        if target in region.blocks:
            key = (src, target)
            if key in edge_preds:
                edge_preds[key] = _por([edge_preds[key], pred])
            else:
                edge_preds[key] = pred
        else:
            # region exit: successor barrier
            if target in exits:
                exits[target] = _por([exits[target], pred])
            else:
                exits[target] = pred

    # -- loops ------------------------------------------------------------------
    def _exec_loop(self, node: LoopNode, region: Region, pred_enter) -> None:
        fn = self.fn
        hdr = fn.blocks[node.header]
        term = hdr.terminator
        assert isinstance(term, CondBranch)
        cond_val = term.cond
        body_first = term.if_true == node.body_entry

        def exec_header(pred):
            for ins in hdr.instrs:
                self.exec_instr(ins, pred)
            c = jnp.asarray(self.val(cond_val))
            return c if body_first else jnp.logical_not(c)

        # values defined in the header survive the loop (they dominate the
        # exit block); latch them across iterations.
        header_vals = [ins.result for ins in hdr.instrs
                       if ins.result is not None]
        loop_vregs = sorted(self._vregs_written(node.blocks))
        buf_names = sorted(self.buffers)

        c0 = exec_header(pred_enter)
        scalar_path = (jnp.ndim(c0) == 0) and (
            pred_enter is None or jnp.ndim(pred_enter) == 0)

        # make sure every loop vreg exists before entering the carry
        for nm in loop_vregs:
            if nm not in self.vregs:
                dt = self._vreg_dtype(nm)
                shape = (self.L,) if self._varying(nm) else ()
                self.vregs[nm] = jnp.zeros(shape, dt)

        if scalar_path:
            # Lock-step loop with a scalar trip condition: this is the §4.6
            # horizontally-parallelized form — all work-items iterate together
            # and the body executes fully vectorized with no masks.
            c_init = c0 if pred_enter is None else jnp.logical_and(
                c0, pred_enter)
            carry0 = (jnp.asarray(c_init, jnp.bool_),
                      tuple(self.vregs[n] for n in loop_vregs),
                      tuple(self.buffers[n] for n in buf_names),
                      tuple(self.env[v.id] for v in header_vals))

            def cond_fn(carry):
                return carry[0]

            def body_fn(carry):
                _, vr, bufs, hv = carry
                sub = self._fork(vr, bufs, loop_vregs, buf_names,
                                 header_vals, hv)
                sub._exec_items(node.body_items, region,
                                entry_pred=pred_enter,
                                entry_block=node.body_entry, exits={})
                for ins in hdr.instrs:
                    sub.exec_instr(ins, pred_enter)
                c = jnp.asarray(sub.val(cond_val))
                c = c if body_first else jnp.logical_not(c)
                return (jnp.asarray(c, jnp.bool_),
                        tuple(sub.vregs[n] for n in loop_vregs),
                        tuple(sub.buffers[n] for n in buf_names),
                        tuple(sub.env[v.id] for v in header_vals))

            out = lax.while_loop(cond_fn, body_fn, carry0)
            _, vr, bufs, hv = out
        else:
            it0 = _pand(_as_lanes(pred_enter, self.L), _as_lanes(c0, self.L))
            hv0 = tuple(jnp.where(it0, self.env[v.id], self.env[v.id])
                        for v in header_vals)
            carry0 = (it0,
                      tuple(self.vregs[n] for n in loop_vregs),
                      tuple(self.buffers[n] for n in buf_names),
                      hv0)

            def cond_fn(carry):
                return jnp.any(carry[0])

            def body_fn(carry):
                it, vr, bufs, hv = carry
                sub = self._fork(vr, bufs, loop_vregs, buf_names,
                                 header_vals, hv)
                sub._exec_items(node.body_items, region, entry_pred=it,
                                entry_block=node.body_entry, exits={})
                for ins in hdr.instrs:
                    sub.exec_instr(ins, it)
                c = jnp.asarray(sub.val(cond_val))
                c = c if body_first else jnp.logical_not(c)
                new_hv = tuple(jnp.where(it, sub.env[v.id], old)
                               for v, old in zip(header_vals, hv))
                new_it = jnp.logical_and(it, _as_lanes(c, self.L))
                return (new_it,
                        tuple(sub.vregs[n] for n in loop_vregs),
                        tuple(sub.buffers[n] for n in buf_names),
                        new_hv)

            out = lax.while_loop(cond_fn, body_fn, carry0)
            _, vr, bufs, hv = out

        for n, v in zip(loop_vregs, vr):
            self.vregs[n] = v
        for n, v in zip(buf_names, bufs):
            self.buffers[n] = v
        for val, v in zip(header_vals, hv):
            self.env[val.id] = v

    def _fork(self, vr, bufs, loop_vregs, buf_names, header_vals, hv):
        sub = LaneExec.__new__(LaneExec)
        sub.prog = self.prog
        sub.fn = self.fn
        sub.L = self.L
        sub.lids = self.lids
        sub.gl = self.gl
        sub.env = dict(self.env)
        sub.vregs = dict(self.vregs)
        sub.buffers = dict(self.buffers)
        for n, v in zip(loop_vregs, vr):
            sub.vregs[n] = v
        for n, v in zip(buf_names, bufs):
            sub.buffers[n] = v
        for val, v in zip(header_vals, hv):
            sub.env[val.id] = v
        return sub

    def _vregs_written(self, blocks: Set[str]) -> Set[str]:
        out: Set[str] = set()
        for b in blocks:
            for ins in self.fn.blocks[b].instrs:
                if ins.op == "vreg_write":
                    out.add(ins.attrs["vreg"])
        return out

    def _vreg_dtype(self, name: str) -> str:
        for blk in self.fn.blocks.values():
            for ins in blk.instrs:
                if ins.op in ("vreg_read", "vreg_write") \
                        and ins.attrs["vreg"] == name:
                    return ins.attrs["dtype"]
        raise KeyError(name)


def _as_lanes(p, L: int):
    if p is None:
        return jnp.ones((L,), jnp.bool_)
    if jnp.ndim(p) == 0:
        return jnp.broadcast_to(p, (L,))
    return p


_BIN_JAX = {
    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
    "div": lambda a, b: lax.div(a, b) if jnp.issubdtype(a.dtype, jnp.integer)
    else a / b,
    "rem": lambda a, b: lax.rem(a, b),
    "min": jnp.minimum, "max": jnp.maximum, "pow": jnp.power,
    "and": lambda a, b: a & b, "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
    "shl": jnp.left_shift, "shr": jnp.right_shift,
    "lt": jnp.less, "le": jnp.less_equal, "gt": jnp.greater,
    "ge": jnp.greater_equal, "eq": jnp.equal, "ne": jnp.not_equal,
}

_UN_JAX = {
    "neg": jnp.negative,
    "not": lambda a: jnp.logical_not(a) if a.dtype == jnp.bool_ else ~a,
    "abs": jnp.abs, "exp": jnp.exp, "log": jnp.log, "sin": jnp.sin,
    "cos": jnp.cos, "tanh": jnp.tanh, "erf": jax.scipy.special.erf,
    "sqrt": jnp.sqrt, "rsqrt": lax.rsqrt, "floor": jnp.floor,
    "ceil": jnp.ceil, "rint": jnp.round,
}

# ops served by Vecmathlib (§5) when use_vml=True
_VML_OPS = {"exp": "exp", "log": "log", "sin": "sin", "cos": "cos",
            "tanh": "tanh", "erf": "erf", "sqrt": "sqrt", "rsqrt": "rsqrt"}


# ---------------------------------------------------------------------------
# Work-group program
# ---------------------------------------------------------------------------

class WGProgram:
    """A compiled work-group function for a fixed local size (the paper
    compiles one work-group function per local size at enqueue time, §4.1).

    This class is purely the target-specific *parallel mapping* half of
    the pipeline: it consumes a prebuilt, shared
    :class:`~repro.core.passes.WorkGroupPlan` (regions, schedule,
    uniformity facts, context slots, parallelism metadata) and binds it to
    a lane count.  It performs no region formation or analysis of its own —
    passing a raw :class:`Function` is a compatibility path that builds the
    plan through the pass manager first."""

    def __init__(self, plan: "WorkGroupPlan | Function",
                 local_size: Sequence[int],
                 horizontal: bool = True, merge_uniform: bool = True,
                 use_vml: bool = False):
        self.lsz = tuple(local_size) + (1,) * (3 - len(local_size))
        self.L = int(np.prod(self.lsz))
        self.use_vml = use_vml
        self.horizontal = horizontal

        if not isinstance(plan, WorkGroupPlan):
            plan = build_plan(plan, horizontal=horizontal,
                              merge_uniform=merge_uniform)
        self.wgplan: WorkGroupPlan = plan
        self.wg: WGInfo = plan.wg
        self.uni = plan.uni
        self.plan: ContextPlan = plan.ctx
        self.region_plans = plan.region_plans
        self.md = plan.md
        self.order = self.wg.order
        self.rid_of = {b: i for i, b in enumerate(self.order)}
        self.K = len(self.order)
        # filled per launch
        self.scalars: Dict[str, jnp.ndarray] = {}
        self.ngrp = (1, 1, 1)

    # -- context helpers -------------------------------------------------------
    def _ctx_init(self):
        out = []
        for s in self.plan.slots:
            shape = () if s.uniform else (self.L,)
            out.append(jnp.zeros(shape, s.dtype))
        return tuple(out)

    def _seed(self, ex: LaneExec, ctx) -> None:
        for s, v in zip(self.plan.slots, ctx):
            if s.kind == "val":
                ex.env[s.key] = v
            else:
                ex.vregs[s.key] = v

    def _harvest(self, ex: LaneExec, ctx):
        out = []
        for s, old in zip(self.plan.slots, ctx):
            if s.kind == "val":
                v = ex.env.get(s.key, old)
            else:
                v = ex.vregs.get(s.key, old)
            if not s.uniform and jnp.ndim(v) == 0:
                v = jnp.broadcast_to(v, (self.L,))
            elif s.uniform and jnp.ndim(v) > jnp.ndim(old):
                # the executor may represent a (provably) uniform value
                # lane-broadcast; collapse to lane 0 to keep the carry
                # type stable across regions
                v = jnp.asarray(v)[0]
            out.append(jnp.asarray(v).astype(s.dtype))
        return tuple(out)

    # -- single work-group execution --------------------------------------------
    def run_wg(self, buffers: Dict[str, jnp.ndarray], group_linear,
               lids_linear=None):
        """Execute one work-group. ``buffers`` threaded functionally."""
        lids = jnp.arange(self.L, dtype=jnp.int32) if lids_linear is None \
            else lids_linear
        buf_names = sorted(buffers)
        ctx = self._ctx_init()

        def run_region(bar: str, ctx, bufs_t):
            bufs = dict(zip(buf_names, bufs_t))
            ex = LaneExec(self, lids, group_linear, bufs, {})
            self._seed(ex, ctx)
            exits = ex.exec_region(self.wg.regions[bar])
            new_ctx = self._harvest(ex, ctx)
            new_bufs = tuple(ex.buffers[n] for n in buf_names)
            # next region id from lane 0 (peeled first work-item, §4.4)
            rid = jnp.int32(self.K)
            for tgt, pred in exits.items():
                if tgt == "":
                    continue
                p0 = pred if pred is None or jnp.ndim(pred) == 0 \
                    else pred[0]
                t = jnp.int32(self.rid_of[tgt])
                rid = t if p0 is None else jnp.where(p0, t, rid)
            return rid, new_ctx, new_bufs

        bufs_t = tuple(buffers[n] for n in buf_names)
        if self.wg.is_chain():
            for bar in self.wg.chain():
                _, ctx, bufs_t = run_region(bar, ctx, bufs_t)
            return dict(zip(buf_names, bufs_t))

        # general scheduler: while(switch(rid))
        branches = [
            (lambda bar: (lambda st: run_region(bar, st[1], st[2])))(bar)
            for bar in self.order]

        def cond_fn(st):
            return st[0] < self.K

        def body_fn(st):
            return lax.switch(st[0], branches, st)

        st0 = (jnp.int32(0), ctx, bufs_t)
        _, ctx, bufs_t = lax.while_loop(cond_fn, body_fn, st0)
        return dict(zip(buf_names, bufs_t))

    # -- NDRange execution ------------------------------------------------------
    def run_ndrange(self, buffers: Dict[str, np.ndarray],
                    scalars: Optional[Dict[str, object]],
                    global_size: Sequence[int],
                    group_range: Optional[Tuple[int, int]] = None):
        """Execute the NDRange.  ``group_range=(lo, hi)`` runs only that
        contiguous range of linearized work-groups *of the full NDRange*
        (group-id decoding still uses the full grid) — the sub-range unit
        the multi-device co-execution scheduler dispatches
        (runtime/scheduler.py); ``None`` runs every group."""
        gsz = tuple(global_size) + (1,) * (3 - len(global_size))
        for g, l in zip(gsz, self.lsz):
            assert g % l == 0, "global size must divide local size"
        self.ngrp = tuple(g // l for g, l in zip(gsz, self.lsz))
        n_groups = int(np.prod(self.ngrp))
        self.scalars = {}
        scalars = scalars or {}
        for a in self.wg.fn.scalar_args:
            self.scalars[a.name] = jnp.asarray(scalars[a.name], a.dtype)

        local_defs = [a for a in self.wg.fn.buffer_args
                      if a.space == ir.LOCAL and a.name not in buffers]
        bufs = {k: jnp.asarray(v) for k, v in buffers.items()}
        global_names = sorted(bufs)

        def one_group(g, bufs_t):
            b = dict(zip(global_names, bufs_t))
            for la in local_defs:
                b[la.name] = jnp.zeros(la.size, la.dtype)
            out = self.run_wg(b, g)
            return tuple(out[n] for n in global_names)

        lo, hi = (0, n_groups) if group_range is None \
            else (int(group_range[0]), int(group_range[1]))
        assert 0 <= lo <= hi <= n_groups, \
            f"group_range {group_range} outside [0, {n_groups}]"
        bufs_t = tuple(bufs[n] for n in global_names)
        if hi - lo == 1:
            bufs_t = one_group(jnp.int32(lo), bufs_t)
        elif hi > lo:
            bufs_t = lax.fori_loop(
                lo, hi, lambda g, bt: one_group(jnp.int32(g), bt),
                bufs_t)
        return dict(zip(global_names, bufs_t))
