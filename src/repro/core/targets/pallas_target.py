"""Pallas target: work-groups on the TPU grid, lanes on the VPU.

The TPU-native parallel mapping (DESIGN.md §2): one work-group per grid
cell of a ``pl.pallas_call``; the work-item lane axis of the vector executor
becomes the 128-wide vector lane axis; OpenCL ``local`` memory becomes VMEM
scratch (materialized as register arrays here — locals are work-group
private, so they never leave the grid cell).  Barrier semantics need no
hardware primitive — after region formation the regions run in sequence over
full lane vectors (the same argument the paper makes for WI loops).

Global buffers are passed whole because generic SPMD kernels compute
arbitrary addresses; the TPU grid is sequential, so aliased output refs give
every work-group a consistent running view — legal under OpenCL's
no-inter-group-dependency contract.

Validated with ``interpret=True`` on CPU; on real TPUs the same code lowers
to Mosaic.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import ir
from .vector import WGProgram


class PallasWGProgram(WGProgram):
    interpret = True  # CPU container; flip to False on real TPUs

    def run_ndrange(self, buffers: Dict[str, np.ndarray],
                    scalars: Optional[Dict[str, object]],
                    global_size: Sequence[int],
                    group_range: Optional[Tuple[int, int]] = None):
        """Execute the NDRange on the Pallas grid.  ``group_range=(lo,
        hi)`` shrinks the grid to ``hi - lo`` cells and offsets
        ``program_id`` by ``lo``, so the sub-range sees its true group ids
        of the full NDRange (multi-device co-execution unit)."""
        gsz = tuple(global_size) + (1,) * (3 - len(global_size))
        for g, l in zip(gsz, self.lsz):
            assert g % l == 0, "global size must divide local size"
        self.ngrp = tuple(g // l for g, l in zip(gsz, self.lsz))
        n_groups = int(np.prod(self.ngrp))
        lo, hi = (0, n_groups) if group_range is None \
            else (int(group_range[0]), int(group_range[1]))
        assert 0 <= lo <= hi <= n_groups, \
            f"group_range {group_range} outside [0, {n_groups}]"
        if hi == lo:
            return {k: jnp.asarray(v) for k, v in buffers.items()}
        self.scalars = {}
        scalars = scalars or {}
        for a in self.wg.fn.scalar_args:
            # numpy (not jnp) so the value embeds as a literal in the
            # kernel jaxpr — pallas_call rejects captured device consts
            self.scalars[a.name] = np.asarray(scalars[a.name],
                                              np.dtype(a.dtype))

        local_defs = [a for a in self.wg.fn.buffer_args
                      if a.space == ir.LOCAL and a.name not in buffers]
        bufs = {k: jnp.asarray(v) for k, v in buffers.items()}
        names = sorted(bufs)

        def kernel(*refs):
            # inputs are aliased to outputs: out_refs carry the running state
            out_refs = refs[len(names):]
            g = pl.program_id(0) + lo  # true group id within the full grid
            b = {nm: oref[...] for nm, oref in zip(names, out_refs)}
            for la in local_defs:
                b[la.name] = jnp.zeros((la.size,), la.dtype)
            out = self.run_wg(b, g)
            for nm, oref in zip(names, out_refs):
                oref[...] = out[nm]

        call = pl.pallas_call(
            kernel,
            grid=(hi - lo,),
            in_specs=[pl.BlockSpec(bufs[n].shape,
                                   lambda g, nd=bufs[n].ndim: (0,) * nd)
                      for n in names],
            out_specs=[pl.BlockSpec(bufs[n].shape,
                                    lambda g, nd=bufs[n].ndim: (0,) * nd)
                       for n in names],
            out_shape=[jax.ShapeDtypeStruct(bufs[n].shape, bufs[n].dtype)
                       for n in names],
            input_output_aliases={i: i for i in range(len(names))},
            interpret=self.interpret,
        )
        out = call(*[bufs[n] for n in names])
        return dict(zip(names, out))
