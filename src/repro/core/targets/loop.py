"""Serial loop target: the 'basic' CPU driver analogue (paper §3).

Executes each parallel region with an explicit work-item loop
(``lax.fori_loop`` over local ids) — the literal "WI loop" form of §4.3
before any vectorization.  Semantically identical to the vector target; it
exists (a) as the portability baseline every device gets for free, and
(b) as the performance baseline the benchmarks compare the vectorized
mapping against (paper Figs. 12–14 compare pocl's static vectorization to
serial/fiber execution).

The next-region decision is taken from work-item 0 — the "peeled first
iteration" of §4.4 that evaluates the (work-group-uniform) branch for the
rest of the work-items.
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
from jax import lax

from .vector import LaneExec, WGProgram


class LoopWGProgram(WGProgram):
    def run_wg(self, buffers: Dict[str, jnp.ndarray], group_linear,
               lids_linear=None):
        buf_names = sorted(buffers)
        ctx = self._ctx_init()

        def run_region(bar: str, ctx, bufs_t):
            region = self.wg.regions[bar]

            def wi_body(wi, st):
                rid_acc, ctx, bufs_t = st
                lids = jnp.reshape(jnp.int32(wi), (1,))
                bufs = dict(zip(buf_names, bufs_t))
                ex = LaneExec(self, lids, group_linear, bufs, {})
                # seed this work-item's context row
                for s, arr in zip(self.plan.slots, ctx):
                    v = arr if s.uniform else \
                        lax.dynamic_slice(arr, (wi,), (1,))
                    if s.kind == "val":
                        ex.env[s.key] = v
                    else:
                        ex.vregs[s.key] = v
                exits = ex.exec_region(region)
                new_ctx = []
                for s, arr in zip(self.plan.slots, ctx):
                    v = ex.env.get(s.key) if s.kind == "val" \
                        else ex.vregs.get(s.key)
                    if v is None:
                        new_ctx.append(arr)
                    elif s.uniform:
                        # LaneExec computes at lane-width 1, so a uniform
                        # value may come back shaped (1,); reshape to the
                        # carry's scalar shape to keep the loop type fixed
                        new_ctx.append(jnp.reshape(
                            jnp.asarray(v, arr.dtype), arr.shape))
                    else:
                        row = jnp.broadcast_to(jnp.asarray(v, arr.dtype),
                                               (1,))
                        new_ctx.append(
                            lax.dynamic_update_slice(arr, row, (wi,)))
                new_bufs = tuple(ex.buffers[n] for n in buf_names)
                # peel: work-item 0 decides the next region
                rid = jnp.int32(self.K)
                for tgt, pred in exits.items():
                    if tgt == "":
                        continue
                    p0 = pred if pred is None or jnp.ndim(pred) == 0 \
                        else pred[0]
                    t = jnp.int32(self.rid_of[tgt])
                    rid = t if p0 is None else jnp.where(p0, t, rid)
                rid_acc = jnp.where(wi == 0, rid, rid_acc)
                return rid_acc, tuple(new_ctx), new_bufs

            st = (jnp.int32(self.K), ctx, bufs_t)
            st = lax.fori_loop(0, self.L, wi_body, st)
            return st

        bufs_t = tuple(buffers[n] for n in buf_names)
        if self.wg.is_chain():
            for bar in self.wg.chain():
                _, ctx, bufs_t = run_region(bar, ctx, bufs_t)
            return dict(zip(buf_names, bufs_t))

        branches = [
            (lambda bar: (lambda st: run_region(bar, st[1], st[2])))(bar)
            for bar in self.order]

        def cond_fn(st):
            return st[0] < self.K

        def body_fn(st):
            return lax.switch(st[0], branches, st)

        st0 = (jnp.int32(0), ctx, bufs_t)
        _, ctx, bufs_t = lax.while_loop(cond_fn, body_fn, st0)
        return dict(zip(buf_names, bufs_t))
