from . import vector, loop  # noqa: F401
