"""repro.core — the pocl kernel compiler, rebuilt for JAX/TPU.

Public API:
  KernelBuilder    — author SPMD kernels (OpenCL C analogue)
  Program / Kernel — first-class host objects over the compiler
                     (docs/host_api.md): build once, set_arg, enqueue
                     anywhere; created through a runtime Context
  compile_kernel   — deprecated direct entry point (run the pocl
                     pipeline for a local size + target); kept as a shim
                     over the same cache/pipeline machinery
  PassManager      — the middle-end pass pipeline (docs/compiler.md);
                     build_plan runs it, producing the WorkGroupPlan all
                     targets share; plan_count counts pipeline runs
  run_ndrange      — fiber-based reference executor (semantics oracle)
  CompilationCache — LRU + disk compilation cache, with a stage-level
                     plan tier and a fused-chain tier (docs/caching.md)
  stitch_functions — DAG-level kernel fusion: compose one IR Function
                     from an elementwise producer→consumer chain
                     (docs/compiler.md §Fusion); FusedSpec/build_fused_spec
                     are the cached runtime product
  TuningTable      — persistent per-kernel-shape target winners
  ReproError       — typed error hierarchy with OpenCL-style status
                     codes (InvalidArgError, BuildError, MapError, ...)
"""

from .dsl import KernelBuilder
from .api import compile_kernel, compile_count, CompiledKernel
from .cache import (CacheKey, CompilationCache, FusedKey, PlanKey,
                    canonical_ir, default_cache, ir_hash,
                    reset_default_cache)
from .errors import (BuildError, InvalidArgError, InvalidBufferError,
                     MapError, ReproError, status_name)
from .fusion import (ChainEdge, FusedSpec, FusionError, build_fused_spec,
                     fusible_kernel, make_fused_key, stitch_functions)
from .passes import (BufferFootprint, KernelFusibility, ParallelRegionMD,
                     Pass, PassManager, VerifierError, WorkGroupPlan,
                     build_plan, kernel_fusibility, plan_count, verify_ir)
from .program import Kernel, Program
from .autotune import AutotunedKernel, TuningTable, default_table, \
    set_default_table
from .interp import run_ndrange

__all__ = [
    "KernelBuilder", "compile_kernel", "compile_count", "CompiledKernel",
    "Program", "Kernel",
    "CacheKey", "CompilationCache", "FusedKey", "PlanKey", "canonical_ir",
    "default_cache", "ir_hash", "reset_default_cache",
    "ReproError", "InvalidArgError", "InvalidBufferError", "BuildError",
    "MapError", "status_name",
    "ChainEdge", "FusedSpec", "FusionError", "build_fused_spec",
    "fusible_kernel", "make_fused_key", "stitch_functions",
    "BufferFootprint", "KernelFusibility", "ParallelRegionMD",
    "Pass", "PassManager", "VerifierError",
    "WorkGroupPlan", "build_plan", "kernel_fusibility", "plan_count",
    "verify_ir",
    "AutotunedKernel", "TuningTable", "default_table", "set_default_table",
    "run_ndrange",
]
