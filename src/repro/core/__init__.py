"""repro.core — the pocl kernel compiler, rebuilt for JAX/TPU.

Public API:
  KernelBuilder  — author SPMD kernels (OpenCL C analogue)
  compile_kernel — run the pocl pipeline for a local size + target
  run_ndrange    — fiber-based reference executor (semantics oracle)
"""

from .dsl import KernelBuilder
from .api import compile_kernel, CompiledKernel
from .interp import run_ndrange

__all__ = ["KernelBuilder", "compile_kernel", "CompiledKernel", "run_ndrange"]
