"""repro.core — the pocl kernel compiler, rebuilt for JAX/TPU.

Public API:
  KernelBuilder    — author SPMD kernels (OpenCL C analogue)
  compile_kernel   — run the pocl pipeline for a local size + target
                     (memoized in a content-addressed compilation cache;
                     target="auto" routes through the autotuner)
  PassManager      — the middle-end pass pipeline (docs/compiler.md);
                     build_plan runs it, producing the WorkGroupPlan all
                     targets share; plan_count counts pipeline runs
  run_ndrange      — fiber-based reference executor (semantics oracle)
  CompilationCache — LRU + disk compilation cache, with a stage-level
                     plan tier (docs/caching.md)
  TuningTable      — persistent per-kernel-shape target winners
"""

from .dsl import KernelBuilder
from .api import compile_kernel, compile_count, CompiledKernel
from .cache import (CacheKey, CompilationCache, PlanKey, canonical_ir,
                    default_cache, ir_hash, reset_default_cache)
from .passes import (ParallelRegionMD, Pass, PassManager, VerifierError,
                     WorkGroupPlan, build_plan, plan_count, verify_ir)
from .autotune import AutotunedKernel, TuningTable, default_table, \
    set_default_table
from .interp import run_ndrange

__all__ = [
    "KernelBuilder", "compile_kernel", "compile_count", "CompiledKernel",
    "CacheKey", "CompilationCache", "PlanKey", "canonical_ir",
    "default_cache", "ir_hash", "reset_default_cache",
    "ParallelRegionMD", "Pass", "PassManager", "VerifierError",
    "WorkGroupPlan", "build_plan", "plan_count", "verify_ir",
    "AutotunedKernel", "TuningTable", "default_table", "set_default_table",
    "run_ndrange",
]
