from .config import ModelConfig, ShapeConfig, ALL_SHAPES, shapes_for
from .model import (forward, loss_fn, init_params, abstract_params,
                    init_caches, cache_logical_axes, model_defs)

__all__ = ["ModelConfig", "ShapeConfig", "ALL_SHAPES", "shapes_for",
           "forward", "loss_fn", "init_params", "abstract_params",
           "init_caches", "cache_logical_axes", "model_defs"]
