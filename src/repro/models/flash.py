"""Memory-efficient blocked attention with a recompute-based custom VJP.

Naive autodiff of an online-softmax scan saves every (bq x bk) probability
matrix — O(S²) residuals, tens of GiB at 4k x 256 batch.  The standard
(FlashAttention) answer is a custom VJP that saves only (o, lse) and
recomputes p blockwise in the backward pass.  This is the XLA-path
counterpart of the Pallas flash kernel; on TPU the Pallas kernel replaces
the forward, while this VJP structure still drives the backward.

Layouts are (B, H, S, D) internally; the public wrapper accepts
(B, S, H, D) with GQA K/V and handles repeat/padding.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG = -1e30


def _mask(qpos, kpos, causal: bool, sk_valid: int):
    m = (kpos[None, :] < sk_valid)
    if causal:
        m = jnp.logical_and(m, kpos[None, :] <= qpos[:, None])
    return m


def _fwd_scan(q, k, v, *, causal, bq, bk, sk_valid, q_offset):
    """q: (B,H,Sq,D) padded; k/v: (B,H,Sk,D) padded.  Returns (o, lse)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    nq, nk = Sq // bq, Sk // bk
    kb = k.reshape(B, H, nk, bk, D)
    vb = v.reshape(B, H, nk, bk, D)

    def q_block(qi, qblk):
        qpos = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, j):
            m, l, acc = carry
            kblk = kb[:, :, j]
            vblk = vb[:, :, j]
            kpos = j * bk + jnp.arange(bk)
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk,
                           preferred_element_type=jnp.float32)
            s = jnp.where(_mask(qpos, kpos, causal, sk_valid)[None, None],
                          s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * alpha[..., None] + pv), None

        m0 = jnp.full((B, H, bq), NEG, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, H, bq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o = (acc / l_safe[..., None]).astype(q.dtype)
        lse = m + jnp.log(l_safe)
        return o, lse

    o, lse = jax.lax.map(
        lambda args: q_block(*args),
        (jnp.arange(nq), q.reshape(B, H, nq, bq, D).transpose(2, 0, 1, 3, 4)))
    o = o.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, D)
    lse = lse.transpose(1, 2, 0, 3).reshape(B, H, Sq)
    return o, lse


def _bwd_scan(q, k, v, o, lse, do, *, causal, bq, bk, sk_valid, q_offset):
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    nq, nk = Sq // bq, Sk // bk
    scale_dt = jnp.float32
    Drow = jnp.sum(do.astype(scale_dt) * o.astype(scale_dt), axis=-1)  # BHS

    qb = q.reshape(B, H, nq, bq, D)
    dob = do.reshape(B, H, nq, bq, D)
    lseb = lse.reshape(B, H, nq, bq)
    Drb = Drow.reshape(B, H, nq, bq)

    def kv_block(dq_acc, j):
        kblk = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=2)
        vblk = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=2)
        kpos = j * bk + jnp.arange(bk)

        def q_step(carry, i):
            dq_acc, dk_j, dv_j = carry
            qblk = qb[:, :, i]
            doblk = dob[:, :, i]
            qpos = q_offset + i * bq + jnp.arange(bq)
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk,
                           preferred_element_type=jnp.float32)
            msk = _mask(qpos, kpos, causal, sk_valid)[None, None]
            s = jnp.where(msk, s, NEG)
            p = jnp.exp(s - lseb[:, :, i][..., None])        # (B,H,bq,bk)
            dv_j = dv_j + jnp.einsum("bhqk,bhqd->bhkd",
                                     p, doblk.astype(jnp.float32))
            dp = jnp.einsum("bhqd,bhkd->bhqk", doblk.astype(jnp.float32),
                            vblk.astype(jnp.float32))
            ds = p * (dp - Drb[:, :, i][..., None])
            ds = jnp.where(msk, ds, 0.0)
            dq_i = jnp.einsum("bhqk,bhkd->bhqd", ds,
                              kblk.astype(jnp.float32))
            prev = jax.lax.dynamic_slice_in_dim(dq_acc, i * bq, bq, axis=2)
            dq_acc = jax.lax.dynamic_update_slice_in_dim(
                dq_acc, prev + dq_i, i * bq, axis=2)
            dk_j = dk_j + jnp.einsum("bhqk,bhqd->bhkd", ds,
                                     qblk.astype(jnp.float32))
            return (dq_acc, dk_j, dv_j), None

        dk0 = jnp.zeros((B, H, bk, D), jnp.float32)
        dv0 = jnp.zeros((B, H, bk, D), jnp.float32)
        (dq_acc, dk_j, dv_j), _ = jax.lax.scan(
            q_step, (dq_acc, dk0, dv0), jnp.arange(nq))
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(kv_block, dq0, jnp.arange(nk))
    dk = dk_blocks.transpose(1, 2, 0, 3, 4).reshape(B, H, Sk, D)
    dv = dv_blocks.transpose(1, 2, 0, 3, 4).reshape(B, H, Sk, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(meta, q, k, v):
    causal, bq, bk, sk_valid, q_offset = meta
    o, _ = _fwd_scan(q, k, v, causal=causal, bq=bq, bk=bk,
                     sk_valid=sk_valid, q_offset=q_offset)
    return o


def _flash_fwd(meta, q, k, v):
    causal, bq, bk, sk_valid, q_offset = meta
    o, lse = _fwd_scan(q, k, v, causal=causal, bq=bq, bk=bk,
                       sk_valid=sk_valid, q_offset=q_offset)
    return o, (q, k, v, o, lse)


def _flash_bwd(meta, res, do):
    causal, bq, bk, sk_valid, q_offset = meta
    q, k, v, o, lse = res
    dq, dk, dv = _bwd_scan(q, k, v, o, lse, do, causal=causal, bq=bq,
                           bk=bk, sk_valid=sk_valid, q_offset=q_offset)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def blocked_attention(q, k, v, *, causal: bool, block_q: int, block_k: int,
                      q_offset: int = 0):
    """Public wrapper.  q: (B,Sq,H,D); k/v: (B,Sk,KV,D) (GQA broadcast)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    if rep > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (B, Sk, KV, rep, D)) \
            .reshape(B, Sk, H, D)
        v = jnp.broadcast_to(v[:, :, :, None, :], (B, Sk, KV, rep, D)) \
            .reshape(B, Sk, H, D)

    q = (q * (1.0 / math.sqrt(D))).transpose(0, 2, 1, 3)   # (B,H,Sq,D)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))

    meta = (bool(causal), bq, bk, Sk, q_offset)
    o = _flash(meta, q, k, v)
    return o[:, :, :Sq].transpose(0, 2, 1, 3)
