"""Declarative parameter trees.

A single table per architecture declares every parameter's shape, logical
sharding axes, and init scale.  Everything else — real initialization,
abstract ShapeDtypeStructs for the dry-run, and PartitionSpec trees — is
derived from that one table, so the three can never drift apart.  (This is
the same single-source-of-truth discipline pocl applies to its kernel
metadata: the parallelism info is attached once and every later stage
reads it.)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules, logical_to_sharding
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | ssm_a | ssm_dt
    scale: Optional[float] = None  # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


ParamTree = Dict[str, object]   # nested dicts of ParamDef / arrays


def _fan_in_scale(shape: Tuple[int, ...]) -> float:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return 1.0 / math.sqrt(max(fan_in, 1))


def _init_leaf(key, d: ParamDef, dtype) -> jnp.ndarray:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "ssm_a":       # Mamba2: A in [-1.5, -0.5]
        u = jax.random.uniform(key, d.shape, jnp.float32, 0.5, 1.5)
        return (-u).astype(dtype)
    if d.init == "ssm_dt":      # dt bias ~ softplus^-1(U(1e-3, 1e-1))
        u = jax.random.uniform(key, d.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    scale = d.scale if d.scale is not None else _fan_in_scale(d.shape)
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dtype)


def init_params(defs: ParamTree, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs: ParamTree, dtype=jnp.float32):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def param_shardings(defs: ParamTree, mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda d: logical_to_sharding(mesh, rules, d.logical), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def param_pspecs(defs: ParamTree, rules: ShardingRules):
    return jax.tree.map(
        lambda d: rules.spec(*d.logical), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs: ParamTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(int(np.prod(d.shape)) for d in leaves))


# ---------------------------------------------------------------------------
# per-family parameter tables
# ---------------------------------------------------------------------------

def _stack(n: int, d: ParamDef) -> ParamDef:
    """Stack a per-layer def along a leading (replicated) layer axis."""
    return ParamDef((n,) + d.shape, (None,) + d.logical, d.init, d.scale)


def _resid_scale(cfg: ModelConfig, fan_in: int) -> float:
    """Residual-branch output projections: fan-in init divided by
    sqrt(2L) (GPT-2 style) so the residual stream's scale — and hence the
    backward through the pre-norm chain — stays depth-stable."""
    return 1.0 / (math.sqrt(fan_in) * math.sqrt(2.0 * max(cfg.n_layers, 1)))


def attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    return {
        # explicit fan-in scales: the heuristic (shape[-2]) would read the
        # HEAD COUNT for these 3D projections, not d_model
        "wq": ParamDef((d, H, hd), ("embed_fsdp", "heads", "head_dim"),
                       scale=1.0 / math.sqrt(d)),
        "wk": ParamDef((d, KV, hd), ("embed_fsdp", "kv_heads", "head_dim"),
                       scale=1.0 / math.sqrt(d)),
        "wv": ParamDef((d, KV, hd), ("embed_fsdp", "kv_heads", "head_dim"),
                       scale=1.0 / math.sqrt(d)),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed_fsdp"),
                       scale=_resid_scale(cfg, H * hd)),
    }


def cross_attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    return attn_defs(cfg)


def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None,
             ff_axis: str = "mlp") -> Dict[str, ParamDef]:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    out = {
        "w_up": ParamDef((d, f), ("embed_fsdp", ff_axis)),
        "w_down": ParamDef((f, d), (ff_axis, "embed_fsdp"),
                           scale=_resid_scale(cfg, f)),
    }
    if cfg.act == "silu":       # gated
        out["w_gate"] = ParamDef((d, f), ("embed_fsdp", ff_axis))
    return out


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, E), ("embed_fsdp", None), scale=0.02),
        "w_up": ParamDef((E, d, f), ("experts", "embed_fsdp", "expert_mlp")),
        "w_gate": ParamDef((E, d, f), ("experts", "embed_fsdp", "expert_mlp")),
        "w_down": ParamDef((E, f, d), ("experts", "expert_mlp", "embed_fsdp"),
                           scale=_resid_scale(cfg, f)),
    }


def mamba2_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    """Mamba-2 (SSD) mixer.  The input projection is kept as SEPARATE
    z/x/B/C/dt matrices rather than one packed matmul: slicing a packed,
    model-sharded output dim at non-shard-aligned offsets would force XLA
    to reshard; separate projections shard each segment cleanly."""
    d = cfg.d_model
    inner = cfg.ssm_inner
    H, N, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    return {
        "w_z": ParamDef((d, inner), ("embed_fsdp", "conv_dim")),
        "w_x": ParamDef((d, inner), ("embed_fsdp", "conv_dim")),
        "w_B": ParamDef((d, G * N), ("embed_fsdp", None)),
        "w_C": ParamDef((d, G * N), ("embed_fsdp", None)),
        "w_dt": ParamDef((d, H), ("embed_fsdp", "ssm_heads")),
        "conv_x_w": ParamDef((cfg.ssm_conv, inner), (None, "conv_dim")),
        "conv_x_b": ParamDef((inner,), ("conv_dim",), init="zeros"),
        "conv_B_w": ParamDef((cfg.ssm_conv, G * N), (None, None)),
        "conv_B_b": ParamDef((G * N,), (None,), init="zeros"),
        "conv_C_w": ParamDef((cfg.ssm_conv, G * N), (None, None)),
        "conv_C_b": ParamDef((G * N,), (None,), init="zeros"),
        "A_log": ParamDef((H,), ("ssm_heads",), init="ssm_a"),
        "dt_bias": ParamDef((H,), ("ssm_heads",), init="ssm_dt"),
        "D": ParamDef((H,), ("ssm_heads",), init="ones"),
        "norm_w": ParamDef((inner,), ("conv_dim",), init="ones"),
        "w_out": ParamDef((inner, d), ("conv_dim", "embed_fsdp"),
                          scale=_resid_scale(cfg, inner)),
    }


def _norm(cfg: ModelConfig, dim: Optional[int] = None) -> Dict[str, ParamDef]:
    dim = dim if dim is not None else cfg.d_model
    out = {"w": ParamDef((dim,), ("d_model",), init="ones")}
    if cfg.norm == "layernorm":
        out["b"] = ParamDef((dim,), ("d_model",), init="zeros")
    return out


def block_defs(cfg: ModelConfig, kind: str) -> Dict[str, ParamDef]:
    """One residual block: pre-norm + mixer (+ pre-norm + ffn for attn)."""
    if kind == "attn":
        ffn = moe_defs(cfg) if cfg.family == "moe" else mlp_defs(cfg)
        return {"ln1": _norm(cfg), "attn": attn_defs(cfg),
                "ln2": _norm(cfg), "ffn": ffn}
    if kind == "mamba":
        return {"ln1": _norm(cfg), "mixer": mamba2_defs(cfg)}
    if kind == "cross":
        return {"ln": _norm(cfg), "xattn": cross_attn_defs(cfg),
                "gate": ParamDef((1,), (None,), init="zeros")}
    raise ValueError(kind)


def model_defs(cfg: ModelConfig) -> ParamTree:
    """Full parameter table for any of the six supported families."""
    V = cfg.padded_vocab
    out: ParamTree = {
        "embed": ParamDef((V, cfg.d_model), ("vocab", "embed_fsdp"), scale=0.02),
        "ln_f": _norm(cfg),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = ParamDef((cfg.d_model, V), ("embed_fsdp", "vocab"))

    L = cfg.n_layers
    if cfg.family in ("dense", "moe"):
        out["layers"] = jax.tree.map(
            lambda p: _stack(L, p), block_defs(cfg, "attn"),
            is_leaf=lambda x: isinstance(x, ParamDef))
    elif cfg.family == "ssm":
        out["layers"] = jax.tree.map(
            lambda p: _stack(L, p), block_defs(cfg, "mamba"),
            is_leaf=lambda x: isinstance(x, ParamDef))
    elif cfg.family == "hybrid":
        out["layers"] = jax.tree.map(
            lambda p: _stack(L, p), block_defs(cfg, "mamba"),
            is_leaf=lambda x: isinstance(x, ParamDef))
        # zamba2-style single SHARED attention block, applied every
        # ``attn_every`` mamba blocks — parameters are not stacked.
        out["shared_attn"] = block_defs(cfg, "attn")
    elif cfg.family == "vlm":
        every = cfg.cross_attn_every
        assert L % every == 0
        n_groups = L // every
        # self-attn decoder layers grouped (n_groups, every, ...)
        grouped = jax.tree.map(
            lambda p: ParamDef((n_groups, every) + p.shape,
                               (None, None) + p.logical, p.init, p.scale),
            block_defs(cfg, "attn"),
            is_leaf=lambda x: isinstance(x, ParamDef))
        out["layers"] = grouped
        out["cross"] = jax.tree.map(
            lambda p: _stack(n_groups, p), block_defs(cfg, "cross"),
            is_leaf=lambda x: isinstance(x, ParamDef))
    elif cfg.family == "encdec":
        out["layers"] = jax.tree.map(          # decoder: self+cross+ffn
            lambda p: _stack(L, p), {**block_defs(cfg, "attn"),
                                     "lnx": _norm(cfg),
                                     "xattn": cross_attn_defs(cfg)},
            is_leaf=lambda x: isinstance(x, ParamDef))
        out["enc_layers"] = jax.tree.map(
            lambda p: _stack(cfg.enc_layers, p), block_defs(cfg, "attn"),
            is_leaf=lambda x: isinstance(x, ParamDef))
        out["ln_enc"] = _norm(cfg)
        out["pos_embed"] = ParamDef((4096, cfg.d_model), (None, "d_model"),
                                    scale=0.02)
        out["enc_pos_embed"] = ParamDef((cfg.enc_seq, cfg.d_model),
                                        (None, "d_model"), scale=0.02)
    else:
        raise ValueError(cfg.family)
    return out
