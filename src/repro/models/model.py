"""End-to-end language models for all six assigned families.

Layers are stacked along a leading axis and consumed with ``lax.scan`` so
the compiled HLO is depth-independent (crucial for 40-cell × 2-mesh
dry-runs on one CPU).  Per-block remat keeps activation memory at
O(sqrt-ish) for training.  All sharding comes from the logical-axis rules.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from . import layers, params as P
from .config import ModelConfig

Params = Dict[str, Any]


def model_defs(cfg: ModelConfig):
    return P.model_defs(cfg)


def init_params(cfg: ModelConfig, key, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    return P.init_params(P.model_defs(cfg), key, dtype)


def abstract_params(cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    return P.abstract_params(P.model_defs(cfg), dtype)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "dots":
        # selective remat: keep matmul outputs (the FLOPs that matter),
        # recompute elementwise/norm chains — near-zero re-forward FLOPs
        # for ~the activation memory of the dot outputs
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=True)
    if cfg.remat in ("block", "full"):
        return jax.checkpoint(fn, prevent_cse=True)
    return fn


def _cast(params: Params, cfg: ModelConfig):
    """Compute-dtype view of the params (bf16 matmuls, fp32 master)."""
    cdt = jnp.dtype(cfg.dtype)

    def leaf(x):
        return x.astype(cdt) if x.dtype == jnp.float32 and x.ndim >= 2 else x
    return jax.tree.map(leaf, params)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params: Params, tokens, cfg: ModelConfig,
                 rules: ShardingRules):
    # T5-style sqrt(d) embedding scale: brings the residual stream to
    # O(1) at layer 0 so the pre-norm backward is depth-stable while the
    # tied unembedding keeps its 0.02-scale logits
    x = jnp.take(params["embed"], tokens, axis=0) \
        * jnp.asarray(math.sqrt(cfg.d_model), params["embed"].dtype)
    return constrain(x, rules, "batch", "act_seq", "d_model")


def lm_head(params: Params, x, cfg: ModelConfig, rules: ShardingRules):
    x = layers.norm(x, params["ln_f"], cfg)
    w = params["unembed"] if "unembed" in params else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    # seq and vocab cannot both land on "model"; prefer the seq sharding
    # when sequence parallelism is on (CE is then fully token-parallel)
    if rules.act_seq is not None:
        logits = constrain(logits, rules, "batch", "act_seq", None)
    else:
        logits = constrain(logits, rules, "batch", "seq", "vocab")
    if cfg.padded_vocab != cfg.vocab:   # mask padded vocab rows
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e9)
    return logits


# ---------------------------------------------------------------------------
# backbones (mode: "train" | "prefill" | "decode")
# ---------------------------------------------------------------------------

def _dense_backbone(params, x, cfg, rules, *, positions, caches, mode):
    use_rope = cfg.family != "encdec"

    def body(carry, inp):
        x, aux = carry
        if caches is None:
            lp = inp
            x, a, _ = layers.attn_block(x, lp, cfg, rules,
                                        positions=positions,
                                        use_rope=use_rope)
            return (x, aux + a), None
        lp, (ck, cv) = inp
        x, a, nc = layers.attn_block(
            x, lp, cfg, rules, positions=positions, use_rope=use_rope,
            cache=(ck, cv, caches["len"]))
        return (x, aux + a), (nc[0], nc[1])

    body = _maybe_remat(body, cfg) if mode == "train" else body
    aux0 = jnp.zeros((), jnp.float32)
    if caches is None:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
        return x, aux, None
    (x, aux), new_kv = jax.lax.scan(
        body, (x, aux0), (params["layers"], (caches["k"], caches["v"])))
    new_len = caches["len"] + x.shape[1]
    return x, aux, {"k": new_kv[0], "v": new_kv[1], "len": new_len}


def _ssm_backbone(params, x, cfg, rules, *, caches, mode):
    def body(carry, inp):
        x = carry
        if caches is None:
            x, _ = layers.mamba_block(x, inp, cfg, rules)
            return x, None
        lp, lc = inp
        x, nc = layers.mamba_block(x, lp, cfg, rules, cache=lc)
        return x, nc

    body = _maybe_remat(body, cfg) if mode == "train" else body
    aux = jnp.zeros((), jnp.float32)
    if caches is None:
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, aux, None
    mc = (caches["conv_x"], caches["conv_B"], caches["conv_C"], caches["ssd"])
    x, new_mc = jax.lax.scan(body, x, (params["layers"], mc))
    return x, aux, {"conv_x": new_mc[0], "conv_B": new_mc[1],
                    "conv_C": new_mc[2], "ssd": new_mc[3],
                    "len": caches["len"] + x.shape[1]}


def _hybrid_backbone(params, x, cfg, rules, *, positions, caches, mode):
    """zamba2-style: stacked mamba blocks + ONE shared attention block
    (unstacked params) applied every ``attn_every`` layers."""
    every = cfg.attn_every
    shared = params["shared_attn"]

    def body(carry, inp):
        x, idx, attn_kv = carry
        if caches is None:
            lp = inp
            x, _ = layers.mamba_block(x, lp, cfg, rules)
        else:
            lp, lc = inp
            x, nc = layers.mamba_block(x, lp, cfg, rules, cache=lc)
        apply_attn = (idx + 1) % every == 0

        def with_attn(operand):
            x, attn_kv = operand
            app = (idx + 1) // every - 1
            if caches is None:
                y, a, _ = layers.attn_block(x, shared, cfg, rules,
                                            positions=positions)
                return y, attn_kv
            ck = jax.lax.dynamic_index_in_dim(attn_kv[0], app, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(attn_kv[1], app, keepdims=False)
            y, a, nc = layers.attn_block(
                x, shared, cfg, rules, positions=positions,
                cache=(ck, cv, caches["len"]))
            nk = jax.lax.dynamic_update_index_in_dim(attn_kv[0], nc[0], app, 0)
            nv = jax.lax.dynamic_update_index_in_dim(attn_kv[1], nc[1], app, 0)
            return y, (nk, nv)

        x, attn_kv = jax.lax.cond(apply_attn, with_attn,
                                  lambda op: op, (x, attn_kv))
        if caches is None:
            return (x, idx + 1, attn_kv), None
        return (x, idx + 1, attn_kv), nc

    body = _maybe_remat(body, cfg) if mode == "train" else body
    aux = jnp.zeros((), jnp.float32)
    if caches is None:
        (x, _, _), _ = jax.lax.scan(
            body, (x, jnp.int32(0), ()), params["layers"])
        return x, aux, None
    mc = (caches["conv_x"], caches["conv_B"], caches["conv_C"], caches["ssd"])
    (x, _, attn_kv), new_mc = jax.lax.scan(
        body, (x, jnp.int32(0), (caches["attn_k"], caches["attn_v"])),
        (params["layers"], mc))
    return x, aux, {"conv_x": new_mc[0], "conv_B": new_mc[1],
                    "conv_C": new_mc[2], "ssd": new_mc[3],
                    "attn_k": attn_kv[0], "attn_v": attn_kv[1],
                    "len": caches["len"] + x.shape[1]}


def _vlm_backbone(params, x, cfg, rules, *, positions, img_embeds, caches,
                  mode):
    """Grouped scan: [gated cross-attn to image tokens] then ``every``
    self-attn decoder layers, repeated n_groups times."""
    def group_body(carry, inp):
        x, aux = carry
        if caches is None:
            xp, sp = inp
        else:
            xp, sp, (gk, gv) = inp
        x = layers.cross_block(x, xp, cfg, rules, kv_x=img_embeds,
                               positions=positions)

        def inner(carry2, inp2):
            x, aux = carry2
            if caches is None:
                x, a, _ = layers.attn_block(x, inp2, cfg, rules,
                                            positions=positions)
                return (x, aux + a), None
            lp, (ck, cv) = inp2
            x, a, nc = layers.attn_block(
                x, lp, cfg, rules, positions=positions,
                cache=(ck, cv, caches["len"]))
            return (x, aux + a), (nc[0], nc[1])

        if caches is None:
            (x, aux), _ = jax.lax.scan(inner, (x, aux), sp)
            return (x, aux), None
        (x, aux), nkv = jax.lax.scan(inner, (x, aux), (sp, (gk, gv)))
        return (x, aux), nkv

    group_body = _maybe_remat(group_body, cfg) if mode == "train" \
        else group_body
    aux0 = jnp.zeros((), jnp.float32)
    if caches is None:
        (x, aux), _ = jax.lax.scan(group_body, (x, aux0),
                                   (params["cross"], params["layers"]))
        return x, aux, None
    (x, aux), new_kv = jax.lax.scan(
        group_body, (x, aux0),
        (params["cross"], params["layers"], (caches["k"], caches["v"])))
    return x, aux, {"k": new_kv[0], "v": new_kv[1],
                    "len": caches["len"] + x.shape[1]}


def _encode_audio(params, frames, cfg, rules):
    """Whisper encoder over (stubbed) precomputed frame embeddings."""
    x = frames + params["enc_pos_embed"][None, :frames.shape[1]]

    def body(x, lp):
        x, _, _ = layers.attn_block(x, lp, cfg, rules, positions=None,
                                    causal=False, use_rope=False)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layers.norm(x, params["ln_enc"], cfg)


def _encdec_backbone(params, x, cfg, rules, *, positions, enc_out, caches,
                     mode):
    def body(carry, inp):
        x = carry
        if caches is None:
            lp = inp
            x, _ = layers.encdec_block(x, lp, cfg, rules, enc_out=enc_out,
                                       positions=positions)
            return x, None
        lp, (ck, cv) = inp
        x, nc = layers.encdec_block(
            x, lp, cfg, rules, enc_out=enc_out, positions=positions,
            cache=(ck, cv, caches["len"]))
        return x, (nc[0], nc[1])

    body = _maybe_remat(body, cfg) if mode == "train" else body
    aux = jnp.zeros((), jnp.float32)
    if caches is None:
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, aux, None
    x, new_kv = jax.lax.scan(body, x,
                             (params["layers"], (caches["k"], caches["v"])))
    return x, aux, {"k": new_kv[0], "v": new_kv[1],
                    "enc_out": enc_out, "len": caches["len"] + x.shape[1]}


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(params: Params, tokens, cfg: ModelConfig, rules: ShardingRules,
            *, aux_inputs: Optional[Dict] = None, caches=None,
            mode: str = "train", return_hidden: bool = False):
    """Returns (logits, moe_aux_loss, new_caches); with
    ``return_hidden`` the final-norm hidden states replace the logits
    (streaming-CE path computes the LM head itself)."""
    params = _cast(params, cfg)
    aux_inputs = aux_inputs or {}
    B, S = tokens.shape
    if caches is not None and mode == "decode":
        positions = jnp.broadcast_to(caches["len"][None, None], (B, S)) \
            if jnp.ndim(caches["len"]) == 0 else caches["len"][:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    x = embed_tokens(params, tokens, cfg, rules)
    if cfg.family == "encdec":
        x = x + params["pos_embed"][None, positions[0]] if B == 1 \
            else x + jnp.take(params["pos_embed"], positions, axis=0)

    fam = cfg.family
    if fam in ("dense", "moe"):
        x, aux, nc = _dense_backbone(params, x, cfg, rules,
                                     positions=positions, caches=caches,
                                     mode=mode)
    elif fam == "ssm":
        x, aux, nc = _ssm_backbone(params, x, cfg, rules, caches=caches,
                                   mode=mode)
    elif fam == "hybrid":
        x, aux, nc = _hybrid_backbone(params, x, cfg, rules,
                                      positions=positions, caches=caches,
                                      mode=mode)
    elif fam == "vlm":
        img = aux_inputs["img_embeds"].astype(x.dtype)
        x, aux, nc = _vlm_backbone(params, x, cfg, rules,
                                   positions=positions, img_embeds=img,
                                   caches=caches, mode=mode)
    elif fam == "encdec":
        if caches is not None and mode == "decode":
            enc_out = caches["enc_out"]
        else:
            enc_out = _encode_audio(params,
                                    aux_inputs["frames"].astype(x.dtype),
                                    cfg, rules)
        x, aux, nc = _encdec_backbone(params, x, cfg, rules,
                                      positions=positions, enc_out=enc_out,
                                      caches=caches, mode=mode)
    else:
        raise ValueError(fam)

    if return_hidden:
        return layers.norm(x, params["ln_f"], cfg), aux, nc
    logits = lm_head(params, x, cfg, rules)
    return logits, aux, nc


def loss_fn(params: Params, batch: Dict, cfg: ModelConfig,
            rules: ShardingRules, aux_weight: float = 0.01):
    aux_in = {k: v for k, v in batch.items()
              if k not in ("tokens", "targets")}
    if cfg.use_streaming_ce:
        # fused unembed + CE over vocab chunks: never materializes the
        # (B, S, V) logits (see blocked_ce.py)
        from .blocked_ce import streaming_ce
        hidden, aux, _ = forward(params, batch["tokens"], cfg, rules,
                                 aux_inputs=aux_in, mode="train",
                                 return_hidden=True)
        cparams = _cast(params, cfg)
        w = cparams["unembed"] if "unembed" in cparams             else cparams["embed"].T
        # largest divisor of the padded vocab <= ce_chunk
        V = cfg.padded_vocab
        chunk = min(cfg.ce_chunk, V)
        while V % chunk:
            chunk -= 1
        ce = streaming_ce(hidden, w, batch["targets"], cfg.vocab, chunk)
    else:
        logits, aux, _ = forward(params, batch["tokens"], cfg, rules,
                                 aux_inputs=aux_in, mode="train")
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, batch["targets"][..., None],
                                  axis=-1)[..., 0]
        ce = jnp.mean(logz - tgt)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux,
                  "ppl": jnp.exp(jnp.clip(ce, a_max=20.0))}


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16, abstract: bool = False):
    """Per-family cache pytree (stacked leading layer axis)."""
    L = cfg.n_layers

    def mk(shape, dt=dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    fam = cfg.family
    out: Dict[str, Any] = {"len": mk((batch,), jnp.int32)}
    # KV caches live in the attention kernel's (B, KV, S, D) layout
    if fam in ("dense", "moe", "encdec"):
        kv = (L, batch, cfg.n_kv, max_seq, cfg.hd)
        out.update(k=mk(kv), v=mk(kv))
        if fam == "encdec":
            out["enc_out"] = mk((batch, cfg.enc_seq, cfg.d_model))
    elif fam == "vlm":
        every = cfg.cross_attn_every
        ngroups = L // every
        kv = (ngroups, every, batch, cfg.n_kv, max_seq, cfg.hd)
        out.update(k=mk(kv), v=mk(kv))
    elif fam in ("ssm", "hybrid"):
        W, inner = cfg.ssm_conv, cfg.ssm_inner
        GN = cfg.ssm_groups * cfg.ssm_state
        out.update(
            conv_x=mk((L, batch, W - 1, inner)),
            conv_B=mk((L, batch, W - 1, GN)),
            conv_C=mk((L, batch, W - 1, GN)),
            ssd=mk((L, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                    cfg.ssm_state), jnp.float32))
        if fam == "hybrid":
            napps = L // cfg.attn_every
            kv = (napps, batch, cfg.n_kv, max_seq, cfg.hd)
            out.update(attn_k=mk(kv), attn_v=mk(kv))
    return out


def cache_logical_axes(cfg: ModelConfig):
    """Logical axis names for every cache leaf (for shardings)."""
    fam = cfg.family
    out = {"len": (None,)}
    if fam in ("dense", "moe", "encdec"):
        kv = (None, "batch", "kv_heads", "cache_seq", "head_dim")
        out.update(k=kv, v=kv)
        if fam == "encdec":
            out["enc_out"] = ("batch", None, "d_model")
    elif fam == "vlm":
        kv = (None, None, "batch", "kv_heads", "cache_seq", "head_dim")
        out.update(k=kv, v=kv)
    elif fam in ("ssm", "hybrid"):
        out.update(conv_x=(None, "batch", None, "conv_dim"),
                   conv_B=(None, "batch", None, None),
                   conv_C=(None, "batch", None, None),
                   ssd=(None, "batch", "ssm_heads", None, None))
        if fam == "hybrid":
            kv = (None, "batch", "kv_heads", "cache_seq", "head_dim")
            out.update(attn_k=kv, attn_v=kv)
    return out
