"""Streaming cross-entropy: fused unembed + CE, chunked over the vocab.

Materializing (B, S, V) logits costs ~1 GiB/device at 128k vocab
(llama-3.2-vision) before the f32 CE temps.  This version scans vocab
chunks computing a running (max, sumexp) plus the target logit, and a
custom VJP recomputes each chunk's logits in the backward — the same
recompute-over-residuals trade as flash attention, applied to the LM
head.  Peak extra memory: one (B, S, C) chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30


def _chunk_logits(x, w_chunk, dtype=jnp.float32):
    return jnp.einsum("bsd,dv->bsv", x, w_chunk).astype(dtype)


def _fwd_scan(x, w, targets, valid_vocab: int, chunk: int):
    """Returns (lse, tgt_logit): (B,S) each."""
    B, S, d = x.shape
    V = w.shape[1]
    nch = V // chunk

    def step(carry, j):
        m, l, tgt = carry
        wj = jax.lax.dynamic_slice_in_dim(w, j * chunk, chunk, axis=1)
        logits = _chunk_logits(x, wj)                      # (B,S,C) f32
        cols = j * chunk + jnp.arange(chunk)
        logits = jnp.where((cols < valid_vocab)[None, None], logits, NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        l = l * jnp.exp(m - m_new) \
            + jnp.sum(jnp.exp(logits - m_new[..., None]), axis=-1)
        # target logit if it falls inside this chunk
        inside = (targets >= j * chunk) & (targets < (j + 1) * chunk)
        local = jnp.clip(targets - j * chunk, 0, chunk - 1)
        picked = jnp.take_along_axis(logits, local[..., None],
                                     axis=-1)[..., 0]
        tgt = jnp.where(inside, picked, tgt)
        return (m_new, l, tgt), None

    m0 = jnp.full((B, S), NEG, jnp.float32)
    l0 = jnp.zeros((B, S), jnp.float32)
    t0 = jnp.full((B, S), NEG, jnp.float32)
    (m, l, tgt), _ = jax.lax.scan(step, (m0, l0, t0), jnp.arange(nch))
    return m + jnp.log(l), tgt


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def streaming_ce(x, w, targets, valid_vocab: int, chunk: int):
    """Mean token cross-entropy of softmax(x @ w) vs targets.
    x: (B,S,d); w: (d,V) with V % chunk == 0; targets: (B,S) int32."""
    lse, tgt = _fwd_scan(x, w, targets, valid_vocab, chunk)
    return jnp.mean(lse - tgt)


def _ce_fwd(x, w, targets, valid_vocab, chunk):
    lse, tgt = _fwd_scan(x, w, targets, valid_vocab, chunk)
    return jnp.mean(lse - tgt), (x, w, targets, lse)


def _ce_bwd(valid_vocab, chunk, res, dce):
    x, w, targets, lse = res
    B, S, d = x.shape
    V = w.shape[1]
    nch = V // chunk
    scale = dce / (B * S)

    def step(dx, j):
        wj = jax.lax.dynamic_slice_in_dim(w, j * chunk, chunk, axis=1)
        logits = _chunk_logits(x, wj)
        cols = j * chunk + jnp.arange(chunk)
        logits = jnp.where((cols < valid_vocab)[None, None], logits, NEG)
        p = jnp.exp(logits - lse[..., None])               # softmax chunk
        onehot = (targets[..., None] == cols[None, None]).astype(p.dtype)
        dl = (p - onehot) * scale                          # (B,S,C)
        dx = dx + jnp.einsum("bsv,dv->bsd", dl, wj.astype(jnp.float32))
        dw_j = jnp.einsum("bsd,bsv->dv", x.astype(jnp.float32), dl)
        return dx, dw_j

    dx0 = jnp.zeros((B, S, d), jnp.float32)
    dx, dw_chunks = jax.lax.scan(step, dx0, jnp.arange(nch))
    dw = jnp.moveaxis(dw_chunks, 0, 1).reshape(d, V)
    return dx.astype(x.dtype), dw.astype(w.dtype), None


streaming_ce.defvjp(_ce_fwd, _ce_bwd)
