"""Model configuration shared by all 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # hybrid (zamba2-style): one shared attention block applied every
    # ``attn_every`` SSM blocks
    attn_every: int = 0

    # VLM: decoder layer indices with interleaved cross-attention to the
    # (stubbed) image patch embeddings
    cross_attn_every: int = 0
    n_img_tokens: int = 0

    # enc-dec (whisper): encoder over stubbed audio-frame embeddings
    enc_layers: int = 0
    enc_seq: int = 0

    rope_theta: float = 10000.0
    act: str = "silu"             # silu (gated) | gelu (non-gated)
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"

    # implementation switches
    use_pallas: bool = False      # Pallas kernels for attention/ssd/rmsnorm
    use_vml_act: bool = True      # vml activations (paper §5 integration)
    remat: str = "block"          # none | block | full
    moe_group: int = 256          # token-group size for dropping MoE dispatch
    use_streaming_ce: bool = False  # fused vocab-chunked CE (no full logits)
    ce_chunk: int = 2048
    attn_block_q: int = 512       # flash-style blocked attention (XLA path)
    attn_block_k: int = 1024

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding table shards
        evenly over the model axis (padded logits are masked to -inf)."""
        return (self.vocab + 255) // 256 * 256

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None \
            else self.d_model // self.n_heads

    @property
    def ssm_heads(self) -> int:
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    @property
    def ssm_inner(self) -> int:
        return self.d_model * self.ssm_expand

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv, 1) == 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if self.family == "hybrid":
            assert self.attn_every > 0
        if self.family == "vlm":
            assert self.cross_attn_every > 0
        if self.family == "encdec":
            assert self.enc_layers > 0 and self.enc_seq > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """long_500k requires sub-quadratic attention: run only for SSM/hybrid
    families, skip (by assignment rule) for pure full-attention archs."""
    if cfg.family in ("ssm", "hybrid"):
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)
