"""Model layers, pure-JAX, sharding-annotated via logical axis names.

Every mixer here has the same split pocl imposes on its kernel compiler:
the *math* is target-independent, and the *mapping* (which mesh axis each
tensor dim lands on) comes from the ShardingRules table, threaded through
``constrain``.  Kernels (Pallas) are swapped in at the ops.py dispatch
layer, mirroring pocl's device-specific builtin libraries.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.kernels import ops
from repro import vml
from .config import ModelConfig

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def norm(x, p: Params, cfg: ModelConfig, eps: float = 1e-6):
    if "b" in p:                                   # layernorm
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["w"].astype(jnp.float32)
                + p["b"].astype(jnp.float32)).astype(x.dtype)
    return ops.rmsnorm(x, p["w"], eps=eps, use_pallas=cfg.use_pallas)


def activation(x, cfg: ModelConfig):
    if cfg.use_vml_act:
        return vml.silu(x) if cfg.act == "silu" else vml.gelu_tanh(x)
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


from .flash import blocked_attention  # noqa: E402  (memory-efficient custom-VJP attention)


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def attention(x, p: Params, cfg: ModelConfig, rules: ShardingRules, *,
              positions, causal: bool = True, kv_x=None,
              use_rope: bool = True,
              cache: Optional[Tuple] = None):
    """Self- or cross-attention.  cache=(k_cache, v_cache, lengths) with
    layout (B, S_cache, KV, D); returns (out, new_cache)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = constrain(q, rules, "batch", "seq", "heads", "head_dim")
    src = kv_x if kv_x is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    k = constrain(k, rules, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, rules, "batch", "seq", "kv_heads", "head_dim")

    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        if kv_x is None:
            k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_x is None:
        k_cache, v_cache, lengths = cache
        if S == 1:
            # decode: append one token then attend over the cache.
            # Cache layout is natively (B, KV, S, D) — the attention
            # kernel's layout — so NO per-step full-cache transpose
            # happens (§Perf H1 iteration 2).  With S sharded
            # ("cache_seq"), XLA turns the softmax over the sharded S
            # into partial max/sum + tiny all-reduces = flash-decoding.
            # Each batch row writes at its OWN length: continuous-batching
            # slots sit at different sequence positions (docs/serving.md),
            # so the write index is per-row, not lengths[0] for the group.
            row_idx = jnp.broadcast_to(jnp.asarray(lengths), (B,))
            row_update = jax.vmap(
                lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                    c, n, i, axis=1))
            k_cache = row_update(
                k_cache, k.transpose(0, 2, 1, 3).astype(k_cache.dtype),
                row_idx)
            v_cache = row_update(
                v_cache, v.transpose(0, 2, 1, 3).astype(v_cache.dtype),
                row_idx)
            kq = jnp.squeeze(q, axis=1)              # (B,H,D)
            o = ops.decode_attention(kq, k_cache, v_cache,
                                     lengths + 1, use_pallas=cfg.use_pallas)
            out = o[:, None]                          # (B,1,H,D)
            new_cache = (k_cache, v_cache, lengths + 1)
        else:
            # prefill: attend causally over fresh K/V, then write the cache
            # (one transpose for the whole prompt, not one per step)
            out = blocked_attention(q, k, v, causal=True,
                                    block_q=cfg.attn_block_q,
                                    block_k=cfg.attn_block_k)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.transpose(0, 2, 1, 3).astype(k_cache.dtype),
                0, axis=2)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.transpose(0, 2, 1, 3).astype(v_cache.dtype),
                0, axis=2)
            new_cache = (k_cache, v_cache, lengths + S)
    else:
        if cfg.use_pallas and S <= 4096 and kv_x is None:
            out = ops.attention(q, k, v, causal=causal, use_pallas=True)
        else:
            out = blocked_attention(q, k, v, causal=causal and kv_x is None,
                                    block_q=cfg.attn_block_q,
                                    block_k=cfg.attn_block_k)

    out = constrain(out, rules, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = constrain(y, rules, "batch", "act_seq", "d_model")
    return y, new_cache


# ---------------------------------------------------------------------------
# FFN: dense MLP and MoE
# ---------------------------------------------------------------------------

def mlp(x, p: Params, cfg: ModelConfig, rules: ShardingRules):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = activation(g, cfg) * h
    else:
        h = activation(h, cfg)
    h = constrain(h, rules, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(y, rules, "batch", "act_seq", "d_model")


def moe(x, p: Params, cfg: ModelConfig, rules: ShardingRules):
    """Token-choice top-k MoE with capacity dropping (GShard-style dispatch
    einsums).  Tokens are chunked into groups of ``cfg.moe_group`` so the
    dispatch tensor is O(group² · k · cf) per group instead of O(S·E·C).
    Experts shard over the 'experts' axis (EP) when divisible, otherwise
    per-expert FFN dims shard over 'expert_mlp' (TP fallback)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    g = min(cfg.moe_group, S)
    pad = (-S) % g
    if pad:   # pad to a group multiple; padded tokens never claim capacity
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    G = (B * Sp) // g
    C = max(1, int(g * K * cfg.capacity_factor / E))

    xt = x.reshape(G, g, d)
    valid = (jnp.arange(Sp) < S)
    valid = jnp.broadcast_to(valid[None], (B, Sp)).reshape(G, g)
    logits = jnp.einsum("gsd,de->gse", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # (G,g,K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    gate_vals = gate_vals * valid[..., None]

    # position of each (token, k) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32) \
        * valid[..., None, None]                           # (G,g,K,E)
    pos = jnp.cumsum(onehot.reshape(G, g * K, E), axis=1).reshape(
        G, g, K, E) - 1
    pos = jnp.sum(pos * onehot, axis=-1)                   # (G,g,K)
    keep = pos < C

    # dispatch: (G,g,E,C) one-hot over (expert, slot)
    disp = jnp.zeros((G, g, E, C), x.dtype)
    comb = jnp.zeros((G, g, E, C), jnp.float32)
    for kk in range(K):
        sel = jax.nn.one_hot(gate_idx[..., kk], E, dtype=x.dtype) \
            * keep[..., kk, None] * valid[..., None]
        slot = jax.nn.one_hot(pos[..., kk], C, dtype=x.dtype)
        contrib = sel[..., None] * slot[..., None, :]
        disp = disp + contrib
        comb = comb + contrib.astype(jnp.float32) \
            * gate_vals[..., kk, None, None]

    xin = jnp.einsum("gsec,gsd->egcd", disp, xt)
    # the token-group dim stays sharded on the data axis: the dispatch is
    # an all-to-all over (data -> experts), NOT a gather of all tokens.
    # "moe_capacity" optionally shards the capacity dim over the model
    # axis (token-parallel MoE; see launch/variants.py).
    xin = constrain(xin, rules, "experts", "batch", "moe_capacity",
                    "d_model")
    up = jnp.einsum("egcd,edf->egcf", xin, p["w_up"])
    gt = jnp.einsum("egcd,edf->egcf", xin, p["w_gate"])
    h = activation(gt, cfg) * up
    h = constrain(h, rules, "experts", "batch", "moe_capacity",
                  "expert_mlp")
    eo = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
    eo = constrain(eo, rules, "experts", "batch", "moe_capacity",
                   "d_model")
    y = jnp.einsum("gsec,egcd->gsd", comb.astype(x.dtype), eo)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=1)                           # (G,E)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2),
        axis=1) / K
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    y = y.reshape(B, Sp, d)[:, :S]
    y = constrain(y, rules, "batch", "act_seq", "d_model")
    return y, aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) mixer
# ---------------------------------------------------------------------------

def _causal_conv(u, w, b, state=None):
    """Depthwise causal conv.  u: (B,S,C), w: (W,C).  With ``state``
    ((B,W-1,C)) performs a streaming step update (decode)."""
    W = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, u], axis=1)       # (B,W,C) for S=1
        y = jnp.einsum("bwc,wc->bc", window[:, -W:], w) + b
        return y[:, None], window[:, 1:]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + u.shape[1]] * w[i] for i in range(W)) + b
    return y, None


def mamba2(x, p: Params, cfg: ModelConfig, rules: ShardingRules, *,
           cache: Optional[Tuple] = None):
    """Mamba-2 SSD mixer.  cache=(conv_x, conv_B, conv_C, ssd_state) for
    decode; returns (out, new_cache)."""
    B, S, _ = x.shape
    Hh, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Gq = cfg.ssm_groups

    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    u = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    Bp = jnp.einsum("bsd,dn->bsn", x, p["w_B"])
    Cp = jnp.einsum("bsd,dn->bsn", x, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    u = constrain(u, rules, "batch", "seq", "conv_dim")

    decode = cache is not None and S == 1
    cx = cB = cC = st = None
    if decode:
        cx, cB, cC, st = cache
    # conv state = the last (W-1) PRE-conv inputs (streaming window)
    W = cfg.ssm_conv
    u_raw, B_raw, C_raw = u, Bp, Cp
    u, ncx = _causal_conv(u, p["conv_x_w"], p["conv_x_b"], cx)
    Bp, ncB = _causal_conv(Bp, p["conv_B_w"], p["conv_B_b"], cB)
    Cp, ncC = _causal_conv(Cp, p["conv_C_w"], p["conv_C_b"], cC)
    u = vml.silu(u) if cfg.use_vml_act else jax.nn.silu(u)
    Bp = vml.silu(Bp) if cfg.use_vml_act else jax.nn.silu(Bp)
    Cp = vml.silu(Cp) if cfg.use_vml_act else jax.nn.silu(Cp)

    xs = u.reshape(B, S, Hh, P)
    xs = constrain(xs, rules, "batch", "seq", "ssm_heads", None)
    Bm = Bp.reshape(B, S, Gq, N)
    Cm = Cp.reshape(B, S, Gq, N)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_cache = None
    if decode:
        y, new_state = ops.ref.ssd_decode_step(
            st, xs[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0])
        y = y[:, None]
        new_cache = (ncx, ncB, ncC, new_state)
    else:
        pad = (-S) % cfg.ssm_chunk
        if pad:
            # pad the scan to a chunk multiple (padded steps only decay the
            # state, and y/state for them are discarded) — prefill requires
            # an exact multiple so the cached state is exact
            assert cache is None, "prefill seq must be a ssm_chunk multiple"
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            y, final_state = ops.ssd_scan(xs_p, dt_p, A, Bm_p, Cm_p,
                                          chunk=cfg.ssm_chunk,
                                          use_pallas=cfg.use_pallas)
            y = y[:, :S]
        else:
            y, final_state = ops.ssd_scan(xs, dt, A, Bm, Cm,
                                          chunk=cfg.ssm_chunk,
                                          use_pallas=cfg.use_pallas)
        if cache is not None:   # prefill: stash streaming window + state
            new_cache = (u_raw[:, S - W + 1:], B_raw[:, S - W + 1:],
                         C_raw[:, S - W + 1:], final_state)

    y = y.astype(x.dtype) + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, Hh * P)
    # gated RMSNorm (Mamba-2 norm before out-proj)
    y = ops.rmsnorm(y * (vml.silu(z) if cfg.use_vml_act else jax.nn.silu(z)),
                    p["norm_w"], use_pallas=cfg.use_pallas)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return constrain(out, rules, "batch", "act_seq", "d_model"), new_cache


# ---------------------------------------------------------------------------
# residual blocks
# ---------------------------------------------------------------------------

def attn_block(x, p: Params, cfg: ModelConfig, rules: ShardingRules, *,
               positions, causal=True, use_rope=True, cache=None):
    """pre-norm attention + FFN block; returns (x, aux_loss, new_cache)."""
    h, new_cache = attention(norm(x, p["ln1"], cfg), p["attn"], cfg, rules,
                             positions=positions, causal=causal,
                             use_rope=use_rope, cache=cache)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe" and "router" in p["ffn"]:
        h, aux = moe(norm(x, p["ln2"], cfg), p["ffn"], cfg, rules)
    else:
        h = mlp(norm(x, p["ln2"], cfg), p["ffn"], cfg, rules)
    return x + h, aux, new_cache


def mamba_block(x, p: Params, cfg: ModelConfig, rules: ShardingRules, *,
                cache=None):
    h, new_cache = mamba2(norm(x, p["ln1"], cfg), p["mixer"], cfg, rules,
                          cache=cache)
    return x + h, new_cache


def cross_block(x, p: Params, cfg: ModelConfig, rules: ShardingRules, *,
                kv_x, positions):
    """Gated cross-attention block (llama-3.2-vision style)."""
    h, _ = attention(norm(x, p["ln"], cfg), p["xattn"], cfg, rules,
                     positions=positions, causal=False, kv_x=kv_x,
                     use_rope=False)
    return x + (jnp.tanh(p["gate"].astype(jnp.float32)) * h).astype(x.dtype)


def encdec_block(x, p: Params, cfg: ModelConfig, rules: ShardingRules, *,
                 enc_out, positions, cache=None):
    """Whisper decoder block: self-attn + cross-attn + FFN."""
    h, new_cache = attention(norm(x, p["ln1"], cfg), p["attn"], cfg, rules,
                             positions=positions, causal=True,
                             use_rope=False, cache=cache)
    x = x + h
    h, _ = attention(norm(x, p["lnx"], cfg), p["xattn"], cfg, rules,
                     positions=positions, causal=False, kv_x=enc_out,
                     use_rope=False)
    x = x + h
    h = mlp(norm(x, p["ln2"], cfg), p["ffn"], cfg, rules)
    return x + h, new_cache
