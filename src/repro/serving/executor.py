"""Batch executors: the device-facing half of the serving engine.

The continuous-batching scheduler (:mod:`repro.serving.engine`) is pure
host logic — slots, paged KV accounting, admission, preemption.  All
model work goes through a small executor interface so the scheduler can
be driven by the real jitted model or by a cheap deterministic stub (the
property-test harness steps the scheduler thousands of times; tracing a
real model for that would hide scheduler bugs behind jit latency):

* ``init_state()``                  — the batch-wide decode state
  (one row per slot; rows are independent).
* ``prefill(prompt, slot)``         — run one request's prompt in
  isolation (batch 1), returning a single-row state fragment plus the
  first sampled token.  Never touches the batch state, so the DAG can
  overlap it with a decode step.
* ``insert(state, fragment, slot)`` — splice a fragment into a slot row.
* ``decode(state, tokens, occupied)`` — one synchronized token for every
  occupied slot.  Row ``i`` of the result depends only on row ``i`` of
  the state, which is what makes per-request outputs independent of how
  requests were interleaved into slots (tests/test_serving_props.py).
* ``cache_bytes(batch, seq)``       — KV footprint, for page sizing.

:class:`JaxExecutor` is the production implementation over
``repro.models.forward``; :class:`StubExecutor` is the deterministic
pure-numpy one used by the scheduler property harness and the
fault-injection tests.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np


class BatchExecutor:
    """Interface contract (see module docstring).  Subclasses must set
    ``batch_slots`` and ``max_seq``."""

    batch_slots: int
    max_seq: int

    def init_state(self) -> Any:
        raise NotImplementedError

    def prefill(self, prompt: np.ndarray, slot: int) -> Tuple[Any, int]:
        raise NotImplementedError

    def insert(self, state: Any, fragment: Any, slot: int) -> Any:
        raise NotImplementedError

    def decode(self, state: Any, tokens: np.ndarray,
               occupied: np.ndarray) -> Tuple[Any, np.ndarray]:
        raise NotImplementedError

    def cache_bytes(self, batch: int, seq: int) -> int:
        raise NotImplementedError

    def compile_stats(self) -> Dict[str, int]:
        return {}


# ---------------------------------------------------------------------------
# production executor over the jitted model
# ---------------------------------------------------------------------------

class JaxExecutor(BatchExecutor):
    """Jitted prefill / insert / decode over ``repro.models.forward``.

    Three jitted functions, each compiled once per shape:

    * prefill: batch-1, prompt padded to a power-of-two bucket (floor
      ``prefill_bucket``) so mixed prompt lengths hit a handful of
      shapes instead of one compile per length.  Padding is exact: the
      prompt is left-aligned, the first token is read at the *true* last
      position, and the cache length is overridden to the true length,
      so junk K/V beyond it is masked out (and overwritten by decode).
    * insert: splices a batch-1 cache pytree into one row of the batch
      cache, ``dynamic_update_slice`` along each leaf's batch axis
      (from :func:`repro.models.cache_logical_axes`).
    * decode: one token for the whole batch; empty slots are masked —
      their cache length is pinned to 0 so they never grow or attend.
    """

    def __init__(self, cfg, params, rules, batch_slots: int, max_seq: int,
                 aux_inputs: Optional[Dict] = None, prefill_bucket: int = 8):
        import jax
        import jax.numpy as jnp

        from repro.models import cache_logical_axes, forward, init_caches

        self.cfg, self.params, self.rules = cfg, params, rules
        self.batch_slots, self.max_seq = batch_slots, max_seq
        self.aux = {k: np.asarray(v) for k, v in (aux_inputs or {}).items()}
        self.prefill_bucket = max(1, prefill_bucket)
        self._init_caches = init_caches
        self._axes = cache_logical_axes(cfg)
        self._jnp = jnp

        def _batch_axis(key: str) -> int:
            ax = self._axes.get(key)
            if ax and "batch" in ax:
                return ax.index("batch")
            return 0          # "len" and any unannotated leaf: axis 0

        self._batch_axis = _batch_axis

        def prefill_fn(params, toks, caches, last_idx, true_len, slot):
            aux = {k: jax.lax.dynamic_slice_in_dim(jnp.asarray(v), slot, 1,
                                                   axis=0)
                   for k, v in self.aux.items()}
            logits, _, caches = forward(params, toks, cfg, rules,
                                        aux_inputs=aux, caches=caches,
                                        mode="prefill")
            tok = jnp.argmax(logits[0, last_idx]).astype(jnp.int32)
            caches = dict(caches)
            caches["len"] = jnp.full_like(caches["len"], true_len)
            return tok, caches

        def insert_fn(state, frag, slot):
            out = {}
            for key, leaf in state.items():
                start = [0] * leaf.ndim
                start[_batch_axis(key)] = slot
                out[key] = jax.lax.dynamic_update_slice(
                    leaf, frag[key].astype(leaf.dtype), tuple(start))
            return out

        def decode_fn(params, toks, caches, occupied):
            logits, _, caches = forward(params, toks, cfg, rules,
                                        aux_inputs=self.aux, caches=caches,
                                        mode="decode")
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            caches = dict(caches)
            caches["len"] = jnp.where(occupied, caches["len"], 0)
            return tok, caches

        self._prefill = jax.jit(prefill_fn, donate_argnums=(2,))
        self._insert = jax.jit(insert_fn, donate_argnums=(0,))
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))
        self._prefill_shapes: set = set()
        self._calls = {"prefill": 0, "decode": 0, "insert": 0}
        self._lock = threading.Lock()

    # -- interface -------------------------------------------------------------
    def init_state(self):
        return self._init_caches(self.cfg, self.batch_slots, self.max_seq)

    def bucket(self, prompt_len: int) -> int:
        """Padded prefill length for a prompt (pow2, floored, capped)."""
        b = max(self.prefill_bucket, 1 << (max(1, prompt_len) - 1)
                .bit_length())
        return min(b, self.max_seq)

    def prefill(self, prompt: np.ndarray, slot: int):
        jnp = self._jnp
        plen = int(len(prompt))
        padded = self.bucket(plen)
        toks = np.zeros((1, padded), np.int32)
        toks[0, :plen] = prompt
        with self._lock:
            self._calls["prefill"] += 1
            self._prefill_shapes.add(padded)
        caches = self._init_caches(self.cfg, 1, self.max_seq)
        tok, frag = self._prefill(self.params, jnp.asarray(toks), caches,
                                  np.int32(plen - 1), np.int32(plen),
                                  np.int32(slot))
        return frag, int(tok)

    def insert(self, state, fragment, slot: int):
        with self._lock:
            self._calls["insert"] += 1
        return self._insert(state, fragment, np.int32(slot))

    def decode(self, state, tokens: np.ndarray, occupied: np.ndarray):
        jnp = self._jnp
        with self._lock:
            self._calls["decode"] += 1
        tok, state = self._decode(self.params,
                                  jnp.asarray(tokens, jnp.int32)[:, None],
                                  state, jnp.asarray(occupied))
        return state, np.asarray(tok)

    def cache_bytes(self, batch: int, seq: int) -> int:
        import jax.tree_util as jtu
        abstract = self._init_caches(self.cfg, batch, seq, abstract=True)
        return int(sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                       for leaf in jtu.tree_leaves(abstract)))

    # -- bookkeeping -----------------------------------------------------------
    @staticmethod
    def _jit_compiles(fn, fallback: int) -> int:
        try:
            return fn._cache_size()
        except AttributeError:   # older jax: fall back to shape bookkeeping
            return fallback

    def compile_stats(self) -> Dict[str, int]:
        """Call and (re)compile counters proving steady-state serving does
        zero tracing work (docs/caching.md §Steady-state serving)."""
        with self._lock:
            calls = dict(self._calls)
            n_shapes = len(self._prefill_shapes)
        return {
            "prefill_calls": calls["prefill"],
            "decode_steps": calls["decode"],
            "insert_calls": calls["insert"],
            "prefill_compiles": self._jit_compiles(self._prefill, n_shapes),
            "decode_compiles": self._jit_compiles(self._decode,
                                                  min(1, calls["decode"])),
            "insert_compiles": self._jit_compiles(self._insert,
                                                  min(1, calls["insert"])),
        }


# ---------------------------------------------------------------------------
# deterministic stub executor (property harness / fault injection)
# ---------------------------------------------------------------------------

class StubExecutor(BatchExecutor):
    """Pure-numpy deterministic executor.

    Token ``j`` of a request is a hash of (prompt, prompt length, j) —
    nothing else — so the expected output stream of any request is
    computable up front (:meth:`expected_tokens`) and *must* be
    independent of slot assignment, co-tenants, preemption, and arrival
    order.  The scheduler property harness leans on exactly that.

    ``delay_s`` adds a sleep per prefill/decode so DAG-overlap behaviour
    is observable in tests and scheduler-overhead benchmarks.
    """

    def __init__(self, batch_slots: int = 4, max_seq: int = 256,
                 vocab: int = 997, bytes_per_token: int = 64,
                 delay_s: float = 0.0):
        self.batch_slots, self.max_seq = batch_slots, max_seq
        self.vocab = vocab
        self.bytes_per_token = bytes_per_token
        self.delay_s = delay_s
        self.prefill_calls = 0
        self.decode_calls = 0
        self._lock = threading.Lock()

    # -- the deterministic token stream ----------------------------------------
    @staticmethod
    def _hash_prompt(prompt: np.ndarray) -> int:
        p = np.asarray(prompt, np.int64)
        return int(np.sum((p + 1) * (np.arange(p.size, dtype=np.int64) + 13))
                   % (1 << 31))

    @classmethod
    def token_at(cls, prompt_hash: int, prompt_len: int, j: int,
                 vocab: int = 997) -> int:
        return int((prompt_hash * 2654435761 + (prompt_len + j) * 40503
                    + j * 97 + 1) % vocab)

    @classmethod
    def expected_tokens(cls, prompt: np.ndarray, max_new: int,
                        eos_token: Optional[int] = None,
                        vocab: int = 997):
        """The oracle: the exact stream a request must produce no matter
        how the scheduler interleaved it."""
        h, plen = cls._hash_prompt(prompt), int(len(prompt))
        out = []
        for j in range(max_new):
            t = cls.token_at(h, plen, j, vocab)
            out.append(t)
            if eos_token is not None and t == eos_token:
                break
        return out

    # -- interface -------------------------------------------------------------
    def init_state(self):
        B = self.batch_slots
        return {"h": np.zeros(B, np.int64), "plen": np.zeros(B, np.int64),
                "emitted": np.zeros(B, np.int64)}

    def _sleep(self):
        if self.delay_s:
            import time
            time.sleep(self.delay_s)

    def prefill(self, prompt: np.ndarray, slot: int):
        with self._lock:
            self.prefill_calls += 1
        self._sleep()
        h, plen = self._hash_prompt(prompt), int(len(prompt))
        return (h, plen), self.token_at(h, plen, 0, self.vocab)

    def insert(self, state, fragment, slot: int):
        h, plen = fragment
        state["h"][slot] = h
        state["plen"][slot] = plen
        state["emitted"][slot] = 1       # prefill emitted token 0
        return state

    def decode(self, state, tokens: np.ndarray, occupied: np.ndarray):
        with self._lock:
            self.decode_calls += 1
        self._sleep()
        out = np.zeros(self.batch_slots, np.int64)
        for i in range(self.batch_slots):
            if not occupied[i]:
                continue
            out[i] = self.token_at(int(state["h"][i]), int(state["plen"][i]),
                                   int(state["emitted"][i]), self.vocab)
            state["emitted"][i] += 1
        return state, out

    def cache_bytes(self, batch: int, seq: int) -> int:
        return batch * seq * self.bytes_per_token

    def compile_stats(self) -> Dict[str, int]:
        with self._lock:
            return {"prefill_calls": self.prefill_calls,
                    "decode_steps": self.decode_calls,
                    "prefill_compiles": 0, "decode_compiles": 0}


__all__ = ["BatchExecutor", "JaxExecutor", "StubExecutor"]
