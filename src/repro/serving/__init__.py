"""Batched serving: `ServingEngine` dispatches request groups through the
runtime's event DAG (prefill/decode chains per group, overlapped across
groups — docs/runtime.md §4)."""

from .engine import ServingEngine, Request

__all__ = ["ServingEngine", "Request"]
