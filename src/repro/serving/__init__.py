"""Continuous-batching serving: `ServingEngine` schedules at request
granularity over fixed decode slots — submit()/step()/drain() admission,
per-step slot refill, paged KV from the context BufferPool, preemption
on OOM — dispatching each step's prefills and decode through the
runtime's event DAG (docs/serving.md).  `ServingMesh` replicates the
engine N ways behind a throughput-weighted router with fault-driven
request migration (docs/mesh.md)."""

from .engine import Request, RequestState, ServingEngine
from .executor import BatchExecutor, JaxExecutor, StubExecutor
from .mesh import Replica, ReplicaState, ServingMesh

__all__ = ["ServingEngine", "Request", "RequestState",
           "BatchExecutor", "JaxExecutor", "StubExecutor",
           "ServingMesh", "Replica", "ReplicaState"]
