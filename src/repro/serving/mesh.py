"""Replicated serving mesh: a front-end router over N engine replicas.

The paper's portability claim (§3) is that one workload maps onto many
devices without the application noticing; EngineCL (PAPERS.md) shows the
host-side runtime that owns scheduling across those devices can also
absorb *asymmetry and faults* behind a stable API.  :class:`ServingMesh`
is that runtime for serving (ROADMAP item 3): it owns ``n_replicas``
independent :class:`~repro.serving.engine.ServingEngine` replicas — each
on its own :class:`~repro.runtime.context.Context` over its own device,
weights shardable per replica via ``distributed/sharding.py`` rules —
and routes ``submit()`` across them so callers see one engine with N
replicas' throughput and none of their failures.

**Router policy** (docs/mesh.md §Router): a request goes to the healthy
replica with the best ``weight / (1 + queued_work)`` score, where the
weight is the PR-7 :class:`~repro.runtime.scheduler.ThroughputModel`
EWMA fed by per-replica step timings — a replica that steps slowly is
de-weighted before the straggler monitor ever flags it.  DRAINING
replicas (flagged by :class:`~repro.training.straggler.StragglerMonitor`)
receive new work only when no HEALTHY replica remains; DEAD replicas
never do.

**Failure ladder** (docs/mesh.md §Failure ladder): a
:class:`~repro.core.errors.DeviceLostError` (or injected
``inject_fault(stage="device")``) mid-group fails every resident of that
replica with the typed error, drains its KV pages to zero, and marks it
DEAD.  The mesh then *migrates*: residents lost mid-flight plus the
replica's still-waiting admissions are requeued on one sibling replica
at the FRONT of its queue (greedy decode makes the recompute bitwise-
identical, exactly like PR-6 preemption), order preserved.  Zero
requests are dropped; the typed error is surfaced on
:attr:`ServingMesh.last_device_loss` and counted, never swallowed.  With
no live sibling the victims park as orphans until
:meth:`recover_replica`; if every replica is dead, ``submit``/``drain``
raise the typed error instead of hanging.

**Observability**: :meth:`attach_trace` wires every replica's dispatch
queue into one :class:`~repro.runtime.trace.ChromeTrace` (one process
row per replica), records per-step ``kv_pages_live`` / queue-depth
counter tracks, and emits a flow arrow for every migration — the
chrome://tracing view shows a killed replica's slices stop and its
requests' arrows land on the sibling.

``tests/test_mesh_props.py`` drives all of this with a seeded
virtual-time random walk and a hypothesis state machine; the invariants
(exact-once retirement, streams are oracle prefixes, zero drops, KV
pages drain to zero on live *and* dead replicas, unhealthy replicas
never receive new work) are the mesh's contract.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.errors import InvalidArgError, ReproError
from repro.runtime.context import Context
from repro.runtime.platform import default_platform
from repro.runtime.scheduler import ThroughputModel
from repro.runtime.trace import ChromeTrace
from repro.training.straggler import StragglerConfig, StragglerMonitor

from .engine import Request, RequestState, ServingEngine

__all__ = ["ServingMesh", "Replica", "ReplicaState"]


class ReplicaState:
    """Replica health ladder: HEALTHY (routable) -> DRAINING (flagged
    slow; finishes residents, new work only as a last resort) -> back to
    HEALTHY once empty, or DEAD (device lost; never routable again until
    :meth:`ServingMesh.recover_replica`)."""

    HEALTHY = "healthy"
    DRAINING = "draining"
    DEAD = "dead"


class Replica:
    """One mesh slot: an engine on its own context/device plus health
    and timing state."""

    __slots__ = ("index", "engine", "context", "device", "state",
                 "step_time_override", "steps", "loss")

    def __init__(self, index: int, engine: ServingEngine,
                 context: Context, device) -> None:
        self.index = index
        self.engine = engine
        self.context = context
        self.device = device
        self.state = ReplicaState.HEALTHY
        # virtual-time hook: when set, observed step duration (fed to
        # the throughput model and straggler monitor) is this value
        # instead of the wall clock — the property harness stalls a
        # replica without sleeping
        self.step_time_override: Optional[float] = None
        self.steps = 0
        self.loss: Optional[BaseException] = None

    @property
    def key(self) -> str:
        return f"r{self.index}"

    @property
    def load(self) -> int:
        s = self.engine.scheduler_stats
        return s["waiting"] + s["running"]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Replica {self.index} {self.state} load={self.load}>"


class ServingMesh:
    """Front-end router owning N replica serving engines (module
    docstring: router policy, failure ladder, observability).

    Parameters
    ----------
    cfg, params, rules:
        Model config / parameters / sharding rules handed to every
        replica engine (``rules`` may also be a list, one per replica —
        heterogeneous sharding across replicas).  Pass ``None`` for all
        three when supplying ``executor_factory``.
    n_replicas:
        Replica count; each gets a fresh platform device
        (:meth:`~repro.runtime.platform.Platform.co_devices`) wrapped in
        its own single-device :class:`~repro.runtime.context.Context`.
    executor_factory:
        ``factory(replica_idx) -> BatchExecutor`` — the property harness
        passes per-replica
        :class:`~repro.serving.executor.StubExecutor`\\ s; also how
        :meth:`recover_replica` rebuilds a dead replica's engine.
    ewma_alpha / straggler_cfg:
        Router throughput-EWMA smoothing and straggler thresholds.
    timer:
        Clock used for per-replica step timing (default
        ``time.perf_counter``); injectable for virtual-time tests.
    engine_kwargs:
        Everything else (``batch_slots``, ``max_seq``, ``page_tokens``,
        ``kv_budget_bytes``, ``scheduler``, ...) is forwarded verbatim
        to every :class:`~repro.serving.engine.ServingEngine`.
    """

    def __init__(self, cfg=None, params=None, rules=None,
                 n_replicas: int = 2,
                 executor_factory: Optional[Callable[[int], Any]] = None,
                 ewma_alpha: float = 0.5,
                 straggler_cfg: Optional[StragglerConfig] = None,
                 timer: Callable[[], float] = time.perf_counter,
                 platform=None, **engine_kwargs):
        if n_replicas < 1:
            raise InvalidArgError(
                f"mesh needs >= 1 replica, got {n_replicas}")
        self.platform = platform or default_platform()
        self._factory = executor_factory
        self._cfg, self._params = cfg, params
        self._rules = rules if isinstance(rules, (list, tuple)) \
            else [rules] * n_replicas
        if len(self._rules) != n_replicas:
            raise InvalidArgError(
                f"{len(self._rules)} sharding rules for "
                f"{n_replicas} replicas")
        self._engine_kwargs = dict(engine_kwargs)
        self._timer = timer
        self._model = ThroughputModel(alpha=ewma_alpha)
        self._monitor = StragglerMonitor(straggler_cfg
                                         or StragglerConfig())
        self._trace: Optional[ChromeTrace] = None

        devices = self.platform.co_devices(n_replicas, driver="vector")
        self.replicas: List[Replica] = []
        for i, dev in enumerate(devices):
            ctx = Context(devices=[dev], platform=self.platform)
            eng = self._make_engine(i, ctx, dev)
            self.replicas.append(Replica(i, eng, ctx, dev))

        self._step_idx = 0
        self._orphans: List[Request] = []
        self.last_device_loss: Optional[BaseException] = None
        self.migrations: List[Dict[str, Any]] = []
        # the Request objects moved by the most recent migration, in
        # requeue order — the bench gate measures recovery (steps until
        # each is decoding again on the sibling) from these
        self.last_migrated: List[Request] = []
        self._sched = {"submitted": 0, "completed": 0, "failed": 0,
                       "migrated": 0, "orphaned": 0, "device_losses": 0,
                       "drops": 0, "steps": 0}

    def _make_engine(self, i: int, ctx: Context, dev) -> ServingEngine:
        executor = self._factory(i) if self._factory is not None else None
        return ServingEngine(self._cfg, self._params, self._rules[i],
                             context=ctx, device=dev,
                             executor=executor, **self._engine_kwargs)

    # ======================================================================
    # introspection
    # ======================================================================
    @property
    def current_step(self) -> int:
        return self._step_idx

    def alive(self) -> List[Replica]:
        """Replicas that can still run work (HEALTHY or DRAINING)."""
        return [r for r in self.replicas
                if r.state != ReplicaState.DEAD]

    def _candidates(self) -> List[Replica]:
        """Routable replicas: HEALTHY first; DRAINING only when no
        HEALTHY replica remains; DEAD never."""
        healthy = [r for r in self.replicas
                   if r.state == ReplicaState.HEALTHY]
        if healthy:
            return healthy
        return [r for r in self.replicas
                if r.state == ReplicaState.DRAINING]

    @property
    def mesh_stats(self) -> Dict[str, Any]:
        """Router counters plus per-replica health/load/weight — the
        observable the bench gate and docs/mesh.md read."""
        out: Dict[str, Any] = dict(self._sched)
        cands = self.alive()
        w = self._model.weights([r.index for r in cands]) if cands else []
        weights = {r.key: round(wi, 4) for r, wi in zip(cands, w)}
        out["replicas"] = [
            {"key": r.key, "state": r.state, "load": r.load,
             "steps": r.steps, "weight": weights.get(r.key, 0.0),
             "pages_live": r.engine.kv_stats["pages_live"]}
            for r in self.replicas]
        out["orphans"] = len(self._orphans)
        return out

    # ======================================================================
    # submission / routing
    # ======================================================================
    def _route(self) -> Replica:
        cands = self._candidates()
        if not cands:
            err = self.last_device_loss or ReproError(
                "no live replica in the mesh")
            raise err
        weights = self._model.weights([r.index for r in cands])
        # best throughput per unit of queued work; lowest index breaks
        # ties so routing is deterministic under equal weights
        best = max(zip(weights, cands),
                   key=lambda wc: (wc[0] / (1 + wc[1].load),
                                   -wc[1].index))
        return best[1]

    def submit(self, request: Request,
               replica: Optional[int] = None) -> int:
        """Admit one request, routed to the best live replica (module
        docstring: router policy).  ``replica`` pins it (tests).  Raises
        the typed device-loss error when every replica is dead."""
        if replica is not None:
            rep = self.replicas[replica]
            if rep.state == ReplicaState.DEAD:
                raise (rep.loss or ReproError(f"{rep.key} is dead"))
        else:
            rep = self._route()
        rid = rep.engine.submit(request)
        self._sched["submitted"] += 1
        return rid

    # ======================================================================
    # fault hooks (test/chaos API)
    # ======================================================================
    def kill_replica(self, i: int,
                     error: Optional[BaseException] = None) -> None:
        """Arm a replica-level device loss on replica ``i`` — it fires
        through that replica's next DAG round (kill-during-prefill /
        -decode, depending on what the round is doing), after which
        :meth:`step` observes the terminal engine and migrates."""
        self.replicas[i].engine.inject_fault(stage="device", error=error)

    def recover_replica(self, i: int) -> None:
        """Bring a DEAD replica back with a *fresh* engine (same
        context/device — the model server restarted); parked orphans
        requeue onto it immediately, order preserved."""
        rep = self.replicas[i]
        if rep.state != ReplicaState.DEAD:
            return
        rep.engine = self._make_engine(i, rep.context, rep.device)
        rep.state = ReplicaState.HEALTHY
        rep.loss = None
        self._monitor.forget(rep.key)
        if self._trace is not None:
            self._trace.attach_queue(
                rep.engine._queue, process=self._proc(rep),
                thread=f"dispatch-gen{rep.steps}")
        orphans, self._orphans = self._orphans, []
        for req in orphans:
            rep.engine.submit(req)

    # ======================================================================
    # stepping
    # ======================================================================
    def _observe(self, rep: Replica, running_before: int,
                 dt: float) -> None:
        if rep.step_time_override is not None:
            dt = rep.step_time_override
        self._model.observe(rep.index, max(1, running_before), dt)
        self._monitor.record(rep.key, dt)

    def _migrate(self, rep: Replica,
                 lost: List[Request]) -> None:
        """Requeue a dead replica's in-flight + waiting requests on one
        sibling, at the FRONT of its queue, order preserved (greedy
        decode recomputes the identical stream)."""
        err = rep.engine.device_lost
        rep.state = ReplicaState.DEAD
        rep.loss = err
        self.last_device_loss = err
        self._sched["device_losses"] += 1
        self._monitor.forget(rep.key)
        victims = lost + rep.engine.release_waiting()
        for req in victims:
            req.state = RequestState.WAITING
            req.done = False
            req.error = None
            req.out_tokens = []
        cands = self._candidates()
        if not cands:
            self._orphans.extend(victims)
            self._sched["orphaned"] += len(victims)
            return
        weights = self._model.weights([r.index for r in cands])
        sibling = max(zip(weights, cands),
                      key=lambda wc: (wc[0] / (1 + wc[1].load),
                                      -wc[1].index))[1]
        # front-requeue in reverse so victims[0] decodes first again
        for req in reversed(victims):
            sibling.engine.submit(req, front=True)
        self._sched["migrated"] += len(victims)
        self.last_migrated = list(victims)
        if self._trace is not None:
            for req in victims:
                src = self._trace.instant(
                    f"lost:r{req.id}", process=self._proc(rep),
                    args={"error": type(err).__name__})
                dst = self._trace.instant(
                    f"requeue:r{req.id}", process=self._proc(sibling))
                self._trace.flow(f"migrate:r{req.id}", src, dst)
        for req in victims:
            self.migrations.append(
                {"step": self._step_idx, "request": req.id,
                 "src": rep.key, "dst": sibling.key,
                 "error": type(err).__name__})

    def step(self) -> List[Request]:
        """One mesh step: step every live replica, feed the router's
        throughput EWMA and the straggler monitor with the step timings,
        migrate off any replica whose device was lost, and apply the
        straggler verdicts.  Returns the requests that *retired* this
        step (finished or terminally failed) — a migrated request is not
        retired and does not appear."""
        self._step_idx += 1
        self._sched["steps"] += 1
        retired: List[Request] = []
        for rep in self.replicas:
            if rep.state == ReplicaState.DEAD:
                continue
            eng = rep.engine
            running_before = eng.scheduler_stats["running"]
            t0 = self._timer()
            finished = eng.step()
            self._observe(rep, running_before, self._timer() - t0)
            rep.steps += 1
            if self._trace is not None:
                self._trace.counter("kv_pages_live",
                                    eng.kv_stats["pages_live"],
                                    process=self._proc(rep))
                self._trace.counter("waiting",
                                    eng.scheduler_stats["waiting"],
                                    process=self._proc(rep))
            if eng.device_lost is not None:
                # residents failed by the loss migrate; requests that
                # failed the same step from their *own* injected fault
                # carry a different error object and retire as failed
                lost = [r for r in finished
                        if r.error is eng.device_lost]
                other = [r for r in finished
                         if r.error is not eng.device_lost]
                self._migrate(rep, lost)
                finished = other
            for r in finished:
                if r.error is not None:
                    self._sched["failed"] += 1
                else:
                    self._sched["completed"] += 1
                retired.append(r)
        # straggler ladder: persistent outliers drain (no new work while
        # a healthy sibling exists); an empty drained replica rejoins
        flagged = set(self._monitor.check())
        healthy = sum(1 for r in self.replicas
                      if r.state == ReplicaState.HEALTHY)
        for rep in self.replicas:
            if rep.state == ReplicaState.HEALTHY and \
                    rep.key in flagged and healthy > 1:
                rep.state = ReplicaState.DRAINING
                healthy -= 1
            elif rep.state == ReplicaState.DRAINING and rep.load == 0:
                rep.state = ReplicaState.HEALTHY
                self._monitor.forget(rep.key)
                healthy += 1
        return retired

    def drain(self, max_steps: Optional[int] = None) -> List[Request]:
        """Step until no live replica holds work and no orphan is
        parked; returns the retired requests in retirement order.
        Raises the typed device-loss error — after failing every parked
        orphan with it — when all replicas are dead with work pending
        (never a hang)."""
        done: List[Request] = []
        stalled = 0
        while True:
            pending = sum(r.load for r in self.alive())
            if pending == 0 and not self._orphans:
                return done
            if not self.alive():
                err = self.last_device_loss or ReproError(
                    "mesh has no live replicas")
                orphans, self._orphans = self._orphans, []
                for req in orphans:
                    req.state = RequestState.FAILED
                    req.error = err
                    self._sched["failed"] += 1
                raise err
            if max_steps is not None and self._step_idx >= max_steps:
                return done
            out = self.step()
            done.extend(out)
            emitted = any(
                s is not None and s.request.out_tokens
                for rep in self.alive() for s in rep.engine._slots)
            stalled = 0 if (out or emitted) else stalled + 1
            if stalled > 4 * len(self.replicas) + 16:
                raise RuntimeError(
                    f"mesh made no progress for {stalled} steps "
                    f"({pending} pending, "
                    f"{len(self._orphans)} orphans)")

    # ======================================================================
    # observability
    # ======================================================================
    def _proc(self, rep: Replica) -> str:
        return f"replica{rep.index}:{rep.device.info.name}"

    def attach_trace(self, tr: Optional[ChromeTrace] = None
                     ) -> ChromeTrace:
        """Wire every replica's dispatch queue into one
        :class:`~repro.runtime.trace.ChromeTrace` — one process row per
        replica, flow arrows for migrations, counter tracks for
        ``kv_pages_live`` and queue depth.  Export with
        ``tr.export("out.json")`` and load in chrome://tracing
        (docs/mesh.md §Reading a mesh trace)."""
        tr = tr or ChromeTrace(name="mesh")
        self._trace = tr
        for rep in self.replicas:
            tr.attach_queue(rep.engine._queue,
                            process=self._proc(rep), thread="dispatch")
        return tr

    def detach_trace(self) -> None:
        if self._trace is not None:
            self._trace.detach_all()
            self._trace = None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        states = ",".join(f"{r.key}={r.state}" for r in self.replicas)
        return f"<ServingMesh {states}>"
