"""Batched serving engine: continuous prefill + decode over a KV cache.

The engine jits two functions per model — ``prefill`` (process a full
prompt, populate caches) and ``decode`` (one token for the whole batch) —
and drives them from a request queue.  Requests are grouped into fixed
batch slots; each group runs synchronized batched decode (all slots step
together), the standard TPU serving shape.

**DAG dispatch** (docs/runtime.md): each group's pipeline is enqueued on
an out-of-order :class:`~repro.runtime.queue.CommandQueue` as a chain of
events — ``prefill -> decode step 0 -> decode step 1 -> ...`` — with *no*
edges between groups, so independent groups overlap on the queue's worker
pool while each group's own steps stay strictly ordered.  Per-group state
flows through the chain, never across it, so results are identical to
serial execution; ``dag_stats`` reports how much overlap the DAG bought.

Steady-state compilation behaviour mirrors the kernel-compiler cache
(docs/caching.md): ``jax.jit`` memoizes by argument shape, and the engine
tracks the shapes it has dispatched so ``compile_stats`` proves that
repeated serving steps trigger zero recompilation — prefill compiles once
per prompt-length shape, decode compiles once per batch shape, and every
subsequent step is a cache hit.

**KV-block pooling** (docs/memory.md): each group's cache block is
accounted on the dispatch device's Bufalloc arena through a size-class
:class:`~repro.runtime.memory.BufferPool`, so per-request KV allocations
in steady state are O(1) free-list pops instead of first-fit walks;
``kv_stats`` exposes hit/miss counters.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import jax.tree_util as jtu

from repro.core.errors import InvalidArgError
from repro.distributed.sharding import ShardingRules
from repro.models import ModelConfig, forward, init_caches
from repro.runtime.bufalloc import OutOfMemory
from repro.runtime.memory import BufferPool
from repro.runtime.queue import CommandQueue


@dataclasses.dataclass
class Request:
    """One generation request: a prompt and a token budget.

    ``out_tokens`` is filled (and ``done`` set) by
    :meth:`ServingEngine.generate`."""

    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServingEngine:
    """Serves generation requests with batched prefill/decode.

    Parameters
    ----------
    batch_slots:
        Requests per group (the decode batch size).
    max_seq:
        KV-cache capacity per slot.
    dag_workers:
        Worker threads of the dispatch queue: independent request groups
        execute concurrently up to this width (1 disables overlap).
    device:
        Runtime device the dispatch queue binds to; defaults to the
        first device of ``context``.
    context:
        The :class:`~repro.runtime.context.Context` the engine's
        runtime resources come from (docs/host_api.md): the dispatch
        queue is created through it and per-group KV blocks are
        accounted on its per-device :class:`~repro.runtime.memory.
        BufferPool` — engines sharing a context share the KV block
        free lists.  Defaults to the process default context.
    """

    def __init__(self, cfg: ModelConfig, params, rules: ShardingRules,
                 batch_slots: int = 4, max_seq: int = 256,
                 aux_inputs: Optional[Dict] = None,
                 dag_workers: int = 2, device=None, context=None):
        self.cfg, self.rules = cfg, rules
        self.params = params
        self.B, self.S = batch_slots, max_seq
        self.aux = aux_inputs or {}

        def prefill(params, tokens, caches):
            logits, _, caches = forward(params, tokens, cfg, rules,
                                        aux_inputs=self.aux, caches=caches,
                                        mode="prefill")
            return logits[:, -1], caches

        def decode(params, tok, caches):
            logits, _, caches = forward(params, tok, cfg, rules,
                                        aux_inputs=self.aux, caches=caches,
                                        mode="decode")
            return logits[:, -1], caches

        self._prefill = jax.jit(prefill, donate_argnums=(2,))
        self._decode = jax.jit(decode, donate_argnums=(2,))
        # compile bookkeeping: compile counts are read from the jitted
        # functions' own tracing caches (so any retrace — new shape, dtype,
        # weak-type change — is observed); the shape sets are the expected
        # lower bound for cross-checking
        self._prefill_shapes: set = set()
        self._decode_shapes: set = set()
        self._calls = {"prefill": 0, "decode": 0}
        self._calls_lock = threading.Lock()
        # request groups dispatch through an out-of-order event DAG; one
        # chain of events per group, no cross-group edges.  The queue,
        # device, and KV pool all come from the host Context
        # (docs/host_api.md) so serving shares the runtime object model
        # with kernel launches and co-execution.
        if context is None:
            from repro.runtime.context import default_context
            context = default_context()
        self.context = context
        if device is None:
            device = context.devices[0]
        self._kv_bytes = self._cache_bytes()
        try:
            self._queue = context.create_queue(
                device, out_of_order=True, workers=max(1, dag_workers))
            # per-group KV-cache accounting goes through the context's
            # dedicated KV-class pool over the device arena
            # (docs/memory.md): each group's cache block is identically
            # sized, so after the first group every alloc is an O(1)
            # free-list pop instead of a first-fit walk
            self._kv_pool = context.pool_for(device, min_class=4096)
        except InvalidArgError:
            # a caller-supplied device outside the context's platform
            # (pre-context behaviour): fall back to engine-owned
            # resources so `device=` keeps working unchanged
            self._queue = CommandQueue(device, out_of_order=True,
                                       workers=max(1, dag_workers))
            self._kv_pool = BufferPool(device.allocator, min_class=4096)
        self._last_dag: Dict[str, Any] = {}
        self._kv_alloc_failures = 0

    def _cache_bytes(self) -> int:
        """Byte footprint of one group's KV/state caches, derived from
        the abstract cache pytree (family-independent)."""
        abstract = init_caches(self.cfg, self.B, self.S, abstract=True)
        return int(sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                       for leaf in jtu.tree_leaves(abstract)))

    @property
    def kv_stats(self) -> Dict[str, int]:
        """KV-block pool counters: steady-state serving shows one miss
        per concurrently-live group and hits for every later group."""
        out = dict(self._kv_pool.stats())
        out["kv_bytes_per_group"] = self._kv_bytes
        out["alloc_failures"] = self._kv_alloc_failures
        return out

    @property
    def compile_stats(self) -> Dict[str, int]:
        """Call and (re)compile counters proving steady-state serving does
        zero tracing work (docs/caching.md §Steady-state serving)."""
        return {
            "prefill_calls": self._calls["prefill"],
            "decode_steps": self._calls["decode"],
            "prefill_compiles": self._jit_compiles(
                self._prefill, len(self._prefill_shapes)),
            "decode_compiles": self._jit_compiles(
                self._decode, len(self._decode_shapes)),
        }

    @property
    def dag_stats(self) -> Dict[str, Any]:
        """What the last :meth:`generate` dispatch did: group/event counts,
        wall time, summed busy time, and the overlap factor busy/wall
        (1.0 = fully serial; >1 means independent groups overlapped)."""
        return dict(self._last_dag)

    @staticmethod
    def _jit_compiles(fn, fallback: int) -> int:
        try:
            return fn._cache_size()
        except AttributeError:  # older jax: fall back to shape bookkeeping
            return fallback

    def _run_prefill(self, tokens, caches):
        with self._calls_lock:   # groups run concurrently on the DAG
            self._calls["prefill"] += 1
            self._prefill_shapes.add(tuple(tokens.shape))
        return self._prefill(self.params, tokens, caches)

    def _run_decode(self, tok, caches):
        with self._calls_lock:
            self._calls["decode"] += 1
            self._decode_shapes.add(tuple(tok.shape))
        return self._decode(self.params, tok, caches)

    # -- group pipeline stages (each one DAG command) ---------------------------
    def _make_groups(self, requests: List[Request]) -> List[List[Request]]:
        groups = []
        for i in range(0, len(requests), self.B):
            group = requests[i:i + self.B]
            # right-pad the group to full batch slots
            while len(group) < self.B:
                group.append(Request(prompt=group[0].prompt,
                                     max_new_tokens=0))
            groups.append(group)
        return groups

    def _start_group(self, group: List[Request]) -> Dict[str, Any]:
        """Prefill stage: batch the prompts, populate caches, emit the
        first sampled token.  Returns the group's pipeline state."""
        plen = max(len(r.prompt) for r in group)
        toks = np.zeros((self.B, plen), np.int32)
        for j, r in enumerate(group):
            toks[j, :len(r.prompt)] = r.prompt   # left-aligned
        try:
            kv_chunk = self._kv_pool.alloc(self._kv_bytes)
        except OutOfMemory:
            # arena accounting is full: serve anyway, untracked
            kv_chunk = None
            self._kv_alloc_failures += 1
        try:
            caches = init_caches(self.cfg, self.B, self.S)
            last_logits, caches = self._run_prefill(jnp.asarray(toks),
                                                    caches)
        except BaseException:
            # a failed prefill never reaches the group state, so the
            # generate() reclaim could not see this chunk — free it here
            if kv_chunk is not None:
                self._kv_pool.free(kv_chunk)
            raise
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        return {"caches": caches, "tok": tok, "kv_chunk": kv_chunk,
                "outs": [[] for _ in group]}

    def _step_group(self, st: Dict[str, Any]) -> None:
        """One synchronized decode step for a group (one DAG command)."""
        tok = st["tok"]
        for j in range(self.B):
            st["outs"][j].append(int(tok[j]))
        last_logits, st["caches"] = self._run_decode(tok[:, None],
                                                     st["caches"])
        st["tok"] = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)

    def _finish_group(self, group: List[Request],
                      st: Dict[str, Any]) -> None:
        for j, r in enumerate(group):
            if r.max_new_tokens:
                r.out_tokens = st["outs"][j][:r.max_new_tokens]
                r.done = True
        if st.get("kv_chunk") is not None:
            # the group's KV block returns to its size-class free list;
            # the next group's alloc is a pool hit, not a first-fit walk
            self._kv_pool.free(st.pop("kv_chunk"))

    # -- dispatch ---------------------------------------------------------------
    def generate(self, requests: List[Request], greedy: bool = True
                 ) -> List[Request]:
        """Serve requests with batched synchronized decode, dispatching
        independent groups through the event DAG so they overlap."""
        groups = self._make_groups(requests)
        q = self._queue
        t0 = time.perf_counter()
        states: List[Dict[str, Any]] = []
        for gi, group in enumerate(groups):
            st: Dict[str, Any] = {}
            states.append(st)

            def prefill_cmd(group=group, st=st):
                st.update(self._start_group(group))

            ev = q.enqueue_native(prefill_cmd, name=f"prefill:g{gi}")
            for step in range(max(r.max_new_tokens for r in group)):
                def step_cmd(st=st):
                    self._step_group(st)
                ev = q.enqueue_native(step_cmd, wait_for=[ev],
                                      name=f"decode:g{gi}:s{step}")

            def finish_cmd(group=group, st=st):
                self._finish_group(group, st)

            q.enqueue_native(finish_cmd, wait_for=[ev],
                             name=f"finish:g{gi}")
        events = q.events()
        try:
            q.finish()
        finally:
            # a failed group pipeline skips its finish command; reclaim
            # any KV block it already allocated so the arena accounting
            # does not leak across failed generate() calls
            for st in states:
                if st.get("kv_chunk") is not None:
                    self._kv_pool.free(st.pop("kv_chunk"))
        wall = time.perf_counter() - t0
        busy = sum((e.end_ns - e.start_ns) for e in events
                   if e.start_ns and e.end_ns) / 1e9
        self._last_dag = {
            "groups": len(groups), "events": len(events),
            "wall_s": wall, "busy_s": busy,
            "overlap": (busy / wall) if wall > 0 else 1.0,
        }
        return [r for r in requests if r.done]
