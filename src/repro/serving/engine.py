"""Continuous-batching serving engine (docs/serving.md).

The engine schedules at *request* granularity over a fixed set of decode
slots — the EngineCL-style host scheduler the ROADMAP calls for, built on
the runtime pieces underneath it (event DAG, size-class ``BufferPool``,
host ``Context``):

* **Admission queue**: ``submit(request)`` enqueues; ``step()`` runs one
  scheduler step; ``drain()`` steps until idle.  ``generate(requests)``
  is the compatible one-shot wrapper (submit all + drain).
* **Continuous batching**: a request that hits EOS / ``max_tokens`` is
  evicted mid-decode and its slot is refilled from the waiting queue *on
  the same step* — a long generation no longer stalls its batch
  neighbours the way the old fixed-group engine did.
* **Paged KV**: each request's cache footprint is accounted as
  fixed-size pages (``page_tokens`` tokens each) allocated from the
  context's size-class :class:`~repro.runtime.memory.BufferPool`, grown
  lazily as the request decodes and freed page-by-page on eviction —
  replacing the old per-group monolithic block.
* **Preemption**: when page growth hits the KV budget (or the arena),
  the lowest-priority running request (latest arrival breaks ties)
  releases its pages and re-enters the waiting queue at the front —
  recompute-style preemption, no request dropped; the typed
  :class:`~repro.runtime.bufalloc.OutOfMemory` is surfaced via
  ``last_oom`` / ``kv_stats``.  A request that cannot fit even alone
  fails with the typed error instead of livelocking.
* **DAG dispatch** (docs/runtime.md): each step's prefill commands and
  the decode command are independent nodes on an out-of-order
  :class:`~repro.runtime.queue.CommandQueue`, so refill prefills overlap
  the decode step on the worker pool.  A failing command surfaces its
  *original typed* exception on the affected request's ``error`` while
  sibling requests keep running (see :meth:`inject_fault`).

Determinism: decode computes every slot row independently (per-row KV
positions, per-row length masking — ``repro.models.layers``), so each
request's token stream is bitwise-identical to serial one-request-at-a-
time execution regardless of slot assignment, co-tenants, preemption, or
arrival interleaving.  ``tests/test_serving_props.py`` state-machines
that invariant against a single-slot oracle.

``scheduler="fixed"`` keeps the paging and DAG machinery but only
refills when *every* slot is empty — the old synchronized-group
behaviour, kept as the benchmark baseline (``benchmarks/bench_serving.py``)
and as the regression reference for the short-tail bugfix (tails are
masked empty slots now, never duplicated requests).

Model work goes through a :class:`~repro.serving.executor.BatchExecutor`
(the jitted :class:`~repro.serving.executor.JaxExecutor` by default);
the deterministic :class:`~repro.serving.executor.StubExecutor` drives
the property harness without tracing anything.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.errors import InvalidArgError, ReproError
from repro.runtime.bufalloc import OutOfMemory
from repro.runtime.events import CommandError
from repro.runtime.memory import BufferPool
from repro.runtime.queue import CommandQueue

from .executor import BatchExecutor


class RequestState:
    """Lifecycle states of a request (docs/serving.md §Request lifecycle):
    WAITING -> RUNNING -> FINISHED, with RUNNING -> WAITING on preemption
    and -> FAILED on a typed error."""

    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"


@dataclasses.dataclass
class Request:
    """One generation request: a prompt and a token budget.

    ``out_tokens`` accumulates generated tokens; ``done`` is set on
    successful completion, ``error`` carries the typed
    :class:`~repro.core.errors.ReproError` on failure.  ``priority``
    orders preemption victims (lower preempts first); ``eos_token``
    stops generation early.  ``id``/``submit_step``/``finish_step``/
    ``preemptions`` are scheduler bookkeeping filled in by the engine.
    """

    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    priority: int = 0
    eos_token: Optional[int] = None
    out_tokens: Optional[List[int]] = None
    done: bool = False
    error: Optional[BaseException] = None
    state: str = RequestState.WAITING
    id: int = -1
    submit_step: int = -1
    finish_step: int = -1
    preemptions: int = 0


class _Slot:
    """One decode slot: the resident request plus its KV pages."""

    __slots__ = ("request", "pages", "cap_tokens", "last_tok", "inserted")

    def __init__(self, request: Request):
        self.request = request
        self.pages: List[Any] = []      # BufferPool chunks
        self.cap_tokens = 0             # tokens the pages cover
        self.last_tok = 0               # input token for the next decode
        self.inserted = False           # prefill fragment spliced in?


class ServingEngine:
    """Continuous-batching request scheduler over ``batch_slots`` decode
    slots (module docstring has the full picture).

    Parameters
    ----------
    cfg, params, rules:
        Model config / parameters / sharding rules for the default
        :class:`~repro.serving.executor.JaxExecutor`; pass ``None`` for
        all three when supplying ``executor``.
    batch_slots:
        Decode batch width (concurrently-running requests).
    max_seq:
        KV-cache capacity per slot; a request is force-finished when
        ``len(prompt) + generated`` reaches it.
    dag_workers:
        Worker threads of the dispatch queue; >=2 lets refill prefills
        overlap the decode command.
    device / context:
        Runtime placement, exactly as before: the dispatch queue and the
        KV page pool come from the host
        :class:`~repro.runtime.context.Context` (engines sharing a
        context share KV free lists); a foreign device falls back to
        engine-owned resources.
    scheduler:
        ``"continuous"`` (default) or ``"fixed"`` — the refill-barrier
        baseline (slots refill only when all are empty).
    page_tokens:
        Tokens per KV page (paging granularity).
    kv_budget_bytes:
        Optional engine-level cap on summed page bytes; growth past it
        triggers preemption.  ``None`` leaves only the arena as the
        limit.
    executor:
        A :class:`~repro.serving.executor.BatchExecutor` override (the
        property harness passes a
        :class:`~repro.serving.executor.StubExecutor`).
    """

    def __init__(self, cfg, params, rules,
                 batch_slots: int = 4, max_seq: int = 256,
                 aux_inputs: Optional[Dict] = None,
                 dag_workers: int = 2, device=None, context=None,
                 scheduler: str = "continuous", page_tokens: int = 16,
                 kv_budget_bytes: Optional[int] = None,
                 executor: Optional[BatchExecutor] = None,
                 prefill_bucket: int = 8, fusion: str = "flush"):
        if scheduler not in ("continuous", "fixed"):
            raise InvalidArgError(
                f"scheduler must be 'continuous' or 'fixed', "
                f"got {scheduler!r}")
        self.cfg, self.rules, self.params = cfg, rules, params
        self.B, self.S = batch_slots, max_seq
        self.aux = aux_inputs or {}
        self.scheduler = scheduler

        if executor is None:
            from .executor import JaxExecutor
            executor = JaxExecutor(cfg, params, rules, batch_slots,
                                   max_seq, aux_inputs=aux_inputs,
                                   prefill_bucket=prefill_bucket)
        if executor.batch_slots != batch_slots or \
                executor.max_seq != max_seq:
            raise InvalidArgError(
                f"executor shape ({executor.batch_slots}, "
                f"{executor.max_seq}) does not match engine "
                f"({batch_slots}, {max_seq})")
        self._exec = executor

        # runtime resources from the host Context (docs/host_api.md);
        # a caller-supplied device outside the context's platform falls
        # back to engine-owned queue + pool, as before
        if context is None:
            from repro.runtime.context import default_context
            context = default_context()
        self.context = context
        if device is None:
            device = context.devices[0]
        try:
            self._queue = context.create_queue(
                device, out_of_order=True, workers=max(1, dag_workers),
                fusion=fusion)
            self._kv_pool = context.pool_for(device, min_class=4096)
        except InvalidArgError:
            self._queue = CommandQueue(device, out_of_order=True,
                                       workers=max(1, dag_workers),
                                       fusion=fusion)
            self._kv_pool = BufferPool(device.allocator, min_class=4096)

        # paged KV accounting: page_bytes covers page_tokens tokens of
        # one slot's cache row (docs/serving.md §KV paging)
        self._kv_bytes = executor.cache_bytes(self.B, self.S)
        per_slot = executor.cache_bytes(1, self.S)
        self._bytes_per_token = max(1, -(-per_slot // self.S))
        self.page_tokens = max(1, int(page_tokens))
        self._page_bytes = self._bytes_per_token * self.page_tokens
        self._kv_budget = kv_budget_bytes
        self._kv_used = 0
        self._kv_alloc_failures = 0
        self.last_oom: Optional[OutOfMemory] = None

        # scheduler state
        self._waiting: deque = deque()
        self._slots: List[Optional[_Slot]] = [None] * self.B
        self._state: Any = None          # executor batch state (lazy)
        self._req_ids = itertools.count()
        self._step_idx = 0
        self._faults: Dict[int, Dict[str, Any]] = {}
        # replica-level device loss (docs/serving.md §Failure handling):
        # _device_fault is the armed error (fires through the next DAG
        # round), device_lost the terminal state once it has fired
        self._device_fault: Optional[BaseException] = None
        self.device_lost: Optional[BaseException] = None
        self._sched = {"submitted": 0, "completed": 0, "failed": 0,
                       "preemptions": 0, "evictions": 0, "steps": 0,
                       "pages_allocated": 0, "pages_freed": 0}
        self._dag_accum = {"steps": 0, "events": 0, "prefill_events": 0,
                           "decode_events": 0, "wall_s": 0.0,
                           "busy_s": 0.0}

    # ======================================================================
    # introspection
    # ======================================================================
    @property
    def current_step(self) -> int:
        return self._step_idx

    @property
    def kv_stats(self) -> Dict[str, int]:
        """KV page-pool counters: steady-state serving pops pages from
        the size-class free list (hits) and eviction returns them
        page-by-page (frees — per request, not per group)."""
        out = dict(self._kv_pool.stats())
        out["kv_bytes_per_group"] = self._kv_bytes   # full-batch footprint
        out["bytes_per_token"] = self._bytes_per_token
        out["page_bytes"] = self._page_bytes
        out["page_tokens"] = self.page_tokens
        out["kv_used_bytes"] = self._kv_used
        out["pages_live"] = self._kv_used // self._page_bytes
        out["alloc_failures"] = self._kv_alloc_failures
        return out

    @property
    def compile_stats(self) -> Dict[str, int]:
        """Call and (re)compile counters proving steady-state serving does
        zero tracing work (docs/caching.md §Steady-state serving)."""
        out = {"prefill_calls": 0, "decode_steps": 0,
               "prefill_compiles": 0, "decode_compiles": 0}
        out.update(self._exec.compile_stats())
        return out

    @property
    def scheduler_stats(self) -> Dict[str, int]:
        """Scheduler counters: admissions, evictions, preemptions, and
        the current queue/slot occupancy."""
        out = dict(self._sched)
        out["waiting"] = len(self._waiting)
        out["running"] = sum(1 for s in self._slots if s is not None)
        return out

    @property
    def dag_stats(self) -> Dict[str, Any]:
        """What the dispatch DAG did since the last :meth:`generate` (or
        engine creation): event counts, wall time, summed busy time, and
        the overlap factor busy/wall (>1 means prefill overlapped
        decode).  ``fusion`` nests the dispatch queue's DAG-fusion
        counters (docs/runtime.md §Kernel fusion) — decode-step kernel
        chains enqueued through the queue fuse like any other."""
        out = dict(self._dag_accum)
        out["overlap"] = (out["busy_s"] / out["wall_s"]) \
            if out["wall_s"] > 0 else 1.0
        out["fusion"] = self._queue.dag_stats()
        return out

    # ======================================================================
    # submission
    # ======================================================================
    def submit(self, request: Request, front: bool = False) -> int:
        """Admit a request to the waiting queue; returns its id.

        Validates the prompt against slot capacity — a prompt that can
        never fit (``len(prompt) >= max_seq``) is rejected with a typed
        :class:`~repro.core.errors.InvalidArgError` instead of wedging
        the queue.  ``front=True`` admits at the *front* of the queue
        (the serving mesh requeues requests migrated off a lost replica
        this way, so they restart before later arrivals).  An engine
        whose device was lost re-raises the typed ``device_lost`` error
        instead of accepting work it can never run."""
        if self.device_lost is not None:
            raise self.device_lost
        plen = int(len(request.prompt))
        if plen < 1:
            raise InvalidArgError("empty prompt")
        if plen >= self.S:
            raise InvalidArgError(
                f"prompt length {plen} >= max_seq {self.S}: no room to "
                f"generate")
        request.id = next(self._req_ids)
        request.state = RequestState.WAITING
        request.out_tokens = []
        request.done = False
        request.error = None
        request.submit_step = self._step_idx
        request.finish_step = -1
        self._sched["submitted"] += 1
        if front:
            self._waiting.appendleft(request)
        else:
            self._waiting.append(request)
        return request.id

    def inject_fault(self, request: Optional[Request] = None,
                     stage: str = "decode",
                     error: Optional[BaseException] = None) -> None:
        """Arm a device-side failure (test/chaos hook, ROADMAP item 3).

        Per-request stages (``request`` required): ``stage="prefill"``
        makes the request's prefill command raise; ``stage="decode"``
        enqueues a failing DAG command attributed to the request on its
        next decode step.  The typed error (default
        :class:`~repro.core.errors.DeviceLostError`) surfaces on the
        request's ``error`` while siblings complete.

        ``stage="device"`` (``request`` must be ``None``) arms a
        *replica-level* device loss: during the next scheduler step
        every command of the DAG round — staged prefills and the shared
        decode — raises the error, so every resident request fails at
        once with the same typed error object, pages drain to zero, the
        queue's unflushed commands are cancelled, and the engine goes
        terminal (``device_lost``).  Waiting requests are untouched —
        the serving mesh (:mod:`repro.serving.mesh`) reclaims them with
        :meth:`release_waiting` and requeues everything on a sibling."""
        if stage == "device":
            if request is not None:
                raise InvalidArgError(
                    "device-level loss takes the whole replica down; "
                    "pass request=None (per-request faults are the "
                    "prefill/decode stages)")
            if error is None:
                from repro.core.errors import DeviceLostError
                error = DeviceLostError("injected device loss")
            self._device_fault = error
            return
        if stage not in ("prefill", "decode"):
            raise InvalidArgError(f"unknown fault stage {stage!r}")
        if request is None:
            raise InvalidArgError(
                f"stage {stage!r} faults one request; pass it (device "
                f"loss is stage='device')")
        if request.id < 0:
            raise InvalidArgError("submit the request before injecting "
                                  "a fault")
        if error is None:
            from repro.core.errors import DeviceLostError
            error = DeviceLostError(
                f"injected {stage} fault for request {request.id}")
        self._faults[request.id] = {"stage": stage, "error": error}

    def release_waiting(self) -> List[Request]:
        """Hand back (and clear) the admission queue — the serving mesh
        calls this after a device loss to migrate not-yet-started
        requests to a sibling replica.  Requests stay in WAITING state
        and carry no error; re-``submit`` re-initializes them."""
        out = list(self._waiting)
        self._waiting.clear()
        return out

    # ======================================================================
    # KV paging
    # ======================================================================
    def _grow(self, slot: _Slot, want_tokens: int) -> None:
        """Grow a slot's pages to cover ``want_tokens`` cache positions;
        raises the typed OutOfMemory on budget or arena exhaustion."""
        while slot.cap_tokens < want_tokens:
            if self._kv_budget is not None and \
                    self._kv_used + self._page_bytes > self._kv_budget:
                raise OutOfMemory(
                    f"KV budget exhausted: {self._kv_used} used + "
                    f"{self._page_bytes} page > {self._kv_budget} budget")
            chunk = self._kv_pool.alloc(self._page_bytes)
            slot.pages.append(chunk)
            slot.cap_tokens += self.page_tokens
            self._kv_used += self._page_bytes
            self._sched["pages_allocated"] += 1

    def _free_pages(self, slot: _Slot) -> None:
        """Return a slot's KV pages to the pool, page by page."""
        for chunk in slot.pages:
            self._kv_pool.free(chunk)
            self._kv_used -= self._page_bytes
            self._sched["pages_freed"] += 1
        slot.pages = []
        slot.cap_tokens = 0

    def _tokens_needed(self, req: Request) -> int:
        """Cache positions the request occupies after its next token."""
        return min(len(req.prompt) + len(req.out_tokens) + 1, self.S)

    def _preempt_one(self, requester: Request) -> Optional[int]:
        """Preempt the lowest-priority occupied slot whose priority does
        not exceed the requester's (latest arrival breaks ties); the
        victim's pages are freed and it re-enters the waiting queue at
        the front (recompute-style — deterministic decode regenerates
        the same tokens).  Returns the freed slot index, or None if every
        other resident outranks the requester."""
        candidates = [
            (s.request.priority, -s.request.id, i)
            for i, s in enumerate(self._slots)
            if s is not None and s.request.priority <= requester.priority]
        if not candidates:
            return None
        _, _, vi = min(candidates)
        slot = self._slots[vi]
        victim = slot.request
        self._free_pages(slot)
        self._slots[vi] = None
        victim.state = RequestState.WAITING
        victim.out_tokens = []
        victim.preemptions += 1
        self._waiting.appendleft(victim)
        self._sched["preemptions"] += 1
        return vi

    def _ensure_capacity(self, i: int) -> bool:
        """Pre-decode page growth for slot ``i``, preempting on OOM.
        Returns False when the slot lost its resident (self-preempted or
        failed)."""
        while True:
            slot = self._slots[i]
            if slot is None:
                return False
            try:
                self._grow(slot, self._tokens_needed(slot.request))
                return True
            except OutOfMemory as e:
                self.last_oom = e
                self._kv_alloc_failures += 1
                others = sum(1 for j, s in enumerate(self._slots)
                             if s is not None and j != i)
                if others == 0:
                    # sole resident: every live page is already its own,
                    # so no preemption can help — fail with the typed
                    # error rather than livelock
                    self._fail_slot(i, e)
                    return False
                vi = self._preempt_one(slot.request)
                if vi is None or vi == i:
                    # every other resident outranks this request (or it
                    # preempted itself): yield the slot and retry later
                    if vi is None:
                        self._preempt_self(i)
                    return False

    def _preempt_self(self, i: int) -> None:
        slot = self._slots[i]
        self._free_pages(slot)
        self._slots[i] = None
        r = slot.request
        r.state = RequestState.WAITING
        r.out_tokens = []
        r.preemptions += 1
        self._waiting.appendleft(r)
        self._sched["preemptions"] += 1

    # ======================================================================
    # request completion / failure
    # ======================================================================
    def _finish_request(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.done = True
        req.finish_step = self._step_idx
        self._sched["completed"] += 1

    def _evict(self, i: int) -> Request:
        """Free slot ``i``'s pages and mark its request finished."""
        slot = self._slots[i]
        self._free_pages(slot)
        self._slots[i] = None
        self._sched["evictions"] += 1
        self._finish_request(slot.request)
        return slot.request

    def _fail_slot(self, i: int, error: BaseException) -> Request:
        slot = self._slots[i]
        self._free_pages(slot)
        self._slots[i] = None
        return self._fail_request(slot.request, error)

    def _fail_request(self, req: Request, error: BaseException) -> Request:
        req.state = RequestState.FAILED
        req.error = error
        req.finish_step = self._step_idx
        self._sched["failed"] += 1
        self._faults.pop(req.id, None)
        return req

    def _should_finish(self, slot: _Slot) -> bool:
        r = slot.request
        if len(r.out_tokens) >= r.max_new_tokens:
            return True
        if r.eos_token is not None and r.out_tokens and \
                r.out_tokens[-1] == r.eos_token:
            return True
        # cache full: force-finish (truncated) rather than overrun
        return len(r.prompt) + len(r.out_tokens) >= self.S

    # ======================================================================
    # admission
    # ======================================================================
    def _admit(self, i: int, req: Request) -> Optional[_Slot]:
        """Reserve slot ``i`` for ``req``: allocate pages for the prompt
        plus the prefill's first token.  Returns None (pages rolled
        back, request NOT requeued) when the allocation fails — the
        caller decides between deferral and failure."""
        slot = _Slot(req)
        try:
            self._grow(slot, min(len(req.prompt) + 1, self.S))
        except OutOfMemory as e:
            self.last_oom = e
            self._kv_alloc_failures += 1
            self._free_pages(slot)
            return None
        self._slots[i] = slot
        req.state = RequestState.RUNNING
        return slot

    def _refill_slots(self, finished: List[Request]) -> List[tuple]:
        """Pop waiting requests into free slots (continuous mode; fixed
        mode only when every slot is empty — the refill barrier).
        Zero-budget requests complete immediately without a slot.
        Returns ``(slot_idx, request)`` pairs needing prefill."""
        if self.scheduler == "fixed" and \
                any(s is not None for s in self._slots):
            return []
        staged = []
        for i in range(self.B):
            if self._slots[i] is not None:
                continue
            while self._waiting:
                req = self._waiting.popleft()
                if req.max_new_tokens <= 0:
                    self._finish_request(req)
                    finished.append(req)
                    continue
                if self._admit(i, req) is None:
                    if all(s is None for s in self._slots):
                        # nothing resident to wait on: the request can
                        # never fit — fail typed instead of wedging
                        finished.append(
                            self._fail_request(req, self.last_oom))
                        continue
                    self._waiting.appendleft(req)   # defer
                    return staged
                staged.append((i, req))
                break
            if not self._waiting and self._slots[i] is None:
                break
        return staged

    # ======================================================================
    # the DAG round
    # ======================================================================
    def _make_prefill_cmd(self, i: int, req: Request):
        holder: Dict[str, Any] = {}

        def cmd():
            if self._device_fault is not None:
                # replica-level loss: every command of the round fails
                # with the same typed error object (kill-during-prefill)
                raise self._device_fault
            fault = self._faults.get(req.id)
            if fault is not None and fault["stage"] == "prefill":
                self._faults.pop(req.id, None)
                raise fault["error"]
            frag, tok = self._exec.prefill(np.asarray(req.prompt,
                                                      np.int32), i)
            holder["frag"], holder["tok"] = frag, tok

        return holder, cmd

    def _install_prefill(self, i: int, req: Request,
                         holder: Dict[str, Any],
                         finished: List[Request]) -> None:
        """Splice a completed prefill into its slot and emit token 0."""
        if self._state is None:
            self._state = self._exec.init_state()
        self._state = self._exec.insert(self._state, holder["frag"], i)
        slot = self._slots[i]
        slot.inserted = True
        tok = int(holder["tok"])
        req.out_tokens.append(tok)
        slot.last_tok = tok
        if self._should_finish(slot):
            finished.append(self._evict(i))

    def _run_round(self, staged: List[tuple], events: List,
                   finished: List[Request]) -> None:
        """One DAG round: staged prefills + (optionally) one decode
        command for the already-resident slots, all independent nodes on
        the out-of-order queue, then failure surfacing and state
        updates."""
        q = self._queue
        prefills = []
        for i, req in staged:
            holder, cmd = self._make_prefill_cmd(i, req)
            ev = q.enqueue_native(cmd, name=f"prefill:r{req.id}")
            prefills.append((i, req, holder, ev))
            events.append(ev)
            self._dag_accum["prefill_events"] += 1

        staged_idx = {i for i, _ in staged}
        decode_rows = [i for i in range(self.B)
                       if self._slots[i] is not None
                       and self._slots[i].inserted
                       and i not in staged_idx]
        decode_ev = None
        decode_holder: Dict[str, Any] = {}
        if decode_rows:
            toks = np.zeros(self.B, np.int64)
            occ = np.zeros(self.B, bool)
            for i in decode_rows:
                toks[i] = self._slots[i].last_tok
                occ[i] = True

            def decode_cmd():
                if self._device_fault is not None:
                    # replica-level loss mid-decode: the shared decode
                    # command fails, taking every decoding row with it
                    raise self._device_fault
                st, out = self._exec.decode(self._state, toks, occ)
                self._state = st
                decode_holder["out"] = out

            decode_ev = q.enqueue_native(
                decode_cmd, name=f"decode:s{self._step_idx}")
            events.append(decode_ev)
            self._dag_accum["decode_events"] += 1

        # armed decode-stage faults: a separately-enqueued failing
        # command attributed to the request (a device-side failure
        # mid-group that must not take the siblings down)
        fault_evs = []
        for rid, fault in list(self._faults.items()):
            if fault["stage"] != "decode":
                continue
            owner = next((i for i in decode_rows
                          if self._slots[i] is not None
                          and self._slots[i].request.id == rid), None)
            if owner is None:
                continue
            self._faults.pop(rid, None)

            def fault_cmd(err=fault["error"]):
                raise err

            ev = q.enqueue_native(fault_cmd, name=f"fault:r{rid}")
            fault_evs.append((owner, ev))
            events.append(ev)

        try:
            q.finish()
        except CommandError:
            pass   # surfaced per-event below, onto the affected request

        # failure surfacing: each failed event maps to exactly the
        # request(s) it belongs to, carrying the original typed error
        for i, req, holder, ev in prefills:
            if ev.failed:
                finished.append(self._fail_slot(i, ev.error))
        for i, ev in fault_evs:
            if ev.failed and self._slots[i] is not None:
                finished.append(self._fail_slot(i, ev.error))
        if decode_ev is not None and decode_ev.failed:
            # the shared decode command failed: every decoding request
            # is affected (the staged prefills are independent nodes and
            # carry on)
            for i in decode_rows:
                if self._slots[i] is not None:
                    finished.append(self._fail_slot(i, decode_ev.error))
        elif decode_ev is not None:
            out = decode_holder["out"]
            for i in decode_rows:
                slot = self._slots[i]
                if slot is None:      # failed via an injected fault
                    continue
                tok = int(out[i])
                slot.request.out_tokens.append(tok)
                slot.last_tok = tok
                if self._should_finish(slot):
                    finished.append(self._evict(i))

        for i, req, holder, ev in prefills:
            if ev.failed or self._slots[i] is None:
                continue
            self._install_prefill(i, req, holder, finished)

    # ======================================================================
    # the scheduler step
    # ======================================================================
    def step(self) -> List[Request]:
        """One scheduler step; returns the requests that finished (or
        failed) during it.

        Phases: (1) pre-decode page growth for residents, preempting on
        OOM; (2) refill free slots from the waiting queue; (3) one DAG
        round — refill prefills overlap the decode command; (4) evict
        finished requests; (5) *same-step* refill of slots freed by
        eviction, so a newly-admitted request has its first token before
        the step returns."""
        if self.device_lost is not None:
            return []          # terminal: the mesh routes around us
        self._step_idx += 1
        self._sched["steps"] += 1
        t0 = time.perf_counter()
        events: List = []
        finished: List[Request] = []
        if self._state is None:
            self._state = self._exec.init_state()

        # 1. page growth (continuous + fixed both page)
        for i in range(self.B):
            if self._slots[i] is not None and self._slots[i].inserted:
                self._ensure_capacity(i)

        # 2+3. refill, then the overlapped DAG round
        staged = self._refill_slots(finished)
        self._run_round(staged, events, finished)

        # 5. same-step refill: evictions (and preemption-freed slots)
        # refill immediately — each refill is its own small DAG round
        # (prefill + insert), repeated until slots or queue run dry
        if self.scheduler == "continuous":
            guard = 0
            while self._waiting and self._device_fault is None and \
                    any(s is None for s in self._slots) and \
                    guard <= 2 * self.B + len(self._waiting):
                guard += 1
                staged = self._refill_slots(finished)
                if not staged:
                    break
                self._run_round(staged, events, finished)

        # an armed device loss fired through the round above: finalize.
        # Any still-resident slot (e.g. admitted but never commanded this
        # round) fails with the same typed error, the queue's unflushed
        # commands are cancelled so finish(timeout) never reports work
        # migrated to a sibling as "stuck", and the engine goes terminal.
        if self._device_fault is not None:
            err, self._device_fault = self._device_fault, None
            self.device_lost = err
            for i in range(self.B):
                if self._slots[i] is not None:
                    finished.append(self._fail_slot(i, err))
            self._queue.cancel_pending(err)

        wall = time.perf_counter() - t0
        busy = sum((e.end_ns - e.start_ns) for e in events
                   if e.start_ns and e.end_ns) / 1e9
        self._dag_accum["steps"] += 1
        self._dag_accum["events"] += len(events)
        self._dag_accum["wall_s"] += wall
        self._dag_accum["busy_s"] += busy
        return finished

    def drain(self, max_steps: Optional[int] = None) -> List[Request]:
        """Step until the queue and every slot are empty; returns the
        requests that finished (or failed), in completion order."""
        done: List[Request] = []
        stalled = 0
        while self._waiting or any(s is not None for s in self._slots):
            if self.device_lost is not None:
                # a lost device can never drain its queue: surface the
                # typed error instead of spinning (the mesh migrates the
                # waiting requests before this can trigger)
                raise self.device_lost
            if max_steps is not None and self._sched["steps"] >= max_steps:
                break
            out = self.step()
            done.extend(out)
            # progress = tokens emitted or requests retired; a scheduler
            # that does neither for several consecutive steps is wedged
            emitted = any(s is not None and s.request.out_tokens
                          for s in self._slots)
            if out or emitted:
                stalled = 0
            else:
                stalled += 1
                if stalled > 2 * self.B + 8:
                    raise RuntimeError(
                        "serving scheduler made no progress for "
                        f"{stalled} steps ({len(self._waiting)} waiting)")
        return done

    # ======================================================================
    # compatible one-shot entry point
    # ======================================================================
    def generate(self, requests: List[Request], greedy: bool = True
                 ) -> List[Request]:
        """Submit every request and drain the scheduler; returns the
        completed requests (the pre-scheduler signature, kept for
        callers that batch up-front)."""
        for k in self._dag_accum:
            self._dag_accum[k] = 0 if isinstance(self._dag_accum[k], int) \
                else 0.0
        for r in requests:
            self.submit(r)
        self.drain()
        return [r for r in requests if r.done]


__all__ = ["ServingEngine", "Request", "RequestState"]
