"""Batched serving engine: continuous prefill + decode over a KV cache.

The engine jits two functions per model — ``prefill`` (process a full
prompt, populate caches) and ``decode`` (one token for the whole batch) —
and drives them from a request queue.  Requests are grouped into fixed
batch slots; the engine runs synchronized batched decode (all slots step
together), the standard TPU serving shape.  Commands flow through the
pocl-style runtime command queue so kernel launches and transfers are
event-ordered (§3 of the paper).

Steady-state compilation behaviour mirrors the kernel-compiler cache
(docs/caching.md): ``jax.jit`` memoizes by argument shape, and the engine
tracks the shapes it has dispatched so ``compile_stats`` proves that
repeated serving steps trigger zero recompilation — prefill compiles once
per prompt-length shape, decode compiles once per batch shape, and every
subsequent step is a cache hit.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules
from repro.models import ModelConfig, forward, init_caches


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, rules: ShardingRules,
                 batch_slots: int = 4, max_seq: int = 256,
                 aux_inputs: Optional[Dict] = None):
        self.cfg, self.rules = cfg, rules
        self.params = params
        self.B, self.S = batch_slots, max_seq
        self.aux = aux_inputs or {}

        def prefill(params, tokens, caches):
            logits, _, caches = forward(params, tokens, cfg, rules,
                                        aux_inputs=self.aux, caches=caches,
                                        mode="prefill")
            return logits[:, -1], caches

        def decode(params, tok, caches):
            logits, _, caches = forward(params, tok, cfg, rules,
                                        aux_inputs=self.aux, caches=caches,
                                        mode="decode")
            return logits[:, -1], caches

        self._prefill = jax.jit(prefill, donate_argnums=(2,))
        self._decode = jax.jit(decode, donate_argnums=(2,))
        # compile bookkeeping: compile counts are read from the jitted
        # functions' own tracing caches (so any retrace — new shape, dtype,
        # weak-type change — is observed); the shape sets are the expected
        # lower bound for cross-checking
        self._prefill_shapes: set = set()
        self._decode_shapes: set = set()
        self._calls = {"prefill": 0, "decode": 0}

    @property
    def compile_stats(self) -> Dict[str, int]:
        return {
            "prefill_calls": self._calls["prefill"],
            "decode_steps": self._calls["decode"],
            "prefill_compiles": self._jit_compiles(
                self._prefill, len(self._prefill_shapes)),
            "decode_compiles": self._jit_compiles(
                self._decode, len(self._decode_shapes)),
        }

    @staticmethod
    def _jit_compiles(fn, fallback: int) -> int:
        try:
            return fn._cache_size()
        except AttributeError:  # older jax: fall back to shape bookkeeping
            return fallback

    def _run_prefill(self, tokens, caches):
        self._calls["prefill"] += 1
        self._prefill_shapes.add(tuple(tokens.shape))
        return self._prefill(self.params, tokens, caches)

    def _run_decode(self, tok, caches):
        self._calls["decode"] += 1
        self._decode_shapes.add(tuple(tok.shape))
        return self._decode(self.params, tok, caches)

    def generate(self, requests: List[Request], greedy: bool = True
                 ) -> List[Request]:
        """Serve a list of requests with batched synchronized decode."""
        cfg = self.cfg
        for i in range(0, len(requests), self.B):
            group = requests[i:i + self.B]
            # right-pad the group to full batch slots
            while len(group) < self.B:
                group.append(Request(prompt=group[0].prompt,
                                     max_new_tokens=0))
            plen = max(len(r.prompt) for r in group)
            toks = np.zeros((self.B, plen), np.int32)
            for j, r in enumerate(group):
                toks[j, :len(r.prompt)] = r.prompt   # left-aligned
            caches = init_caches(cfg, self.B, self.S)
            last_logits, caches = self._run_prefill(jnp.asarray(toks), caches)
            max_new = max(r.max_new_tokens for r in group)
            outs = [[] for _ in group]
            tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
            for step in range(max_new):
                for j in range(self.B):
                    outs[j].append(int(tok[j]))
                last_logits, caches = self._run_decode(tok[:, None], caches)
                tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
            for j, r in enumerate(group):
                if r.max_new_tokens:
                    r.out_tokens = outs[j][:r.max_new_tokens]
                    r.done = True
        return [r for r in requests if r.done]
