"""Jit-able prefill / decode step functions (shared by the serving engine
and the multi-pod dry-run)."""

from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules
from repro.models import ModelConfig, forward


def _aux(batch: Dict[str, Any]):
    return {k: v for k, v in batch.items() if k != "tokens"}


def make_prefill_step(cfg: ModelConfig, rules: ShardingRules):
    """Build the jit-able prefill step: consumes a full prompt batch,
    fills the KV caches, and returns the last-position logits."""
    def prefill_step(params, batch, caches):
        logits, _, caches = forward(params, batch["tokens"], cfg, rules,
                                    aux_inputs=_aux(batch), caches=caches,
                                    mode="prefill")
        return logits[:, -1], caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: ShardingRules):
    """Build the jit-able decode step: one synchronized token for the
    whole batch, greedily sampled from the step logits."""
    def decode_step(params, batch, caches):
        logits, _, caches = forward(params, batch["tokens"], cfg, rules,
                                    aux_inputs=_aux(batch), caches=caches,
                                    mode="decode")
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return tok, caches
    return decode_step
