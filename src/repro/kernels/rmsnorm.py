"""Fused RMSNorm as a Pallas kernel, using Vecmathlib's rsqrt.

A deliberately simple kernel demonstrating the vml-inside-Pallas integration
(paper §5: built-ins linked into the kernel at IR level so they vectorize
with surrounding code): the normalizer uses :func:`repro.vml.rsqrt`
(Newton iteration on the magic-constant initial guess), which lowers to
straight VPU vector ops inside the kernel body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import vml


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, use_vml: bool):
    x = x_ref[...].astype(jnp.float32)          # (block_rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    r = vml.rsqrt(var + eps) if use_vml else jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * r * w[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "use_vml",
                                             "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = 128, use_vml: bool = True,
            interpret: bool = True) -> jnp.ndarray:
    """x: (..., d); w: (d,).  Rows are tiled over the grid."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = int(x.size // d)
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, use_vml=use_vml)
    out = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
