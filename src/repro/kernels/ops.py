"""Dispatch wrappers: Pallas kernel vs pure-jnp reference.

The model stack calls these; ``use_pallas`` selects the hand-written Pallas
kernels (interpret=True on CPU, Mosaic on TPU).  The reference path is the
default for training (XLA-differentiable) and for the multi-pod dry-run.
This mirrors pocl linking device-optimized built-in libraries at IR level:
same call site, target-specific implementation.
"""

from __future__ import annotations



from . import ref
from .decode_attention import decode_attention as _dec_pallas
from .flash_attention import flash_attention as _fa_pallas
from .rmsnorm import rmsnorm as _rms_pallas
from .ssd_scan import ssd_scan as _ssd_pallas


def attention(q, k, v, causal: bool = True, use_pallas: bool = False,
              block_q: int = 128, block_k: int = 128):
    if use_pallas:
        return _fa_pallas(q, k, v, causal=causal, block_q=block_q,
                          block_k=block_k)
    return ref.attention(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, lengths, use_pallas: bool = False,
                     block_k: int = 256):
    if use_pallas:
        return _dec_pallas(q, k_cache, v_cache, lengths, block_k=block_k)
    return ref.decode_attention(q, k_cache, v_cache, lengths)


def rmsnorm(x, w, eps: float = 1e-6, use_pallas: bool = False):
    if use_pallas:
        return _rms_pallas(x, w, eps=eps)
    return ref.rmsnorm(x, w, eps=eps)


def ssd_scan(x, dt, A, B, C, chunk: int = 64, use_pallas: bool = False):
    if use_pallas:
        return _ssd_pallas(x, dt, A, B, C, chunk=chunk)
    return ref.ssd_scan(x, dt, A, B, C, chunk=chunk, return_state=True)
