"""Single-token decode attention over a KV cache, as a Pallas TPU kernel.

Decode attention is memory-bound (one query row against S cached keys), so
the kernel is organized to stream K/V blocks through VMEM exactly once:
grid ``(batch*heads, k_blocks)``, running-softmax scratch like flash
attention, and a ``lengths`` scalar-prefetch operand masks the invalid cache
tail.  Block size tunes the VMEM footprint: ``2 * block_k * D * bytes``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, sm_scale: float, block_k: int, n_kb: int, h: int):
    bh = pl.program_id(0)
    ki = pl.program_id(1)
    b = bh // h

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(ki * block_k < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)[None, :] * sm_scale  # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)                     # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1,bk)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kb - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :] = (acc_scr[...] / l[:, None])[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_k",
                                             "interpret"))
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray,
                     sm_scale: Optional[float] = None, block_k: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, D); caches: (B, Hkv, S, D); lengths: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    group = H // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_kb = S // block_k
    scale = float(sm_scale) if sm_scale is not None \
        else 1.0 / float(np.sqrt(D))

    kernel = functools.partial(_dec_kernel, sm_scale=scale, block_k=block_k,
                               n_kb=n_kb, h=H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda bh, ki, lens: (bh // H, bh % H, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda bh, ki, lens: (bh // H, (bh % H) // group,
                                               ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda bh, ki, lens: (bh // H, (bh % H) // group,
                                               ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D),
                               lambda bh, ki, lens: (bh // H, bh % H, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_cache, v_cache)
