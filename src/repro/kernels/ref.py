"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's tests sweep shapes/dtypes
and assert allclose against these.  They are also the default model path on
CPU and inside the multi-pod dry-run (XLA shards/fuses them well, and their
HLO FLOPs feed the roofline analysis).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, Hkv, S, D) -> (B, Hkv*n_rep, S, D) for GQA."""
    if n_rep == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, n_rep, s, d)) \
        .reshape(b, h * n_rep, s, d)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, sm_scale: Optional[float] = None,
              bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full attention.  q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    k = repeat_kv(k, H // Hkv)
    v = repeat_kv(v, H // Hkv)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if bias is not None:
        s = s + bias
    if causal:
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        ki = jnp.arange(Sk)[None, :]
        s = jnp.where(ki <= qi, s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray,
                     sm_scale: Optional[float] = None) -> jnp.ndarray:
    """One-token attention against a KV cache.

    q: (B, H, D); caches: (B, Hkv, S, D); lengths: (B,) valid prefix sizes.
    """
    B, H, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    # GQA without materializing repeated K/V: group the query heads.
    # Keeping the cache un-broadcast lets the SPMD partitioner keep its
    # sequence sharding (flash-decoding: partial softmax + tiny
    # all-reduces) instead of replicating the cache.
    rep = H // Hkv
    qg = q.reshape(B, Hkv, rep, D)
    # dot in the cache dtype (MXU accumulates f32 internally); upcasting
    # the operands instead would materialize an f32 copy of the WHOLE
    # cache — scores are tiny, casting them is free
    s = jnp.einsum("bgrd,bgsd->bgrs", qg,
                   k_cache).astype(jnp.float32) * scale
    mask = jnp.arange(S)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(mask, s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrs,bgsd->bgrd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, D)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, chunk: int = 64,
             initial_state: Optional[jnp.ndarray] = None,
             return_state: bool = False):
    """Mamba-2 SSD (state-space duality) reference, chunked formulation.

    x:  (b, s, h, p)   inputs (already conv'd/activated)
    dt: (b, s, h)      positive step sizes (post softplus)
    A:  (h,)           negative state decay rates
    B:  (b, s, g, n)   input projections (g groups broadcast over h)
    C:  (b, s, g, n)   output projections
    Returns y: (b, s, h, p) [and final state (b, h, p, n)].

    Semantics: h_t = exp(dt_t*A) * h_{t-1} + dt_t * B_t x_t ; y_t = C_t h_t.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2) if rep > 1 else B  # (b, s, h, n)
    Ch = jnp.repeat(C, rep, axis=2) if rep > 1 else C

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bh.reshape(b, nc, chunk, h, n)
    Cc = Ch.reshape(b, nc, chunk, h, n)

    dA = dtc * A[None, None, None, :]              # (b, nc, L, h), negative
    dA_cs = jnp.cumsum(dA, axis=2)                 # inclusive cumsum
    # intra-chunk: y_intra[i] = sum_{j<=i} C_i . B_j x_j dt_j exp(cs_i-cs_j)
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (b,nc,i,j,h)
    iidx = jnp.arange(chunk)
    causal = iidx[:, None] >= iidx[None, :]
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)
    y_intra = jnp.einsum("bcijh,bcijh,bcjh,bcjhp->bcihp", cb, L, dtc, xc)

    # chunk-final states: S_c = sum_j exp(cs_L - cs_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # (b,nc,L,h)
    states = jnp.einsum("bcjh,bcjh,bcjhn,bcjhp->bchpn",
                        decay_to_end, dtc, Bc, xc)

    # inter-chunk recurrence over c: S'_c = G_c S'_{c-1} + states_c
    G = jnp.exp(dA_cs[:, :, -1, :])                          # (b, nc, h)

    def scan_fn(carry, inp):
        g_c, st_c = inp
        new = g_c[:, :, None, None] * carry + st_c
        return new, carry  # emit the state *entering* this chunk

    # carry the inter-chunk state in fp32 regardless of activation dtype
    init = initial_state.astype(jnp.float32) if initial_state is not None \
        else jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(G, 1, 0).astype(jnp.float32),
         jnp.moveaxis(states, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (b,nc,h,p,n)

    # inter-chunk contribution: y_inter[i] = C_i exp(cs_i) S_prev
    decay_from_start = jnp.exp(dA_cs)                        # (b,nc,L,h)
    y_inter = jnp.einsum("bcihn,bcih,bchpn->bcihp",
                         Cc, decay_from_start, prev_states)

    y = (y_intra + y_inter).reshape(b, s, h, p).astype(x.dtype)
    if return_state:
        return y, final
    return y


def ssd_decode_step(state: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
                    A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSD recurrence.  state: (b,h,p,n); x_t: (b,h,p);
    dt_t: (b,h); B_t, C_t: (b,g,n).  Returns (y_t, new_state)."""
    b, h, p = x_t.shape
    g = B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1) if rep > 1 else B_t   # (b,h,n)
    Ch = jnp.repeat(C_t, rep, axis=1) if rep > 1 else C_t
    dA = jnp.exp(dt_t * A[None, :])                         # (b,h)
    new = dA[:, :, None, None] * state + \
        (dt_t[:, :, None] * x_t)[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new, Ch)
    return y, new
