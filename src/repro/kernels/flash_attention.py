"""Flash attention forward as a Pallas TPU kernel.

TPU adaptation notes (DESIGN.md §2): the grid is ``(batch*heads, q_blocks,
k_blocks)`` with the KV axis innermost — TPU grids execute sequentially, so
the running softmax state (row max ``m``, normalizer ``l``, accumulator)
lives in VMEM scratch that persists across the k-block steps of one q block.
Block shapes default to MXU-aligned 128×128 tiles; ``(block_q, head_dim)``
and ``(block_k, head_dim)`` tiles are the VMEM working set, so
``vmem_bytes ≈ (bq + 2*bk) * D * bytes + bq*D*4`` — block sizes are chosen to
keep this under ~4 MB while filling the 128×128 MXU.

GQA is handled in the BlockSpec index maps (query head h reads kv head
``h // (H // Hkv)``) — no materialized ``repeat_kv``.

Causal masking supports ``Sq != Sk`` (the query block is aligned to the tail
of the key sequence, as in incremental prefill).  With ``causal=True`` fully
masked k-blocks are *skipped* via ``pl.when`` — they still occupy grid steps
but issue no MXU work (the grid-pruning variant is a recorded §Perf item).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               sm_scale: float, causal: bool, block_q: int, block_k: int,
               n_kb: int, sq: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_off = qi * block_q + (sk - sq)          # causal alignment offset
    k_off = ki * block_k

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = q_off + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
            cols = k_off + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip k blocks entirely above the diagonal of this q block
        block_needed = k_off <= q_off + block_q - 1
        pl.when(block_needed)(compute)
    else:
        compute()

    @pl.when(ki == n_kb - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "sm_scale", "block_q", "block_k",
                              "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, sm_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_qb, n_kb = Sq // block_q, Sk // block_k
    scale = float(sm_scale) if sm_scale is not None else 1.0 / float(np.sqrt(D))

    kernel = functools.partial(
        _fa_kernel, sm_scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_kb=n_kb, sq=Sq, sk=Sk)

    return pl.pallas_call(
        kernel,
        grid=(B * H, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda bh, qi, ki: (bh // H, (bh % H) // group,
                                             ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda bh, qi, ki: (bh // H, (bh % H) // group,
                                             ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
