"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD insight (Dao & Gu, 2024) maps the selective-SSM recurrence onto
matmuls: within a chunk of length L the output is a masked (semiseparable)
attention-like product — MXU work — while the recurrent state only crosses
chunk boundaries.  TPU adaptation: grid ``(B, H, n_chunks)`` with the chunk
axis innermost; the inter-chunk state ``(N, P)`` lives in VMEM scratch and
persists across sequential grid steps, so the recurrence costs no HBM
traffic.  VMEM working set per step:
``L*P + 2*L*N + L + L*L + N*P`` floats — with L=64..256 this tiles well
under the ~16 MB VMEM budget while the (L,L) and (L,P) products fill the MXU.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref,
                state_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    A = a_ref[0].astype(jnp.float32)                 # ()
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # (L, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # (L, N)

    dA = dt * A                                      # (L,) negative
    cs = jnp.cumsum(dA)                              # (L,)

    # intra-chunk (semiseparable "attention"):
    seg = cs[:, None] - cs[None, :]                  # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lm = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    w = cb * Lm * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, P)

    # inter-chunk: contribution of the state entering this chunk
    state = state_scr[...]                            # (N, P)
    cstate = jax.lax.dot_general(Cm, state, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = y + jnp.exp(cs)[:, None] * cstate

    # state update: S' = exp(cs_L) S + B^T diag(dt * exp(cs_L - cs)) x
    decay_in = dt * jnp.exp(cs[-1] - cs)              # (L,)
    bx = jax.lax.dot_general(Bm, decay_in[:, None] * x,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    state_scr[...] = jnp.exp(cs[-1]) * state + bx

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        st_ref[0, 0, :, :] = state_scr[...].T.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, chunk: int = 64,
             interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Shapes as in :func:`repro.kernels.ref.ssd_scan`.

    Returns (y, final_state) with y: (b, s, h, p), state: (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, st
