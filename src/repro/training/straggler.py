"""Straggler monitoring and elastic re-mesh planning.

At 1000+ nodes the common failures are (a) a host that dies (handled by
checkpoint/restart) and (b) a host that runs slow — a straggler that
silently caps the whole synchronous step.  The monitor keeps an online
median/deviation of step times, flags persistent outliers, and the
elastic planner recomputes a (pod, data, model) factorization for the
surviving host count so the job restarts from the last checkpoint on a
smaller-but-healthy mesh (checkpoints are mesh-independent by design).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class StragglerConfig:
    window: int = 20              # step-time history window
    slow_factor: float = 1.5      # flagged when > factor x median
    persist_steps: int = 5        # consecutive flags before reporting


class StragglerMonitor:
    """Feed per-host step durations; yields persistent stragglers."""

    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self._times: Dict[str, List[float]] = {}
        self._flags: Dict[str, int] = {}

    def record(self, host: str, seconds: float) -> None:
        h = self._times.setdefault(host, [])
        h.append(seconds)
        if len(h) > self.cfg.window:
            h.pop(0)

    def _median_all(self) -> float:
        allt = sorted(t for h in self._times.values() for t in h)
        return allt[len(allt) // 2] if allt else 0.0

    def check(self) -> List[str]:
        """Update flags; return hosts flagged persistently slow."""
        med = self._median_all()
        out = []
        for host, h in self._times.items():
            if not h:
                continue
            if med > 0 and h[-1] > self.cfg.slow_factor * med:
                self._flags[host] = self._flags.get(host, 0) + 1
            else:
                self._flags[host] = 0
            if self._flags[host] >= self.cfg.persist_steps:
                out.append(host)
        return out

    def forget(self, host: str) -> None:
        """Drop a host's history and flags — used when the host leaves
        the mesh (dead replica) or finishes draining and rejoins healthy
        (its stale slow samples must not re-flag it instantly)."""
        self._times.pop(host, None)
        self._flags.pop(host, None)


def plan_elastic_mesh(n_healthy_chips: int, model_axis: int = 16,
                      chips_per_pod: int = 256) -> Optional[Tuple]:
    """Largest (pod, data, model) mesh that fits the healthy chips,
    keeping the model axis fixed (param shardings stay valid) and the
    data axis a power of two (batch divisibility).

    Returns (pods, data, model) or None when no viable mesh remains."""
    if n_healthy_chips < model_axis:
        return None
    pods = max(1, n_healthy_chips // chips_per_pod)
    while pods >= 1:
        per_pod = n_healthy_chips // pods
        data = per_pod // model_axis
        # round data down to a power of two
        p2 = 1
        while p2 * 2 <= data:
            p2 *= 2
        if p2 >= 1 and pods * p2 * model_axis <= n_healthy_chips and p2 > 0:
            return (pods, p2, model_axis)
        pods -= 1
    return None
