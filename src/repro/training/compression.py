"""Error-feedback int8 gradient compression (distributed-optimization
trick for the DP all-reduce).

Each tensor is quantized to int8 with a per-tensor scale before crossing
the data-parallel axis; the quantization residual is kept locally and
added back into the next step's gradient (error feedback), which keeps
SGD/Adam convergence unbiased in the long run.  8x less DP traffic for
<1% noise per step once feedback has warmed up.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """float grad -> (int8 payload, f32 scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, feedback):
    """Apply error feedback, quantize, and return (quantized tree,
    new feedback tree).  The quantized tree (a (payload, scale) pair per
    leaf, same treedef) is what crosses the wire."""
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(feedback)
    qs, fbs = [], []
    for g, e in zip(g_leaves, e_leaves):
        g_corr = g.astype(jnp.float32) + e
        q, s = compress(g_corr)
        qs.append((q, s))
        fbs.append(g_corr - decompress(q, s))
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, fbs))


def decompress_grads(qtree):
    qs, ss = _split(qtree)
    return jax.tree.map(decompress, qs, ss)


def _split(qtree):
    leaves, treedef = jax.tree.flatten(
        qtree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and hasattr(x[0], "dtype"))
    qs = jax.tree.unflatten(treedef, [t[0] for t in leaves])
    ss = jax.tree.unflatten(treedef, [t[1] for t in leaves])
    return qs, ss
