"""Mesh-independent checkpointing with atomic commit.

Format: one .npz of flattened leaves + a JSON manifest carrying the tree
structure and the step.  Writes go to a temp dir and are renamed into
place (atomic on POSIX), so a failure mid-save never corrupts the latest
checkpoint — the restart simply sees the previous one.  Checkpoints store
fully-replicated numpy arrays, so a restore can target a DIFFERENT mesh
(elastic scaling: grow/shrink the data axis between runs).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save(ckpt_dir: str, state, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    step = int(state["step"])
    leaves, treedef = _flatten(state)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp-")
    try:
        arrays = {f"leaf_{i}": np.asarray(jax.device_get(x))
                  for i, x in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {"step": step, "num_leaves": len(leaves),
                    "treedef": str(treedef),
                    "dtypes": [str(a.dtype) for a in arrays.values()],
                    "shapes": [list(a.shape) for a in arrays.values()]}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, step: int, example_state=None):
    """Restore a checkpoint.  ``example_state`` (a pytree of the same
    structure, e.g. from abstract_state) provides the treedef; when None,
    the state is reconstructed against the stored structure of a freshly
    flattened template and must match leaf-count."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    if example_state is not None:
        _, treedef = jax.tree.flatten(example_state)
        return jax.tree.unflatten(treedef, leaves)
    return leaves, manifest


def restore_latest(ckpt_dir: str, example_state=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    assert example_state is not None, "restore needs a structure template"
    return restore(ckpt_dir, step, example_state)
