"""AdamW + schedules, pure JAX (no optax in this environment).

Optimizer state lives in the same pytree structure as the params, so the
param PartitionSpecs apply verbatim to ``m``/``v`` — sharded optimizer
state (ZeRO-style along existing shardings) for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # distributed-optimization tricks
    grad_dtype: str = "float32"       # "bfloat16" = compressed grad accum
    skip_nonfinite: bool = True       # drop the update on inf/nan grads


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.float32(step)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptimizerConfig, params, grads, opt_state, step):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    finite = jnp.isfinite(gnorm)
    lr = lr_schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # no weight decay on norms/biases/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        if cfg.skip_nonfinite:
            p_new = jnp.where(finite, p_new, p.astype(jnp.float32))
            m_new = jnp.where(finite, m_new, m)
            v_new = jnp.where(finite, v_new, v)
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr,
               "nonfinite": (~finite).astype(jnp.float32)}
    return new_params, {"m": new_m, "v": new_v}, metrics
