from .optimizer import OptimizerConfig, adamw_update, init_opt_state, \
    lr_schedule, global_norm
from .trainer import (TrainConfig, Trainer, make_train_step, init_state,
                      abstract_state, state_shardings, batch_pspec)
from . import checkpoint
