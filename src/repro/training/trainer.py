"""Distributed train step + training loop with fault tolerance.

The step is a single pjit'd function: microbatched grad accumulation
(``lax.scan`` over microbatches, optionally accumulating in bf16 — the
gradient-compression trick), AdamW, and metric reduction.  Sharding comes
exclusively from the logical-rule table; the same step function lowers for
1 CPU device or the 512-chip production mesh.

Fault tolerance: the loop checkpoints every N steps (atomic rename),
restores on restart (elastic: checkpoints are mesh-independent), and an
injectable failure hook in the loop exercises the restart path in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules
from repro.models import (ModelConfig, init_params, abstract_params,
                          loss_fn, model_defs)
from repro.models import params as PP
from .optimizer import (OptimizerConfig, adamw_update, init_opt_state)
from . import checkpoint as ckpt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    num_microbatches: int = 1
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 10
    opt: OptimizerConfig = OptimizerConfig()


def batch_pspec(cfg: ModelConfig, rules: ShardingRules) -> Dict[str, P]:
    out = {"tokens": rules.spec("batch", None),
           "targets": rules.spec("batch", None)}
    if cfg.family == "vlm":
        out["img_embeds"] = rules.spec("batch", None, None)
    if cfg.family == "encdec":
        out["frames"] = rules.spec("batch", None, None)
    return out


def make_train_step(cfg: ModelConfig, rules: ShardingRules,
                    tcfg: TrainConfig):
    """Returns step(state, batch) -> (state, metrics); pure, jit-able."""
    ocfg = tcfg.opt
    nmb = tcfg.num_microbatches

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, rules), has_aux=True)(params)
        return loss, metrics, grads

    def step(state, batch):
        params = state["params"]
        if nmb == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            # microbatch accumulation: reshape leading batch dim to
            # (nmb, B/nmb, ...) and scan, accumulating in grad_dtype
            # (bf16 accumulation halves the grad-buffer memory + any
            # cross-slice reduce traffic = gradient compression).
            gdt = jnp.dtype(ocfg.grad_dtype)
            mb = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]),
                batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params)

            def mb_step(carry, mbatch):
                acc, loss_sum, aux_sum = carry
                loss, metrics, grads = grads_of(params, mbatch)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(gdt), acc, grads)
                return (acc, loss_sum + loss, aux_sum + metrics["aux"]), None

            (grads, loss, aux), _ = jax.lax.scan(
                mb_step, (acc0, 0.0, 0.0), mb)
            grads = jax.tree.map(lambda g: (g / nmb).astype(jnp.float32),
                                 grads)
            loss = loss / nmb
            metrics = {"ce": loss, "aux": aux / nmb,
                       "ppl": jnp.exp(jnp.clip(loss, a_max=20.0))}

        new_params, new_opt, opt_metrics = adamw_update(
            ocfg, params, grads, state["opt"], state["step"])
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return step


def state_shardings(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules):
    defs = model_defs(cfg)
    pshard = PP.param_shardings(defs, mesh, rules)
    return {"params": pshard,
            "opt": {"m": pshard, "v": pshard},
            "step": NamedSharding(mesh, P())}


def init_state(cfg: ModelConfig, key):
    params = init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(cfg: ModelConfig):
    params = abstract_params(cfg)
    zeros = lambda tree: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)
    return {"params": params,
            "opt": {"m": zeros(params), "v": zeros(params)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


class Trainer:
    """Orchestrates the jitted step + checkpoint/restore + failure
    recovery.  On CPU this drives real (small) training; on a cluster the
    same object drives the production mesh."""

    def __init__(self, cfg: ModelConfig, rules: ShardingRules,
                 tcfg: TrainConfig, mesh: Optional[Mesh] = None):
        self.cfg, self.rules, self.tcfg = cfg, rules, tcfg
        self.mesh = mesh
        step = make_train_step(cfg, rules, tcfg)
        if mesh is not None:
            shardings = state_shardings(cfg, mesh, rules)
            bspec = batch_pspec(cfg, rules)
            bshard = {k: NamedSharding(mesh, v) for k, v in bspec.items()}
            self.step_fn = jax.jit(
                step, in_shardings=(shardings, bshard),
                out_shardings=(shardings, None),
                donate_argnums=(0,))
        else:
            self.step_fn = jax.jit(step, donate_argnums=(0,))
        self.state = None

    def init(self, seed: int = 0):
        restored = None
        if self.tcfg.ckpt_dir:
            restored = ckpt.restore_latest(self.tcfg.ckpt_dir,
                                           abstract_state(self.cfg))
        if restored is not None:
            self.state = restored
        else:
            self.state = init_state(self.cfg, jax.random.PRNGKey(seed))
        return int(self.state["step"])

    def run(self, data_iter, num_steps: int,
            failure_hook: Optional[Callable[[int], None]] = None):
        """Train for num_steps batches.  ``failure_hook(step)`` may raise
        to simulate a node failure; the caller restarts via ``init()``."""
        assert self.state is not None, "call init() first"
        history = []
        for _ in range(num_steps):
            batch = next(data_iter)
            step_no = int(self.state["step"])
            if failure_hook is not None:
                failure_hook(step_no)
            self.state, metrics = self.step_fn(self.state, batch)
            if self.tcfg.ckpt_dir and \
                    (step_no + 1) % self.tcfg.ckpt_every == 0:
                ckpt.save(self.tcfg.ckpt_dir, self.state,
                          keep=self.tcfg.keep_ckpts)
            if (step_no + 1) % self.tcfg.log_every == 0 or not history:
                history.append({k: float(v) for k, v in metrics.items()})
        return history
