import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, with zero real allocation (ShapeDtypeStruct inputs).

The two lines above MUST precede any jax import: jax locks the device
count at first init, and the dry-run needs 512 placeholder host devices
to build the 16x16 / 2x16x16 production meshes.  Smoke tests and benches
import repro normally and see the single real CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k [--multi-pod] [--variant baseline] [--all]

Per cell this prints/records compiled.memory_analysis() (proves the step
fits HBM) and cost_analysis() + parsed collective bytes (feeds §Roofline).
Results land in results/dryrun/<mesh>/<variant>/<arch>__<shape>.json.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro import configs
from repro.distributed.sharding import (BASELINE_RULES, DECODE_RULES,
                                        LONG_DECODE_RULES, adapt_rules_for,
                                        logical_to_sharding)
from repro.launch.mesh import make_production_mesh, HW
from repro.launch.specs import input_specs
from repro.launch import roofline as RL
from repro.models import (ALL_SHAPES, cache_logical_axes, abstract_params,
                          shapes_for)
from repro.models import params as PP
from repro.models import model_defs
from repro.serving.steps import make_prefill_step, make_decode_step
from repro.training import (TrainConfig, make_train_step, abstract_state,
                            state_shardings, batch_pspec)


def batch_axes_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def rules_for(cfg, shape, mesh, variant: str):
    """Pick + adapt the sharding rule table for one cell."""
    if shape.kind == "decode":
        base = LONG_DECODE_RULES if shape.global_batch == 1 \
            else DECODE_RULES
    else:
        base = BASELINE_RULES
    if variant != "baseline":
        from repro.launch.variants import VARIANTS
        for v in variant.split("+"):
            if v in VARIANTS:
                base = VARIANTS[v](base, cfg, shape, mesh)
    from repro.distributed.sharding import prune_to_mesh
    base = prune_to_mesh(base, mesh)
    rules = adapt_rules_for(base, mesh, n_kv=cfg.n_kv,
                            n_experts=cfg.n_experts, n_heads=cfg.n_heads,
                            d_ff=cfg.d_ff, vocab=cfg.padded_vocab)
    if shape.global_batch % batch_axes_size(mesh) != 0 \
            and rules.batch is not None:
        rules = rules.replace(batch=("data",)
                              if shape.global_batch % mesh.shape["data"] == 0
                              else None)
    return rules


def lower_cell(arch: str, shape, mesh, variant: str = "baseline"):
    cfg = configs.get_config(arch)
    from repro.launch.variants import CFG_OVERRIDES
    for v in variant.split("+"):
        if v in CFG_OVERRIDES:
            cfg = dataclasses.replace(cfg, **CFG_OVERRIDES[v])
    rules = rules_for(cfg, shape, mesh, variant)
    specs = input_specs(cfg, shape)
    defs = model_defs(cfg)

    with mesh:
        if shape.kind == "train":
            step = make_train_step(cfg, rules,
                                   TrainConfig(num_microbatches=1))
            st_sh = state_shardings(cfg, mesh, rules)
            b_sh = {k: NamedSharding(mesh, v)
                    for k, v in batch_pspec(cfg, rules).items()}
            # extra aux-input shardings
            for k in specs["batch"]:
                if k not in b_sh:
                    b_sh[k] = NamedSharding(mesh, rules.spec("batch", None,
                                                             None))
            jit = jax.jit(step, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None), donate_argnums=(0,))
            lowered = jit.lower(abstract_state(cfg), specs["batch"])
        else:
            fn = make_prefill_step(cfg, rules) if shape.kind == "prefill" \
                else make_decode_step(cfg, rules)
            p_sh = PP.param_shardings(defs, mesh, rules)
            cax = cache_logical_axes(cfg)
            c_sh = {k: logical_to_sharding(mesh, rules, cax[k])
                    for k in cax}
            b_sh = {}
            for k, v in specs["batch"].items():
                nlog = ("batch",) + (None,) * (len(v.shape) - 1)
                b_sh[k] = logical_to_sharding(mesh, rules, nlog)
            params_abs = abstract_params(cfg, dtype=cfg.dtype)
            jit = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh),
                          donate_argnums=(2,))
            lowered = jit.lower(params_abs, specs["batch"],
                                specs["caches"])
    return cfg, rules, lowered


def run_cell(arch: str, shape, *, multi_pod: bool = False,
             variant: str = "baseline", out_dir: str = "results/dryrun",
             verbose: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    cfg, rules, lowered = lower_cell(arch, shape, mesh, variant)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    mem_bytes = float(getattr(mem, "temp_size_in_bytes", 0)
                      + getattr(mem, "argument_size_in_bytes", 0)
                      + getattr(mem, "output_size_in_bytes", 0))
    report = RL.build_report(arch=arch, shape=shape, mesh_name=mesh_name,
                             chips=chips, cost=cost, mem_bytes=mem_bytes,
                             hlo_text=hlo, cfg=cfg)
    rec = report.to_dict()
    rec.update(variant=variant, t_lower_s=t_lower, t_compile_s=t_compile,
               argument_bytes=float(getattr(mem, "argument_size_in_bytes", 0)),
               temp_bytes=float(getattr(mem, "temp_size_in_bytes", 0)),
               output_bytes=float(getattr(mem, "output_size_in_bytes", 0)),
               hbm_fraction=mem_bytes / HW["hbm_bytes"],
               rules=str(rules))

    path = os.path.join(out_dir, mesh_name, variant)
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, f"{arch}__{shape.name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        print(f"[{mesh_name}/{variant}] {arch} x {shape.name}: "
              f"compile={t_compile:.1f}s "
              f"mem/dev={mem_bytes/2**30:.2f}GiB "
              f"t_comp={report.t_compute*1e3:.2f}ms "
              f"t_mem={report.t_memory*1e3:.2f}ms "
              f"t_coll={report.t_collective*1e3:.2f}ms "
              f"dominant={report.dominant} "
              f"roofline={report.roofline_fraction:.2%}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    shape_by_name = {s.name: s for s in ALL_SHAPES}
    cells = []
    if args.all:
        for arch in configs.ARCH_IDS:
            cfg = configs.get_config(arch)
            for s in shapes_for(cfg):
                cells.append((arch, s))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, shape_by_name[args.shape])]

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    failures = []
    for arch, s in cells:
        out_json = os.path.join(args.out, mesh_name, args.variant,
                                f"{arch}__{s.name}.json")
        if args.skip_existing and os.path.exists(out_json):
            print(f"skip {arch} x {s.name} (exists)")
            continue
        try:
            run_cell(arch, s, multi_pod=args.multi_pod,
                     variant=args.variant, out_dir=args.out)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, s.name, repr(e)[:200]))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("dry-run OK")


if __name__ == "__main__":
    main()
