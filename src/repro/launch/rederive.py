"""Recompute the derived roofline fields of stored dry-run JSONs from
their raw measurements (idempotent; used when the metric definitions
improve without recompiling 64 cells on one CPU core).

  PYTHONPATH=src python -m repro.launch.rederive [results/dryrun]
"""

from __future__ import annotations

import glob
import json
import sys

from .mesh import HW


def rederive(rec: dict) -> dict:
    ideal = rec.get("ideal_gbytes", 0.0)
    art = rec.get("cpu_artifact_gbytes", 0.0)
    hlo_adj = max(rec["hlo_gbytes"] - art, ideal, 0.0)
    t_mem_adj = hlo_adj * 1e9 / HW["hbm_bw"]
    t_comp_eff = max(rec["t_compute"],
                     rec.get("executed_gflops_per_chip", 0.0) * 1e9
                     / HW["peak_flops_bf16"])
    terms = {"compute": t_comp_eff, "memory": t_mem_adj,
             "collective": rec["t_collective"]}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    if rec.get("kind") == "decode":
        t_ideal = ideal * 1e9 / HW["hbm_bw"]
        roofline = min(1.0, t_ideal / max(t_bound, 1e-12))
    else:
        t_useful = rec["model_gflops_per_chip"] * 1e9 \
            / HW["peak_flops_bf16"]
        roofline = t_useful / max(t_bound, 1e-12)
    rec.update(hlo_gbytes_adj=hlo_adj, t_memory_adj=t_mem_adj,
               t_compute_eff=t_comp_eff, dominant=dominant,
               t_bound=t_bound, roofline_fraction=roofline,
               bw_fraction=min(1.0, ideal / max(hlo_adj, 1e-9)))
    return rec


def main(base="results/dryrun"):
    n = 0
    for f in glob.glob(f"{base}/**/*.json", recursive=True):
        rec = json.load(open(f))
        if "hlo_gbytes" not in rec:
            continue
        json.dump(rederive(rec), open(f, "w"), indent=1)
        n += 1
    print(f"rederived {n} records under {base}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
