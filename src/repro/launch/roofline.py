"""Roofline term extraction from compiled dry-run artifacts.

compute    = HLO_FLOPs   / (chips x peak_FLOP/s)
memory     = HLO_bytes   / (chips x HBM_bw)
collective = coll_bytes  / (chips x link_bw)

``cost_analysis`` supplies FLOPs / bytes; collective bytes are NOT in
cost_analysis, so we parse the post-SPMD HLO text and sum the output
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  Post-partitioning HLO is the per-device
program, so parsed quantities are per-chip already; cost_analysis is also
per-device on a partitioned module — we therefore do NOT divide by chips
again (the formulas above are kept for the whole-cluster convention and
reduce to per-chip values on the partitioned module).
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np
from typing import Dict

from .mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape literal (or a tuple of them)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_DEF_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_CONV_RE = re.compile(
    r"^\s*%[\w.\-]+\s*=\s*f32\[([0-9,]*)\]\S*\s+convert\(([^)]*)\)")


def cpu_upconvert_bytes(hlo_text: str) -> int:
    """XLA's CPU backend cannot execute bf16 dots natively: it inserts
    convert(bf16->f32) on dot/fusion operands, materializing f32 copies
    of weights/caches that would NOT exist on the TPU target (Mosaic/MXU
    consume bf16 directly).  Two-pass parse: map value names to dtypes,
    then sum the f32 output bytes of every convert whose operand is bf16
    (written once, read once -> x2 traffic), so the memory term can be
    reported with and without this compile-target artifact."""
    dtype_of = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            dtype_of[m.group(1)] = m.group(2)
    total = 0
    for line in hlo_text.splitlines():
        m = _CONV_RE.match(line.rstrip())
        if not m:
            continue
        operand = m.group(2).strip()
        # operand is either "bf16[...] %name" or just "%name"
        src_dt = None
        if operand.startswith("%"):
            src_dt = dtype_of.get(operand.split()[0].rstrip(","))
        else:
            src_dt = operand.split("[")[0]
        if src_dt != "bf16":
            continue
        dims = m.group(1)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * 4 * 2
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind over the (per-device) HLO."""
    out = {k: 0 for k in COLLECTIVES}
    count = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g.  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %p), ...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)"
                     r"\s+([a-z\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        # normalize fused/start variants: all-gather-start, all-reduce-done
        base = None
        for k in COLLECTIVES:
            if op == k or op.startswith(k + "-start"):
                base = k
                break
        if base is None:
            continue
        out[base] += _shape_bytes(m.group(1))
        count[base] += 1
    return {"bytes": out, "count": count,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float             # per-chip
    hlo_gbytes: float             # per-chip
    coll_gbytes: float            # per-chip
    t_compute: float              # seconds
    t_memory: float
    t_collective: float
    model_gflops_per_chip: float  # 6ND useful flops, per chip per step
    bytes_per_device: float       # from memory_analysis (peak allocation)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    coll_bytes_by_kind: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    kind: str = "train"           # train | prefill | decode
    ideal_gbytes: float = 0.0     # per-chip: params + caches + tokens once
    executed_gflops_per_chip: float = 0.0   # useful + remat re-forward
    cpu_artifact_gbytes: float = 0.0   # CPU-backend bf16->f32 dot copies

    @property
    def hlo_gbytes_adj(self) -> float:
        """HBM traffic with the CPU-only upconvert copies removed — the
        TPU-target estimate, floored at the irreducible bytes (the
        artifact estimate double-counts when converts fuse)."""
        return max(self.hlo_gbytes - self.cpu_artifact_gbytes,
                   self.ideal_gbytes, 0.0)

    @property
    def t_memory_adj(self) -> float:
        return self.hlo_gbytes_adj * 1e9 / HW["hbm_bw"]

    @property
    def t_compute_eff(self) -> float:
        """XLA's cost_analysis counts a while-loop body ONCE, so HLO FLOPs
        undercount scanned-layer models by ~n_layers.  The analytic
        EXECUTED-flops estimate (useful + remat re-forward + attention/SSD
        terms) repairs the term: t_compute = max(HLO, EXECUTED)/peak."""
        t_model = self.executed_gflops_per_chip * 1e9 \
            / HW["peak_flops_bf16"]
        return max(self.t_compute, t_model)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute_eff, "memory": self.t_memory_adj,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute_eff, self.t_memory_adj,
                   self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_gflops_per_chip / max(self.hlo_gflops, 1e-9)

    @property
    def bw_fraction(self) -> float:
        """Fraction of HBM traffic that is irreducible (params + caches +
        tokens read exactly once).  The efficiency metric for memory-bound
        kinds (decode)."""
        return min(1.0, self.ideal_gbytes / max(self.hlo_gbytes_adj, 1e-9))

    @property
    def roofline_fraction(self) -> float:
        """Compute-bound kinds (train/prefill): useful-compute time over
        the binding-resource time.  Memory-bound kinds (decode): fraction
        of the irreducible HBM traffic — the step is at roofline when it
        moves only the bytes it must."""
        if self.kind == "decode":
            t_ideal = self.ideal_gbytes * 1e9 / HW["hbm_bw"]
            return min(1.0, t_ideal / max(self.t_bound, 1e-12))
        t_useful = self.model_gflops_per_chip * 1e9 / HW["peak_flops_bf16"]
        return t_useful / max(self.t_bound, 1e-12)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant,
                 useful_flop_ratio=self.useful_flop_ratio,
                 roofline_fraction=self.roofline_fraction,
                 bw_fraction=self.bw_fraction,
                 t_compute_eff=self.t_compute_eff,
                 t_memory_adj=self.t_memory_adj,
                 hlo_gbytes_adj=self.hlo_gbytes_adj,
                 t_bound=self.t_bound)
        return d


@dataclasses.dataclass
class KernelRoofline:
    """Roofline verdict for ONE DSL suite kernel on ONE compiled target.

    The HLO path above prices a whole training/serving step against
    *datasheet* peaks (``mesh.HW``); a DSL kernel runs through the
    repro.core compiler stack on the host, where datasheet numbers are
    meaningless.  This entry point instead takes *measured* per-target
    peaks — calibrated by DSL microkernels (an FMA chain for FLOP/s, a
    streaming copy for bandwidth, repro.suite.scoreboard.calibrate) so
    the numerator and the denominator go through the same compiler,
    runtime and launch overheads.  ``t_bound`` is the classic two-term
    roofline bound; ``fraction`` is achieved-vs-roofline, the Rupp-style
    performance-portability metric the scoreboard reports per cell.
    """
    kernel: str
    target: str
    flops: float          # analytic FLOPs executed by one launch
    bytes_moved: float    # analytic bytes moved by one launch
    time_s: float         # measured wall time of one launch
    peak_flops: float     # measured per-target peak, FLOP/s
    peak_bw: float        # measured per-target peak, B/s

    @property
    def t_compute(self) -> float:
        return self.flops / max(self.peak_flops, 1e-9)

    @property
    def t_memory(self) -> float:
        return self.bytes_moved / max(self.peak_bw, 1e-9)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory)

    @property
    def dominant(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"

    @property
    def achieved_gflops(self) -> float:
        return self.flops / max(self.time_s, 1e-12) / 1e9

    @property
    def achieved_gbs(self) -> float:
        return self.bytes_moved / max(self.time_s, 1e-12) / 1e9

    @property
    def fraction(self) -> float:
        """Achieved-vs-roofline: the fraction of the binding resource's
        bound this launch actually reached (1.0 = at the roofline).  Not
        clamped — a value > 1 flags a mis-calibrated peak or timing
        noise, which the scoreboard should surface, not hide."""
        return self.t_bound / max(self.time_s, 1e-12)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_bound=self.t_bound, dominant=self.dominant,
                 achieved_gflops=self.achieved_gflops,
                 achieved_gbs=self.achieved_gbs,
                 fraction=self.fraction)
        return d


def kernel_report(*, kernel: str, target: str, flops: float,
                  bytes_moved: float, time_s: float, peak_flops: float,
                  peak_bw: float) -> KernelRoofline:
    """Build a :class:`KernelRoofline` for one (suite kernel, target)
    measurement.  All quantities must be positive and finite; a bad
    measurement raises rather than producing a silently-wrong fraction."""
    vals = {"flops": flops, "bytes_moved": bytes_moved, "time_s": time_s,
            "peak_flops": peak_flops, "peak_bw": peak_bw}
    for name, v in vals.items():
        if not (isinstance(v, (int, float)) and np.isfinite(v) and v > 0):
            raise ValueError(f"kernel_report: {name} must be a positive "
                             f"finite number, got {v!r}")
    return KernelRoofline(kernel=kernel, target=target, flops=float(flops),
                          bytes_moved=float(bytes_moved),
                          time_s=float(time_s),
                          peak_flops=float(peak_flops),
                          peak_bw=float(peak_bw))


def model_flops(cfg, shape, n_params_active: int, mode: str) -> float:
    """USEFUL model FLOPs per step: 6·N·D train / 2·N·D inference, plus
    the attention (and SSD) FLOPs that 6ND does not count.  ``mode``
    overrides the shape kind (the remat re-forward is a prefill-shaped
    pass over the train shape)."""
    if mode == "decode":
        tokens = shape.global_batch
        mult = 2.0
    else:
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0 if mode == "train" else 2.0
    base = mult * n_params_active * tokens
    return base + _mixer_flops(cfg, shape, mode)


def _mixer_flops(cfg, shape, mode) -> float:
    """Attention score/value + SSD flops (not captured by 6ND)."""
    B, S = shape.global_batch, shape.seq_len
    fb = 3.0 if mode == "train" else 1.0    # fwd(+bwd=2x)
    H, hd = cfg.n_heads, cfg.hd
    total = 0.0
    if mode == "decode":
        # one query token against the full cache
        att_layers = _attn_layers(cfg)
        total += att_layers * 4.0 * B * S * H * hd
        return total
    att_layers = _attn_layers(cfg)
    # causal self-attention: 2 matmuls x 2 flops x half the S^2 triangle
    total += att_layers * 2.0 * B * S * S * H * hd * fb
    if cfg.family == "vlm":
        ncross = cfg.n_layers // cfg.cross_attn_every
        total += ncross * 4.0 * B * S * cfg.n_img_tokens * H * hd * fb
    if cfg.family == "encdec":
        total += cfg.enc_layers * 4.0 * B * cfg.enc_seq ** 2 * H * hd * fb
        total += cfg.n_layers * 4.0 * B * S * cfg.enc_seq * H * hd * fb
    if cfg.family in ("ssm", "hybrid"):
        Hs, P, N, ch = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                        cfg.ssm_chunk)
        # per token per layer: intra-chunk (~2·ch·(N+P)) + states (~4·P·N)
        per_tok = 2.0 * ch * (N + P) + 4.0 * P * N
        total += cfg.n_layers * B * S * Hs * per_tok * fb
    return total


def _attn_layers(cfg) -> int:
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        return cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return 0


def executed_flops(cfg, shape, n_params_active: int) -> float:
    """EXECUTED compute: useful flops plus the remat re-forward (block
    remat recomputes the forward during backward: +2ND on top of 6ND)."""
    useful = model_flops(cfg, shape, n_params_active, shape.kind)
    if shape.kind == "train" and cfg.remat in ("block", "full"):
        refwd = model_flops(cfg, shape, n_params_active, "prefill")
        return useful + refwd
    return useful   # remat="dots" recomputes no matmuls


def active_params(cfg) -> int:
    """Parameter count with only top-k experts active (MoE)."""
    from repro.models import model_defs
    from repro.models.params import ParamDef
    import jax
    import numpy as np

    defs = model_defs(cfg)
    total = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    for path, d in flat:
        n = int(np.prod(d.shape))
        keys = [getattr(k, "key", str(k)) for k in path]
        if cfg.family == "moe" and any(k in ("w_up", "w_gate", "w_down")
                                       for k in keys) \
                and "ffn" in keys:
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


def ideal_bytes(cfg, shape, chips: int) -> float:
    """Irreducible per-chip HBM traffic for one step: every (active)
    parameter byte once (bf16 for serve, bf16 weights + f32 opt update
    traffic for train), plus KV/state caches read+written once (decode),
    plus the token activations once."""
    from repro.models import model_defs, init_caches
    from repro.models.params import count_params
    import jax

    n = count_params(model_defs(cfg))
    B, S = shape.global_batch, shape.seq_len
    act = B * S * cfg.d_model * 2 if shape.kind != "decode" \
        else B * cfg.d_model * 2
    if shape.kind == "train":
        # fwd read (bf16 cast) + bwd read + grad write + opt read/write f32
        pbytes = n * (2 + 2 + 4 + 3 * 4)
        return (pbytes + 4 * act) / chips
    pbytes = n * 2
    cbytes = 0
    if shape.kind == "decode":
        caches = init_caches(cfg, B, S, abstract=True)
        cbytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                     for x in jax.tree.leaves(caches))
    return (pbytes + cbytes + act) / chips


def build_report(*, arch: str, shape, mesh_name: str, chips: int,
                 cost: Dict, mem_bytes: float, hlo_text: str,
                 cfg) -> RooflineReport:
    coll = collective_bytes(hlo_text)
    artifact = cpu_upconvert_bytes(hlo_text)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    na = active_params(cfg)
    mf = model_flops(cfg, shape, na, shape.kind) / chips
    ef = executed_flops(cfg, shape, na) / chips
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=byts / 1e9,
        coll_gbytes=coll["total_bytes"] / 1e9,
        t_compute=flops / HW["peak_flops_bf16"],
        t_memory=byts / HW["hbm_bw"],
        t_collective=coll["total_bytes"] / HW["ici_link_bw"],
        model_gflops_per_chip=mf / 1e9,
        executed_gflops_per_chip=ef / 1e9,
        bytes_per_device=mem_bytes,
        kind=shape.kind,
        ideal_gbytes=ideal_bytes(cfg, shape, chips) / 1e9,
        cpu_artifact_gbytes=artifact / 1e9,
        coll_counts=coll["count"], coll_bytes_by_kind=coll["bytes"])
