"""Named sharding-rule variants for the §Perf hillclimb.

Each entry maps (base_rules, cfg, shape, mesh) -> ShardingRules.  The
baseline is paper-faithful 2D DP x TP; variants are the beyond-paper
optimizations and are recorded separately in EXPERIMENTS.md §Perf.

``KERNEL_VARIANTS`` is the kernel-compiler analogue: named compile policies
(target pinning + cache policy) used by ``benchmarks/bench_cache.py`` and
the serving steady-state measurements (docs/caching.md).
"""

VARIANTS = {}

# kernel-compiler execution variants: how compile_kernel is invoked per
# launch.  "uncached" is the seed behaviour (full pipeline per enqueue);
# "cached" is the steady-state hash-lookup path; "autotuned" additionally
# lets the tuning table choose the target per kernel shape.
KERNEL_VARIANTS = {
    "uncached": {"target": "vector", "cache": False},
    "cached": {"target": "vector", "cache": True},
    "cached_loop": {"target": "loop", "cache": True},
    "cached_pallas": {"target": "pallas", "cache": True},
    "autotuned": {"target": "auto", "cache": True},
}


def kernel_variant(name: str) -> dict:
    """Resolve a named kernel-compile policy to compile_kernel kwargs."""
    return dict(KERNEL_VARIANTS[name])


def variant(name):
    def deco(fn):
        VARIANTS[name] = fn
        return fn
    return deco


@variant("h1_cache_layout")
def h1_cache_layout(base, cfg, shape, mesh):
    """H1 iter 2: (B,KV,S,D)-native KV cache (code change; rules equal
    to baseline — the variant exists to record the measurement)."""
    return base


@variant("no_fsdp")
def no_fsdp(base, cfg, shape, mesh):
    """H2: drop FSDP weight sharding (kills per-layer weight all-gathers;
    viable when params*3*4B fit per model-rank)."""
    return base.replace(embed_fsdp=None)


@variant("no_sp")
def no_sp(base, cfg, shape, mesh):
    """Ablation: no sequence-parallel residuals (the pre-SP baseline)."""
    return base.replace(act_seq=None)


@variant("moe_data_dispatch")
def moe_data_dispatch(base, cfg, shape, mesh):
    """H3: experts sharded over the DATA axis instead of model (a2a moves
    to the data axis; model axis keeps pure TP)."""
    return base.replace(experts="data", expert_mlp="model")


@variant("ctl_f32")
def ctl_f32(base, cfg, shape, mesh):
    """Control: all-f32 lowering (no CPU bf16-dot upconversion) — proves
    how much of the memory term is compile-target artifact."""
    return base


@variant("moe_token_parallel")
def moe_token_parallel(base, cfg, shape, mesh):
    """H2: token/capacity-parallel MoE.  Experts replicate on the model
    axis (FSDP over data keeps memory flat); the dispatch capacity dim
    shards over model.  No sharded contraction appears in the expert-FFN
    backward, killing the per-layer (E,G,C,d) dxin all-reduce that
    dominates the TP-of-experts fallback when n_experts % model != 0."""
    return base.replace(experts=None, expert_mlp=None,
                        moe_capacity="model")


# config-level overrides applied per variant name (composable via '+')
CFG_OVERRIDES = {
    "ctl_f32": {"dtype": "float32"},
    "remat_dots": {"remat": "dots"},
    "stream_ce": {"use_streaming_ce": True},
}


@variant("remat_dots")
def remat_dots(base, cfg, shape, mesh):
    """Selective remat: save dot outputs, recompute elementwise — trades
    activation memory for the 2ND re-forward FLOPs (75% -> ~100% of the
    compute roofline when memory allows)."""
    return base


@variant("moe_tp_fallback")
def moe_tp_fallback(base, cfg, shape, mesh):
    """The paper-faithful fallback for n_experts % model != 0: per-expert
    FFN tensor parallelism (kept for the §Perf H2 record)."""
    return base.replace(experts="model", expert_mlp="model",
                        moe_capacity=None)


@variant("stream_ce")
def stream_ce(base, cfg, shape, mesh):
    """Fused vocab-chunked cross-entropy: no (B,S,V) logits buffer."""
    return base
