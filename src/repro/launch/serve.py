"""Continuous-batching serving driver (smoke-scale on CPU, production
mesh on TPU).

Requests are submitted into the engine's admission queue on a staggered
arrival schedule and the driver pumps ``step()`` until the queue drains —
the submit()/step() loop a real serving front-end runs, exercising
per-step slot refill and paged KV instead of one-shot batch generate.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.distributed.sharding import BASELINE_RULES
from repro.models import init_params
from repro.runtime import Context
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", choices=["continuous", "fixed"],
                    default="continuous")
    ap.add_argument("--arrival-every", type=int, default=1,
                    help="submit one request every N scheduler steps")
    ap.add_argument("--trace", metavar="OUT.JSON", default=None,
                    help="export the run's event DAG as Chrome-trace "
                         "JSON (open in chrome://tracing, docs/mesh.md)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    aux = {}
    if cfg.family == "vlm":
        aux["img_embeds"] = np.asarray(rng.standard_normal(
            (args.batch_slots, cfg.n_img_tokens, cfg.d_model)), np.float32)
    if cfg.family == "encdec":
        aux["frames"] = np.asarray(rng.standard_normal(
            (args.batch_slots, cfg.enc_seq, cfg.d_model)), np.float32)

    # the engine's dispatch queue and KV page pool come from a host
    # Context (docs/host_api.md) — the same object model kernel launches
    # and co-execution use
    ctx = Context()
    eng = ServingEngine(cfg, params, BASELINE_RULES,
                        batch_slots=args.batch_slots, max_seq=args.max_seq,
                        aux_inputs=aux, context=ctx,
                        scheduler=args.scheduler)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(4, 17),
                                        dtype=np.int64).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, args.max_new + 1)))
            for _ in range(args.requests)]

    t0 = time.time()
    done = []
    pending = list(reqs)
    # staggered arrivals: one request every --arrival-every steps, then
    # pump the scheduler until the queue drains — optionally recording
    # every DAG command (plus a kv_pages_live counter track) as a
    # Chrome trace
    with ctx.trace() as tr:
        while pending or eng.scheduler_stats["waiting"] or \
                eng.scheduler_stats["running"]:
            if pending and eng.current_step % max(1, args.arrival_every) == 0:
                eng.submit(pending.pop(0))
            done.extend(eng.step())
            if args.trace:
                tr.counter("kv_pages_live", eng.kv_stats["pages_live"],
                           process="serve")
    dt = time.time() - t0
    if args.trace:
        doc = tr.export(args.trace)
        print(f"trace: {len(doc['traceEvents'])} events -> {args.trace} "
              f"(load in chrome://tracing)")

    total_toks = sum(len(r.out_tokens) for r in done if r.done)
    print(f"served {len(done)} requests, {total_toks} tokens "
          f"in {dt:.2f}s ({total_toks / max(dt, 1e-9):.1f} tok/s)")
    sched = eng.scheduler_stats
    print(f"  sched: {sched['steps']} steps, {sched['evictions']} "
          f"evictions, {sched['preemptions']} preemptions")
    dag = eng.dag_stats
    if dag["steps"]:
        print(f"  dag: {dag['events']} events over {dag['steps']} steps, "
              f"overlap {dag['overlap']:.2f}x")
    kv = eng.kv_stats
    print(f"  kv pool: {kv['hits']} hits / {kv['misses']} misses, "
          f"{kv['page_bytes']} B/page x {kv['pages_live']} live, "
          f"{kv['frees']} frees (context pools: {list(ctx.pool_stats())})")
    for i, r in enumerate(done):
        tag = "FAILED " + type(r.error).__name__ if r.error else \
            f"{r.out_tokens}"
        print(f"  req{r.id}: prompt[:4]={r.prompt[:4].tolist()} -> {tag}")


if __name__ == "__main__":
    main()
