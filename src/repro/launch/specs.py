"""Abstract input specs (ShapeDtypeStruct) for every (arch × shape) cell.

Same pattern the paper's runtime uses for device-agnostic buffer handles:
weak-type-correct stand-ins that can be sharded and lowered with zero
device allocation.  Modality frontends are STUBS per the assignment —
``[audio]``/``[vlm]`` cells receive precomputed frame/patch embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, ShapeConfig, init_caches


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": sds((B, S), jnp.int32),
           "targets": sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        out["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model),
                                jnp.bfloat16)
    if cfg.family == "encdec":
        out["frames"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return out


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                      ) -> Tuple[Dict[str, Any], Any]:
    """(aux/token specs, cache specs).  For ``decode`` kinds the step
    consumes one new token against a seq_len-deep cache; for ``prefill``
    the step consumes the full prompt and writes the cache."""
    B, S = shape.global_batch, shape.seq_len
    caches = init_caches(cfg, B, S, dtype=jnp.dtype(cfg.dtype),
                         abstract=True)
    if shape.kind == "decode":
        toks = {"tokens": sds((B, 1), jnp.int32)}
    else:
        toks = {"tokens": sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        toks["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model),
                                 jnp.bfloat16)
    if cfg.family == "encdec":
        toks["frames"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return toks, caches


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """The dry-run entry: kwargs for the lowered step function."""
    if shape.kind == "train":
        return {"batch": train_input_specs(cfg, shape)}
    toks, caches = serve_input_specs(cfg, shape)
    return {"batch": toks, "caches": caches}
