"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices before any jax import, and tests/benches
see the single real CPU device.
"""

from __future__ import annotations


import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips, TPU v5e pod) or 2x16x16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Degenerate mesh over the locally available devices (CPU testing)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


HW = {
    # TPU v5e, per chip
    "peak_flops_bf16": 197e12,       # FLOP/s
    "hbm_bw": 819e9,                 # B/s
    "ici_link_bw": 50e9,             # B/s per link
    "hbm_bytes": 16 * 1024**3,
}
