"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --smoke --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/run1

On this CPU container ``--smoke`` selects the reduced config; on a real
cluster the same driver takes the full config + production mesh.  The
loop is restart-safe: rerunning with the same --ckpt-dir resumes from the
last checkpoint (fault tolerance / elasticity path).
"""

from __future__ import annotations

import argparse
import json
import time


from repro import configs
from repro.data import data_iterator
from repro.distributed.sharding import BASELINE_RULES, prune_to_mesh, \
    adapt_rules_for
from repro.launch.mesh import make_host_mesh
from repro.training import Trainer, TrainConfig, OptimizerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    mesh = make_host_mesh()
    rules = adapt_rules_for(
        prune_to_mesh(BASELINE_RULES, mesh), mesh, n_kv=cfg.n_kv,
        n_experts=cfg.n_experts, n_heads=cfg.n_heads, d_ff=cfg.d_ff,
        vocab=cfg.padded_vocab)

    tcfg = TrainConfig(
        num_microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, log_every=args.log_every,
        opt=OptimizerConfig(lr=args.lr, warmup_steps=min(100, args.steps),
                            total_steps=args.steps))
    trainer = Trainer(cfg, rules, tcfg, mesh=None)
    start = trainer.init(args.seed)
    print(f"training {cfg.name} from step {start} "
          f"(batch={args.batch} seq={args.seq})")
    it = data_iterator(cfg, args.batch, args.seq, start_step=start,
                       seed=args.seed)
    t0 = time.time()
    hist = trainer.run(it, args.steps - start)
    dt = time.time() - t0
    steps_done = args.steps - start
    print(f"{steps_done} steps in {dt:.1f}s "
          f"({steps_done / max(dt, 1e-9):.2f} steps/s)")
    for h in hist:
        print({k: round(v, 4) for k, v in h.items()})
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
